#!/usr/bin/env bash
# Determinism gate: placement-independent sampling (docs/DETERMINISM.md).
#
# Runs train_grpo twice at temperature 1.0 — a 1-engine fleet, then a
# 2-engine fleet that joins a third engine at iteration 2 — and fails if any
# request's sampled token/logprob stream differs between the dumps. This is
# the end-to-end check that every random draw is keyed by
# (run_seed, request_id, decode_step), never by engine identity, slot index,
# batch-mates, or admission order.
#
# Skips loudly (exit 0) when compiled artifacts are missing and cannot be
# built, or when the binary was built against the vendored xla stub (no PJRT
# backend) — environments that cannot execute artifacts at all. It becomes a
# hard gate automatically wherever a real backend is vendored.
set -euo pipefail
cd "$(dirname "$0")/.."

CONFIG="${PA_RL_DET_CONFIG:-configs/tiny.json}"
ITERS="${PA_RL_DET_ITERS:-3}"
OUT="${PA_RL_DET_OUT:-target/determinism-gate}"
NAME="$(basename "$CONFIG" .json)"
ARTIFACTS="artifacts/$NAME"

if [ ! -f "$ARTIFACTS/manifest.json" ]; then
  echo "determinism gate: $ARTIFACTS/manifest.json missing, trying to build it"
  if ! (cd python && python3 -m compile.aot --config "../$CONFIG") \
      || [ ! -f "$ARTIFACTS/manifest.json" ]; then
    echo "SKIP determinism gate: no compiled artifacts for $CONFIG (need python + jax)"
    exit 0
  fi
fi

mkdir -p "$OUT"

run() { # run <tag> <extra train_grpo flags...>
  local tag="$1"
  shift
  local log="$OUT/$tag.log"
  if ! cargo run -q --release --example train_grpo -- \
      --config "$CONFIG" --mode sync --iters "$ITERS" --temperature 1.0 \
      --dump-rollouts "$OUT/$tag.jsonl" "$@" >"$log" 2>&1; then
    if grep -q "requires a PJRT backend" "$log"; then
      echo "SKIP determinism gate: built against the vendored xla stub (no PJRT backend)"
      exit 0
    fi
    echo "determinism gate: run '$tag' failed:" >&2
    cat "$log" >&2
    exit 1
  fi
}

run single --engines 1
run elastic --engines 2 --join iter:2

if ! cmp -s "$OUT/single.jsonl" "$OUT/elastic.jsonl"; then
  echo "determinism gate FAILED: rollout streams differ between fleet shapes" >&2
  diff -u "$OUT/single.jsonl" "$OUT/elastic.jsonl" | head -40 >&2 || true
  exit 1
fi
echo "determinism gate OK: $(wc -l <"$OUT/single.jsonl") per-request streams bit-identical across fleet shapes"
