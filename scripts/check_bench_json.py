#!/usr/bin/env python3
"""Validate BENCH_*.json perf records against the repo's schema.

Usage: check_bench_json.py BENCH_micro.json [BENCH_pipeline.json ...]
       check_bench_json.py --diff COMMITTED.json FRESH.json [COMMITTED2 FRESH2 ...]

`--diff` compares freshly measured records against their committed
baselines, one (committed, fresh) pair at a time — CI gates both
BENCH_micro.json and BENCH_pipeline.json in a single invocation — and
fails (exit 1) on a perf regression:

  * every committed metric must still exist in the fresh record;
  * a metric measured with a real iteration count (fresh iters >
    QUICK_ITERS_MAX) may regress at most REGRESSION_LIMIT (25%);
  * a quick-clamped metric (fresh iters <= QUICK_ITERS_MAX — CI's
    PA_RL_BENCH_QUICK runs, too noisy for a tight gate) only trips the
    CATASTROPHIC_LIMIT (4x) backstop;
  * direction comes from the unit: throughput, speedup and efficiency
    units ("/s", "ops", "x", "ratio") regress downward, everything else
    (ns/us/ms/pct latencies and overheads) regresses upward. Metrics the
    baseline lacks are new and always pass.

Schema (emitted by rust/src/util/bench.rs::BenchRecorder):

    {
      "bench":   "<name>",            # non-empty string
      "source":  "<provenance>",      # non-empty string
      "metrics": [                    # >= MIN_METRICS entries
        {"metric": "<name>",          # non-empty string, unique per file
         "value":  <finite number>,
         "unit":   "<unit string>",
         "iters":  <int >= 0>},      # timed runs behind the value (0 = analytic)
        ...
      ]
    }

Exits non-zero (failing the CI job) on a missing file, unparseable JSON, or
any schema violation.
"""

import json
import math
import sys

MIN_METRICS = 5

# --diff gate: quick-mode runs clamp iters to <= 10 (see benches/perf_micro.rs
# PA_RL_BENCH_QUICK), so their numbers are noise-bounded only by the 4x
# catastrophic backstop; properly measured metrics get the strict 25% gate.
QUICK_ITERS_MAX = 10
REGRESSION_LIMIT = 1.25
CATASTROPHIC_LIMIT = 4.0


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    return 1


def check(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return fail(path, "file missing")
    except json.JSONDecodeError as e:
        return fail(path, f"not valid JSON: {e}")

    if not isinstance(doc, dict):
        return fail(path, "top level must be an object")
    for key in ("bench", "source"):
        if not isinstance(doc.get(key), str) or not doc[key]:
            return fail(path, f"'{key}' must be a non-empty string")
    metrics = doc.get("metrics")
    if not isinstance(metrics, list):
        return fail(path, "'metrics' must be an array")
    if len(metrics) < MIN_METRICS:
        return fail(path, f"only {len(metrics)} metrics; need >= {MIN_METRICS}")

    seen = set()
    for i, m in enumerate(metrics):
        where = f"metrics[{i}]"
        if not isinstance(m, dict):
            return fail(path, f"{where} must be an object")
        name = m.get("metric")
        if not isinstance(name, str) or not name:
            return fail(path, f"{where}.metric must be a non-empty string")
        if name in seen:
            return fail(path, f"duplicate metric '{name}'")
        seen.add(name)
        value = m.get("value")
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            return fail(path, f"{where}.value must be a number")
        if not math.isfinite(value):
            return fail(path, f"{where}.value must be finite, got {value}")
        if not isinstance(m.get("unit"), str):
            return fail(path, f"{where}.unit must be a string")
        iters = m.get("iters")
        if isinstance(iters, bool) or not isinstance(iters, int) or iters < 0:
            # BenchRecorder serialises iters through f64; accept exact floats.
            if not (isinstance(iters, float) and iters >= 0 and iters.is_integer()):
                return fail(path, f"{where}.iters must be an integer >= 0, got {iters!r}")
    print(f"OK   {path}: bench '{doc['bench']}', {len(metrics)} metrics")
    return 0


def higher_is_better(unit, metric):
    """Throughputs/speedups/efficiencies regress downward; latencies and
    overheads regress upward. Mirrored by tools/pa-report."""
    return (
        "/s" in unit
        or unit in ("ops", "x", "ratio")
        or metric.endswith("_per_s")
        or metric.endswith("_efficiency")
    )


def diff(committed_path, fresh_path):
    """Gate FRESH against the COMMITTED baseline; returns an exit code."""
    if check(committed_path) or check(fresh_path):
        return 1
    with open(committed_path, encoding="utf-8") as f:
        committed = {m["metric"]: m for m in json.load(f)["metrics"]}
    with open(fresh_path, encoding="utf-8") as f:
        fresh = {m["metric"]: m for m in json.load(f)["metrics"]}

    rc = 0
    for name, base in committed.items():
        cur = fresh.get(name)
        if cur is None:
            rc = fail(fresh_path, f"metric '{name}' vanished from the fresh record")
            continue
        quick = cur["iters"] <= QUICK_ITERS_MAX
        limit = CATASTROPHIC_LIMIT if quick else REGRESSION_LIMIT
        b, v = float(base["value"]), float(cur["value"])
        if b <= 0 or v <= 0:
            # Analytic zeros / degenerate baselines carry no ratio.
            print(f"OK   {name}: {b} -> {v} (no ratio gate on non-positive values)")
            continue
        ratio = b / v if higher_is_better(cur.get("unit", ""), name) else v / b
        tag = "quick, 4x backstop" if quick else "25% gate"
        if ratio > limit:
            rc = fail(
                fresh_path,
                f"metric '{name}' regressed {ratio:.2f}x (limit {limit}x, {tag}): "
                f"{b} -> {v} {cur.get('unit', '')}",
            )
        else:
            print(f"OK   {name}: {b} -> {v} ({ratio:.2f}x worse-direction, {tag})")
    for name in fresh:
        if name not in committed:
            print(f"OK   {name}: new metric (no baseline)")
    if rc == 0:
        print(f"OK   {fresh_path}: no regression vs {committed_path}")
    return rc


def main(argv):
    if len(argv) >= 2 and argv[1] == "--diff":
        pairs = argv[2:]
        if not pairs or len(pairs) % 2 != 0:
            print(__doc__, file=sys.stderr)
            return 2
        return max(
            diff(pairs[i], pairs[i + 1]) for i in range(0, len(pairs), 2)
        )
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    return max(check(p) for p in argv[1:])


if __name__ == "__main__":
    sys.exit(main(sys.argv))
