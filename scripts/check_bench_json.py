#!/usr/bin/env python3
"""Validate BENCH_*.json perf records against the repo's schema.

Usage: check_bench_json.py BENCH_micro.json [BENCH_pipeline.json ...]

Schema (emitted by rust/src/util/bench.rs::BenchRecorder):

    {
      "bench":   "<name>",            # non-empty string
      "source":  "<provenance>",      # non-empty string
      "metrics": [                    # >= MIN_METRICS entries
        {"metric": "<name>",          # non-empty string, unique per file
         "value":  <finite number>,
         "unit":   "<unit string>",
         "iters":  <int >= 0>},      # timed runs behind the value (0 = analytic)
        ...
      ]
    }

Exits non-zero (failing the CI job) on a missing file, unparseable JSON, or
any schema violation.
"""

import json
import math
import sys

MIN_METRICS = 5


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    return 1


def check(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return fail(path, "file missing")
    except json.JSONDecodeError as e:
        return fail(path, f"not valid JSON: {e}")

    if not isinstance(doc, dict):
        return fail(path, "top level must be an object")
    for key in ("bench", "source"):
        if not isinstance(doc.get(key), str) or not doc[key]:
            return fail(path, f"'{key}' must be a non-empty string")
    metrics = doc.get("metrics")
    if not isinstance(metrics, list):
        return fail(path, "'metrics' must be an array")
    if len(metrics) < MIN_METRICS:
        return fail(path, f"only {len(metrics)} metrics; need >= {MIN_METRICS}")

    seen = set()
    for i, m in enumerate(metrics):
        where = f"metrics[{i}]"
        if not isinstance(m, dict):
            return fail(path, f"{where} must be an object")
        name = m.get("metric")
        if not isinstance(name, str) or not name:
            return fail(path, f"{where}.metric must be a non-empty string")
        if name in seen:
            return fail(path, f"duplicate metric '{name}'")
        seen.add(name)
        value = m.get("value")
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            return fail(path, f"{where}.value must be a number")
        if not math.isfinite(value):
            return fail(path, f"{where}.value must be finite, got {value}")
        if not isinstance(m.get("unit"), str):
            return fail(path, f"{where}.unit must be a string")
        iters = m.get("iters")
        if isinstance(iters, bool) or not isinstance(iters, int) or iters < 0:
            # BenchRecorder serialises iters through f64; accept exact floats.
            if not (isinstance(iters, float) and iters >= 0 and iters.is_integer()):
                return fail(path, f"{where}.iters must be an integer >= 0, got {iters!r}")
    print(f"OK   {path}: bench '{doc['bench']}', {len(metrics)} metrics")
    return 0


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    return max(check(p) for p in argv[1:])


if __name__ == "__main__":
    sys.exit(main(sys.argv))
