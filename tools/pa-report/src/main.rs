//! `pa-report` — the human front-end for the repo's perf trajectory
//! (`cargo run -q -p pa-report -- <subcommand>` from the workspace root).
//!
//! Three subcommands, all reading artifacts other parts of the repo already
//! emit (no new instrumentation here):
//!
//! * `bench <fresh.json> [<baseline.json>]` — render a `BENCH_*.json`
//!   record (rust/src/util/bench.rs::BenchRecorder schema) as an aligned
//!   table; with a baseline, add delta columns and fail on regressions
//!   using the same gates as `scripts/check_bench_json.py`: 25% for
//!   properly measured metrics, a 4x backstop for quick-clamped ones
//!   (`iters <= 10`), direction from the unit.
//! * `diff <runA> <runB>` — compare two full-telemetry run directories
//!   (`artifacts/runs/<name>/`, written by the driver at
//!   `metrics.level = "full"`): per-iteration phase-attribution tables
//!   (producer idle / consumer wait / sync / useful / efficiency) for each
//!   run, then a mean-per-iteration comparison that fails when run B's
//!   pipeline efficiency regresses more than 25% against run A.
//! * `trace <trace.json>` — parse a Chrome trace-event export
//!   (`artifacts/runs/<name>/trace.json`) and check it against the schema
//!   Perfetto needs (monotonic `ts`, matched B/E pairs per track, stable
//!   pid/tid) via `pa_rl::metrics::validate_chrome_trace` — CI's gate on
//!   the exporter.
//!
//! Exit status 0 when clean; 1 with every finding on stderr otherwise.

use pa_rl::metrics::validate_chrome_trace;
use pa_rl::util::bench::Table;
use pa_rl::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// `--diff` gates, mirrored from `scripts/check_bench_json.py`: quick-mode
/// runs clamp `iters` to <= 10 and are noise-bounded only by the 4x
/// backstop; properly measured metrics get the strict 25% gate.
const QUICK_ITERS_MAX: f64 = 10.0;
const REGRESSION_LIMIT: f64 = 1.25;
const CATASTROPHIC_LIMIT: f64 = 4.0;

/// Throughputs, speedups, ratios and efficiencies regress downward;
/// latencies and overheads regress upward. Mirrors (and is mirrored by)
/// `higher_is_better` in `scripts/check_bench_json.py`.
fn higher_is_better(unit: &str, metric: &str) -> bool {
    unit.contains("/s")
        || unit == "ops"
        || unit == "x"
        || unit == "ratio"
        || metric.ends_with("_per_s")
        || metric.ends_with("_efficiency")
}

fn load_json(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

// ---------------------------------------------------------------- bench --

struct BenchMetric {
    name: String,
    value: f64,
    unit: String,
    iters: f64,
}

fn parse_bench(doc: &Json, path: &str) -> Result<(String, Vec<BenchMetric>), String> {
    let err = |e: pa_rl::util::json::JsonError| format!("{path}: {e}");
    let bench = doc.req_str("bench").map_err(err)?.to_string();
    let arr = doc
        .req("metrics")
        .map_err(err)?
        .as_arr()
        .ok_or_else(|| format!("{path}: 'metrics' must be an array"))?;
    let mut out = Vec::new();
    for m in arr {
        out.push(BenchMetric {
            name: m.req_str("metric").map_err(err)?.to_string(),
            value: m.req_f64("value").map_err(err)?,
            unit: m.req_str("unit").map_err(err)?.to_string(),
            iters: m.f64_or("iters", 0.0),
        });
    }
    Ok((bench, out))
}

/// Render the bench table (with delta columns when a baseline is given) and
/// collect regression findings. Pure, so the gate logic is unit-testable.
fn bench_report(
    fresh: &Json,
    fresh_path: &str,
    baseline: Option<(&Json, &str)>,
) -> Result<(String, Vec<String>), String> {
    let (name, metrics) = parse_bench(fresh, fresh_path)?;
    let base = match baseline {
        Some((doc, path)) => Some(parse_bench(doc, path)?.1),
        None => None,
    };
    let mut findings = Vec::new();
    let header: &[&str] = if base.is_some() {
        &["Metric", "Value", "Unit", "Iters", "Baseline", "Delta", "Gate"]
    } else {
        &["Metric", "Value", "Unit", "Iters"]
    };
    let mut t = Table::new(&format!("BENCH '{name}' ({fresh_path})"), header);
    for m in &metrics {
        let mut row = vec![
            m.name.clone(),
            format!("{}", m.value),
            m.unit.clone(),
            format!("{}", m.iters as u64),
        ];
        if let Some(base) = &base {
            match base.iter().find(|b| b.name == m.name) {
                None => row.extend(["(new)".into(), "-".into(), "pass".into()]),
                Some(b) if b.value <= 0.0 || m.value <= 0.0 => {
                    // Analytic zeros / degenerate baselines carry no ratio.
                    row.extend([format!("{}", b.value), "-".into(), "no-ratio".into()]);
                }
                Some(b) => {
                    let quick = m.iters <= QUICK_ITERS_MAX;
                    let limit = if quick { CATASTROPHIC_LIMIT } else { REGRESSION_LIMIT };
                    let ratio = if higher_is_better(&m.unit, &m.name) {
                        b.value / m.value
                    } else {
                        m.value / b.value
                    };
                    let gate = if ratio > limit {
                        findings.push(format!(
                            "{fresh_path}: metric '{}' regressed {ratio:.2}x \
                             (limit {limit}x{}): {} -> {} {}",
                            m.name,
                            if quick { ", quick backstop" } else { "" },
                            b.value,
                            m.value,
                            m.unit,
                        ));
                        "FAIL".to_string()
                    } else {
                        "pass".to_string()
                    };
                    row.extend([format!("{}", b.value), format!("{ratio:.2}x"), gate]);
                }
            }
        }
        t.row(&row);
    }
    if let Some(base) = &base {
        for b in base {
            if !metrics.iter().any(|m| m.name == b.name) {
                findings.push(format!(
                    "{fresh_path}: metric '{}' vanished vs the baseline",
                    b.name
                ));
            }
        }
    }
    Ok((t.render(), findings))
}

// ----------------------------------------------------------------- diff --

/// One iteration's phase attribution, from `iter_NNNN.json`'s `phases`
/// object (written by the driver at `metrics.level = "full"`).
#[derive(Debug, Clone, Copy, Default)]
struct IterPhases {
    iter: usize,
    idle: f64,
    wait: f64,
    sync: f64,
    useful: f64,
    eff: f64,
}

/// Load every `iter_*.json` under a full-telemetry run directory, in
/// iteration order. Errors when the directory holds none — the usual cause
/// is a run at `metrics.level = "basic"`, which writes no snapshots.
fn load_run(dir: &Path) -> Result<Vec<IterPhases>, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("iter_") && n.ends_with(".json"))
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!(
            "{}: no iter_*.json snapshots — was the run at metrics.level = \"full\"?",
            dir.display()
        ));
    }
    let mut out = Vec::new();
    for p in paths {
        let doc = load_json(&p)?;
        let phases = doc
            .req("phases")
            .map_err(|e| format!("{}: {e} (pre-attribution run?)", p.display()))?;
        out.push(IterPhases {
            iter: doc.f64_or("iter", out.len() as f64) as usize,
            idle: phases.f64_or("producer_idle_s", 0.0),
            wait: phases.f64_or("consumer_wait_s", 0.0),
            sync: phases.f64_or("sync_overhead_s", 0.0),
            useful: phases.f64_or("useful_compute_s", 0.0),
            eff: phases.f64_or("pipeline_efficiency", 0.0),
        });
    }
    Ok(out)
}

fn phase_table(name: &str, iters: &[IterPhases]) -> String {
    let mut t = Table::new(
        &format!("Phase attribution: {name}"),
        &["Iter", "Idle (s)", "Wait (s)", "Sync (s)", "Useful (s)", "Efficiency"],
    );
    for p in iters {
        t.row(&[
            format!("{}", p.iter),
            format!("{:.3}", p.idle),
            format!("{:.3}", p.wait),
            format!("{:.3}", p.sync),
            format!("{:.3}", p.useful),
            format!("{:.1}%", p.eff * 100.0),
        ]);
    }
    t.render()
}

fn mean<F: Fn(&IterPhases) -> f64>(iters: &[IterPhases], f: F) -> f64 {
    if iters.is_empty() {
        0.0
    } else {
        iters.iter().map(f).sum::<f64>() / iters.len() as f64
    }
}

/// Compare the per-iteration means of two runs; B regresses when a
/// lower-is-better phase grows (or efficiency shrinks) past the 25% gate.
/// Near-zero baselines (< 1 ms) carry no ratio and never flag.
fn diff_report(
    a_name: &str,
    a: &[IterPhases],
    b_name: &str,
    b: &[IterPhases],
) -> (String, Vec<String>) {
    let mut findings = Vec::new();
    let mut t = Table::new(
        &format!("Mean per iteration: {a_name} (A) vs {b_name} (B)"),
        &["Phase", "A", "B", "Ratio", "Gate"],
    );
    let rows: [(&str, fn(&IterPhases) -> f64, bool); 5] = [
        ("producer_idle_s", |p| p.idle, false),
        ("consumer_wait_s", |p| p.wait, false),
        ("sync_overhead_s", |p| p.sync, false),
        ("useful_compute_s", |p| p.useful, true),
        ("pipeline_efficiency", |p| p.eff, true),
    ];
    for (label, f, hib) in rows {
        let (va, vb) = (mean(a, f), mean(b, f));
        let (ratio, gate) = if va < 1e-3 || vb < 1e-3 {
            (None, "no-ratio".to_string())
        } else {
            let r = if hib { va / vb } else { vb / va };
            let gate = if r > REGRESSION_LIMIT {
                findings.push(format!(
                    "phase '{label}' regressed {r:.2}x (limit {REGRESSION_LIMIT}x): \
                     {va:.3} -> {vb:.3}"
                ));
                "FAIL".to_string()
            } else {
                "pass".to_string()
            };
            (Some(r), gate)
        };
        t.row(&[
            label.to_string(),
            format!("{va:.3}"),
            format!("{vb:.3}"),
            ratio.map_or("-".to_string(), |r| format!("{r:.2}x")),
            gate,
        ]);
    }
    (t.render(), findings)
}

// ---------------------------------------------------------------- trace --

fn trace_report(doc: &Json, path: &str) -> Result<String, String> {
    validate_chrome_trace(doc).map_err(|e| format!("{path}: {e}"))?;
    let events = doc
        .req("traceEvents")
        .ok()
        .and_then(Json::as_arr)
        .map_or(0, <[Json]>::len);
    Ok(format!("trace OK: {path} ({events} events, Perfetto-loadable)"))
}

// ----------------------------------------------------------------- main --

const USAGE: &str = "usage: pa-report bench <fresh.json> [<baseline.json>]
       pa-report diff <runA-dir> <runB-dir>
       pa-report trace <trace.json>";

fn run(args: &[String]) -> Result<Vec<String>, String> {
    match args {
        [cmd, fresh] if cmd == "bench" => {
            let doc = load_json(Path::new(fresh))?;
            let (table, findings) = bench_report(&doc, fresh, None)?;
            print!("{table}");
            Ok(findings)
        }
        [cmd, fresh, base] if cmd == "bench" => {
            let doc = load_json(Path::new(fresh))?;
            let base_doc = load_json(Path::new(base))?;
            let (table, findings) =
                bench_report(&doc, fresh, Some((&base_doc, base.as_str())))?;
            print!("{table}");
            Ok(findings)
        }
        [cmd, run_a, run_b] if cmd == "diff" => {
            let a = load_run(Path::new(run_a))?;
            let b = load_run(Path::new(run_b))?;
            print!("{}", phase_table(run_a, &a));
            print!("{}", phase_table(run_b, &b));
            let (table, findings) = diff_report(run_a, &a, run_b, &b);
            print!("{table}");
            Ok(findings)
        }
        [cmd, trace] if cmd == "trace" => {
            let doc = load_json(Path::new(trace))?;
            println!("{}", trace_report(&doc, trace)?);
            Ok(Vec::new())
        }
        [h] if h == "-h" || h == "--help" => {
            println!("{USAGE}");
            Ok(Vec::new())
        }
        _ => Err(USAGE.to_string()),
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Err(e) => {
            eprintln!("pa-report: {e}");
            ExitCode::FAILURE
        }
        Ok(findings) if findings.is_empty() => ExitCode::SUCCESS,
        Ok(findings) => {
            for f in &findings {
                eprintln!("pa-report: {f}");
            }
            eprintln!("pa-report: {} regression(s)", findings.len());
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bench_doc(metrics: &[(&str, f64, &str, f64)]) -> Json {
        Json::obj(vec![
            ("bench", Json::str("t")),
            ("source", Json::str("test")),
            (
                "metrics",
                Json::arr(metrics.iter().map(|(n, v, u, i)| {
                    Json::obj(vec![
                        ("metric", Json::str(n)),
                        ("value", Json::num(*v)),
                        ("unit", Json::str(u)),
                        ("iters", Json::num(*i)),
                    ])
                })),
            ),
        ])
    }

    #[test]
    fn direction_follows_unit_and_name() {
        assert!(higher_is_better("ops/s", "m"));
        assert!(higher_is_better("tokens/s/device", "m"));
        assert!(higher_is_better("x", "sim_async_speedup_x"));
        assert!(higher_is_better("ratio", "m"));
        assert!(higher_is_better("s", "pipeline_efficiency"));
        assert!(!higher_is_better("ns/token", "m"));
        assert!(!higher_is_better("us/call", "m"));
        assert!(!higher_is_better("%", "overhead_pct"));
    }

    #[test]
    fn bench_without_baseline_renders_and_passes() {
        let doc = bench_doc(&[("a", 10.0, "us/call", 100.0)]);
        let (table, findings) = bench_report(&doc, "f.json", None).unwrap();
        assert!(table.contains("a"));
        assert!(table.contains("us/call"));
        assert!(findings.is_empty());
    }

    #[test]
    fn bench_latency_regression_flags_past_25pct() {
        let base = bench_doc(&[("lat", 100.0, "us/call", 100.0)]);
        let ok = bench_doc(&[("lat", 120.0, "us/call", 100.0)]);
        let bad = bench_doc(&[("lat", 130.0, "us/call", 100.0)]);
        let (_, f) = bench_report(&ok, "f", Some((&base, "b"))).unwrap();
        assert!(f.is_empty(), "{f:?}");
        let (t, f) = bench_report(&bad, "f", Some((&base, "b"))).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(t.contains("FAIL"));
    }

    #[test]
    fn bench_throughput_regresses_downward_only() {
        let base = bench_doc(&[("tps", 100.0, "ops/s", 100.0)]);
        let up = bench_doc(&[("tps", 200.0, "ops/s", 100.0)]);
        let down = bench_doc(&[("tps", 70.0, "ops/s", 100.0)]);
        assert!(bench_report(&up, "f", Some((&base, "b"))).unwrap().1.is_empty());
        assert_eq!(bench_report(&down, "f", Some((&base, "b"))).unwrap().1.len(), 1);
    }

    #[test]
    fn bench_quick_iters_get_4x_backstop() {
        let base = bench_doc(&[("lat", 100.0, "us/call", 100.0)]);
        let noisy = bench_doc(&[("lat", 300.0, "us/call", 5.0)]);
        let awful = bench_doc(&[("lat", 500.0, "us/call", 5.0)]);
        assert!(bench_report(&noisy, "f", Some((&base, "b"))).unwrap().1.is_empty());
        assert_eq!(bench_report(&awful, "f", Some((&base, "b"))).unwrap().1.len(), 1);
    }

    #[test]
    fn bench_new_and_vanished_metrics() {
        let base = bench_doc(&[("gone", 1.0, "us", 100.0)]);
        let fresh = bench_doc(&[("new", 1.0, "us", 100.0)]);
        let (t, f) = bench_report(&fresh, "f", Some((&base, "b"))).unwrap();
        assert!(t.contains("(new)"));
        assert_eq!(f.len(), 1);
        assert!(f[0].contains("vanished"));
    }

    #[test]
    fn bench_non_positive_values_skip_the_ratio() {
        let base = bench_doc(&[("m", 0.0, "us", 0.0)]);
        let fresh = bench_doc(&[("m", 5.0, "us", 0.0)]);
        let (t, f) = bench_report(&fresh, "f", Some((&base, "b"))).unwrap();
        assert!(f.is_empty(), "{f:?}");
        assert!(t.contains("no-ratio"));
    }

    fn phases(iter: usize, idle: f64, eff: f64) -> IterPhases {
        IterPhases { iter, idle, wait: 0.5, sync: 0.2, useful: 8.0, eff }
    }

    #[test]
    fn diff_flags_efficiency_drop_and_idle_growth() {
        let a = vec![phases(0, 1.0, 0.8), phases(1, 1.0, 0.8)];
        let b = vec![phases(0, 2.0, 0.5), phases(1, 2.0, 0.5)];
        let (table, findings) = diff_report("a", &a, "b", &b);
        assert!(table.contains("pipeline_efficiency"));
        assert!(findings.iter().any(|f| f.contains("pipeline_efficiency")));
        assert!(findings.iter().any(|f| f.contains("producer_idle_s")));
    }

    #[test]
    fn diff_passes_on_equal_runs() {
        let a = vec![phases(0, 1.0, 0.8)];
        let (_, findings) = diff_report("a", &a, "b", &a.clone());
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn diff_near_zero_baselines_carry_no_ratio() {
        let a = vec![IterPhases { iter: 0, ..Default::default() }];
        let b = vec![IterPhases { iter: 0, idle: 5.0, ..Default::default() }];
        let (table, findings) = diff_report("a", &a, "b", &b);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(table.contains("no-ratio"));
    }

    #[test]
    fn run_dir_loads_iter_snapshots_in_order() {
        let dir = std::env::temp_dir().join("pa_report_run_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (i, eff) in [(1usize, 0.5), (0, 0.4)] {
            let doc = Json::obj(vec![
                ("iter", Json::num(i as f64)),
                (
                    "phases",
                    Json::obj(vec![
                        ("producer_idle_s", Json::num(1.0)),
                        ("consumer_wait_s", Json::num(0.1)),
                        ("sync_overhead_s", Json::num(0.2)),
                        ("useful_compute_s", Json::num(4.0)),
                        ("pipeline_efficiency", Json::num(eff)),
                    ]),
                ),
            ]);
            std::fs::write(dir.join(format!("iter_{i:04}.json")), doc.to_pretty()).unwrap();
        }
        // A non-iteration file in the same dir is ignored.
        std::fs::write(dir.join("metrics.prom"), "x 1\n").unwrap();
        let run = load_run(&dir).unwrap();
        assert_eq!(run.len(), 2);
        assert_eq!((run[0].iter, run[1].iter), (0, 1));
        assert!((run[0].eff - 0.4).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_dir_without_snapshots_errors_helpfully() {
        let dir = std::env::temp_dir().join("pa_report_empty_run_test");
        std::fs::create_dir_all(&dir).unwrap();
        let err = load_run(&dir).unwrap_err();
        assert!(err.contains("metrics.level"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_subcommand_accepts_a_real_export() {
        let trace = pa_rl::metrics::Trace::new();
        trace.record_abs("infer-0", "step", 0.0, 0.5);
        trace.record_abs("train", "micro", 0.2, 0.9);
        let doc = trace.to_chrome_json();
        let report = trace_report(&doc, "t.json").unwrap();
        assert!(report.contains("trace OK"));
    }

    #[test]
    fn trace_subcommand_rejects_garbage() {
        let doc = Json::obj(vec![("nope", Json::num(1.0))]);
        assert!(trace_report(&doc, "t.json").is_err());
    }
}
