//! `pa-lint` — the repo-invariant source linter, run as a hard CI gate
//! (`cargo run -q -p pa-lint` from the workspace root).
//!
//! A deliberately dumb plain-text scanner (no syn, no regex, no
//! dependencies) enforcing four invariants the compiler cannot:
//!
//! 1. **shims** — no direct `std::sync` concurrency primitive or
//!    `std::thread` spawn outside `rust/src/check/`: everything must go
//!    through the `check::sync` / `check::thread` shims so the
//!    `pa_modelcheck` scheduler sees every operation. `Arc`, `Weak`,
//!    `Once` and `atomic::Ordering` are exempt (they carry no scheduling
//!    decision).
//! 2. **unwraps** — no `.unwrap()` / `.expect(` in non-test code under
//!    `rust/src/{engine,store,coordinator}` (the hot paths): fallible paths
//!    use `anyhow` or `check::sync::lock_or_poison`. A site whose panic
//!    freedom is a structural invariant carries a
//!    `// pa-lint: allow(unwrap): <reason>` (or `allow(expect)`) waiver on
//!    the same or the preceding line.
//! 3. **config-docs** — every `pub` field in `rust/src/config.rs` states
//!    its default (or that it is required) in its doc comment, so the doc
//!    comments cannot silently drift from `Config::from_json`.
//! 4. **coordinator-threads** — no thread creation (not even via the
//!    shims) inside `rust/src/coordinator/` outside the executor entry
//!    point `worker.rs`: coordinator control flow lives on the
//!    deterministic executor (`coordinator::exec`), where the simulated
//!    fleet can replay it. New concurrency goes in as an executor task or
//!    behind `spawn_worker`, not as an ad-hoc thread.
//!
//! Exit status 0 with a one-line summary when clean; otherwise every
//! violation prints as `file:line: [rule] message` and the status is 1.
//!
//! Lines whose trimmed form starts with a comment marker are never
//! flagged — prose about a pattern is not a use of it.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One finding, printed as `file:line: [rule] message`.
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// `std::sync` / `std::thread` tokens the shims must mediate. `Arc`,
/// `Weak`, `Once` and `Ordering` are deliberately absent: they are not
/// scheduling decisions, and the shims re-export them untouched.
const FORBIDDEN_STD: &[&str] = &[
    "std::sync::Mutex",
    "std::sync::Condvar",
    "std::sync::RwLock",
    "std::sync::Barrier",
    "std::sync::mpsc",
    "std::sync::atomic::Atomic",
    "std::thread::spawn",
    "std::thread::Builder",
    "std::thread::yield_now",
    "std::thread::scope",
];

/// Directories (relative to the workspace root) swept by the shims rule.
const SHIM_SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Hot-path directories swept by the unwraps rule.
const UNWRAP_SCAN_DIRS: &[&str] =
    &["rust/src/engine", "rust/src/store", "rust/src/coordinator"];

/// The shim layer itself — the one place allowed to touch std primitives.
const SHIM_EXEMPT_PREFIX: &str = "rust/src/check";

fn is_comment(trimmed: &str) -> bool {
    trimmed.starts_with("//")
}

const WAIVER: &str = "pa-lint: allow(";

/// Rule 1: forbid direct std concurrency primitives outside the shim layer.
fn lint_shims(file: &str, content: &str, out: &mut Vec<Violation>) {
    for (i, line) in content.lines().enumerate() {
        let t = line.trim_start();
        if is_comment(t) {
            continue;
        }
        for pat in FORBIDDEN_STD {
            if line.contains(pat) {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "shims",
                    msg: format!(
                        "`{pat}` bypasses the model-check shims; use `crate::check::sync` / `crate::check::thread` instead"
                    ),
                });
                break; // one finding per line is enough
            }
        }
    }
}

/// Rule 2: forbid `.unwrap()` / `.expect(` in non-test hot-path code,
/// unless waived on the same or the preceding line.
fn lint_unwraps(file: &str, content: &str, out: &mut Vec<Violation>) {
    let mut prev = "";
    for (i, line) in content.lines().enumerate() {
        let t = line.trim_start();
        // Everything from the first `#[cfg(test)]` down is test code.
        if t.starts_with("#[cfg(test)]") {
            break;
        }
        if is_comment(t) {
            prev = t;
            continue;
        }
        let hit = line.contains(".unwrap(") || line.contains(".expect(");
        if hit {
            let waived =
                line.contains(WAIVER) || (is_comment(prev) && prev.contains(WAIVER));
            if !waived {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "unwraps",
                    msg: "unwaived `.unwrap()`/`.expect(` on a hot path; return anyhow::Result, use `lock_or_poison`, or add `// pa-lint: allow(unwrap): <reason>`"
                        .to_string(),
                });
            }
        }
        prev = t;
    }
}

/// Rule 3: every pub field in config.rs documents its default. A field
/// line is `pub <name>: <type>,` (no parentheses — that would be a fn).
fn lint_config_docs(file: &str, content: &str, out: &mut Vec<Violation>) {
    let mut docs = String::new();
    for (i, line) in content.lines().enumerate() {
        let t = line.trim_start();
        if t.starts_with("#[cfg(test)]") {
            break;
        }
        if t.starts_with("///") {
            docs.push_str(&t.to_ascii_lowercase());
            docs.push('\n');
            continue;
        }
        let is_field = t.starts_with("pub ")
            && !t.starts_with("pub fn")
            && !t.starts_with("pub struct")
            && !t.starts_with("pub enum")
            && !t.starts_with("pub use")
            && !t.starts_with("pub mod")
            && t.contains(':')
            && !t.contains('(')
            && t.ends_with(',');
        if is_field && !docs.contains("default") && !docs.contains("required") {
            out.push(Violation {
                file: file.to_string(),
                line: i + 1,
                rule: "config-docs",
                msg: format!(
                    "config knob `{}` has no doc comment stating its default (or that it is required)",
                    t.trim_end_matches(',')
                ),
            });
        }
        if !t.starts_with("#[") {
            docs.clear(); // attributes may sit between docs and field
        }
    }
}

/// The coordinator module swept by the coordinator-threads rule, and the
/// one file inside it allowed to create OS threads (the bridge between the
/// real fleet and the executor's control loops).
const COORD_DIR: &str = "rust/src/coordinator/";
const COORD_THREAD_EXEMPT: &str = "rust/src/coordinator/worker.rs";

/// Rule 4: coordinator control flow runs on the deterministic executor;
/// only the executor entry point (`worker.rs`) may create threads, even
/// through the shims. Everything else would run outside both the simulated
/// fleet and the model checker.
fn lint_coordinator_threads(file: &str, content: &str, out: &mut Vec<Violation>) {
    if !file.starts_with(COORD_DIR) || file == COORD_THREAD_EXEMPT {
        return;
    }
    for (i, line) in content.lines().enumerate() {
        let t = line.trim_start();
        if is_comment(t) {
            continue;
        }
        if t.contains("thread::") || t.contains("check::thread") {
            out.push(Violation {
                file: file.to_string(),
                line: i + 1,
                rule: "coordinator-threads",
                msg: "thread use in the coordinator outside the executor entry point (worker.rs); run it as an `exec::spawn` task so the simulated fleet and model checker see it"
                    .to_string(),
            });
        }
    }
}

/// Collect `.rs` files under `dir`, sorted for deterministic output.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

fn read(p: &Path) -> Result<String, String> {
    std::fs::read_to_string(p).map_err(|e| format!("pa-lint: reading {}: {e}", p.display()))
}

/// Run all rules against the workspace at `root`.
fn run(root: &Path) -> Result<Vec<Violation>, String> {
    let src = root.join("rust/src");
    if !src.is_dir() {
        return Err(format!(
            "pa-lint: {} has no rust/src — run from the workspace root or pass --root",
            root.display()
        ));
    }
    let mut out = Vec::new();

    for dir in SHIM_SCAN_DIRS {
        let mut files = Vec::new();
        rs_files(&root.join(dir), &mut files);
        for f in files {
            let name = rel(root, &f);
            if name.starts_with(SHIM_EXEMPT_PREFIX) {
                continue;
            }
            let content = read(&f)?;
            lint_shims(&name, &content, &mut out);
            lint_coordinator_threads(&name, &content, &mut out);
        }
    }

    for dir in UNWRAP_SCAN_DIRS {
        let mut files = Vec::new();
        rs_files(&root.join(dir), &mut files);
        for f in files {
            let name = rel(root, &f);
            lint_unwraps(&name, &read(&f)?, &mut out);
        }
    }

    let cfg = root.join("rust/src/config.rs");
    lint_config_docs(&rel(root, &cfg), &read(&cfg)?, &mut out);

    Ok(out)
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("pa-lint: --root needs a path");
                    return ExitCode::FAILURE;
                }
            },
            "-h" | "--help" => {
                println!("usage: pa-lint [--root <workspace-root>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pa-lint: unknown argument {other} (try --help)");
                return ExitCode::FAILURE;
            }
        }
    }
    match run(&root) {
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
        Ok(violations) if violations.is_empty() => {
            println!("pa-lint: OK (shims, unwraps, config-docs, coordinator-threads)");
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            eprintln!("pa-lint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLEAN: &str = include_str!("../fixtures/clean.rs");
    const VIOLATIONS: &str = include_str!("../fixtures/violations.rs");

    #[test]
    fn clean_fixture_passes_every_rule() {
        let mut out = Vec::new();
        lint_shims("fixtures/clean.rs", CLEAN, &mut out);
        lint_unwraps("fixtures/clean.rs", CLEAN, &mut out);
        lint_config_docs("fixtures/clean.rs", CLEAN, &mut out);
        assert!(out.is_empty(), "clean fixture flagged: {:?}", out.iter().map(|v| v.to_string()).collect::<Vec<_>>());
    }

    #[test]
    fn violations_fixture_fails_with_file_and_line() {
        let mut out = Vec::new();
        lint_shims("fixtures/violations.rs", VIOLATIONS, &mut out);
        lint_unwraps("fixtures/violations.rs", VIOLATIONS, &mut out);
        lint_config_docs("fixtures/violations.rs", VIOLATIONS, &mut out);
        let msgs: Vec<String> = out.iter().map(|v| v.to_string()).collect();
        // Every expected violation, located by file:line.
        let expect = [
            ("shims", 4),      // std::sync::Mutex
            ("shims", 5),      // std::thread::spawn
            ("shims", 6),      // std::sync::mpsc
            ("unwraps", 10),   // bare .unwrap()
            ("unwraps", 11),   // bare .expect(
            ("config-docs", 21), // knob without a Default line
        ];
        for (rule, line) in expect {
            assert!(
                out.iter().any(|v| v.rule == rule && v.line == line),
                "missing [{rule}] at line {line}; got: {msgs:?}"
            );
            assert!(
                msgs.iter().any(|m| m.starts_with(&format!("fixtures/violations.rs:{line}:"))),
                "violation at {line} not formatted as file:line; got: {msgs:?}"
            );
        }
        assert_eq!(out.len(), expect.len(), "unexpected extra findings: {msgs:?}");
    }

    #[test]
    fn waivers_and_comments_are_respected() {
        let src = "\
// std::sync::Mutex in prose is fine
let a = x.lock(); // no unwrap here
// pa-lint: allow(unwrap): checked two lines up
let b = y.unwrap();
let c = z.unwrap(); // pa-lint: allow(unwrap): same-line waiver
";
        let mut out = Vec::new();
        lint_shims("w.rs", src, &mut out);
        lint_unwraps("w.rs", src, &mut out);
        assert!(out.is_empty(), "waived/comment lines flagged: {:?}", out.iter().map(|v| v.to_string()).collect::<Vec<_>>());
    }

    #[test]
    fn coordinator_threads_rule_is_scoped_to_the_executor_entry_point() {
        let src = "\
use crate::check::thread::Builder;
let h = thread::spawn(work);
";
        let mut out = Vec::new();
        lint_coordinator_threads("rust/src/coordinator/driver.rs", src, &mut out);
        assert_eq!(out.len(), 2, "both the import and the spawn must be flagged");
        assert!(out.iter().all(|v| v.rule == "coordinator-threads"));
        assert_eq!((out[0].line, out[1].line), (1, 2));

        out.clear();
        // worker.rs is the executor entry point; code outside the
        // coordinator is the shims rule's business, not this one's.
        lint_coordinator_threads("rust/src/coordinator/worker.rs", src, &mut out);
        lint_coordinator_threads("rust/src/engine/mod.rs", src, &mut out);
        // Prose about threads is never a violation.
        lint_coordinator_threads(
            "rust/src/coordinator/exec.rs",
            "// unlike thread::spawn, tasks are polled deterministically\n",
            &mut out,
        );
        assert!(
            out.is_empty(),
            "exempt paths flagged: {:?}",
            out.iter().map(|v| v.to_string()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn test_module_code_is_exempt_from_unwraps() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    fn t() { None::<u32>.unwrap(); }
}
";
        let mut out = Vec::new();
        lint_unwraps("t.rs", src, &mut out);
        assert!(out.is_empty());
    }
}
