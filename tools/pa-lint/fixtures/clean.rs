//! Fixture: idiomatic code every rule accepts (never compiled — scanned as
//! plain text).

use crate::check::sync::{lock_or_poison, Arc, Mutex};
use crate::check::thread;

fn shims_ok() {
    let m = Arc::new(Mutex::new(0u32));
    let h = thread::spawn(move || *lock_or_poison(&m));
    drop(h);
}

fn unwraps_ok(x: Option<u32>) -> anyhow::Result<u32> {
    // pa-lint: allow(unwrap): fixture demonstrates a waived site
    let _a = x.unwrap();
    x.ok_or_else(|| anyhow::anyhow!("missing"))
}

/// A fully documented config struct.
pub struct CleanCfg {
    /// Knob with a stated fallback. Default: 4.
    pub knob: usize,
    /// Knob the user must set. Required.
    pub mandatory: usize,
}
