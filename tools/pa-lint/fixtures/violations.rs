//! Fixture: every rule fires here (never compiled — scanned as plain text).

fn shim_violations() {
    let _m = std::sync::Mutex::new(0);
    let _t = std::thread::spawn(|| {});
    let (_tx, _rx) = std::sync::mpsc::channel::<u32>();
}

fn unwrap_violations(x: Option<u32>) {
    let _a = x.unwrap();
    let _b = x.expect("boom");
}

/// A config struct whose second knob forgot its fallback line.
pub struct FixtureCfg {
    /// Documented knob. Default: 8.
    pub documented: usize,
    /// Undocumented knob — the doc comment says nothing about its
    /// fallback value, so the config-docs rule must flag the field
    /// below.
    pub undocumented: usize,
}
