//! L3 hot-path microbenchmarks: the coordinator-side code that runs per
//! rollout / per micro-step. None of these may rival the PJRT compute —
//! EXPERIMENTS.md §Perf tracks the before/after of the optimisation pass.

use pa_rl::data::{DataLoader, TaskGen, Tokenizer};
use pa_rl::engine::{sample, SamplerCfg};
use pa_rl::grpo::{build_spa, build_standard, group_advantages, reward, Sample};
use pa_rl::metrics::Trace;
use pa_rl::util::bench::{bench, BenchRecorder, Table};
use pa_rl::util::json::Json;
use pa_rl::util::rng::Pcg64;

fn main() {
    let mut t = Table::new(
        "L3 microbenchmarks (per-op cost on the request path)",
        &["Operation", "mean", "p95", "per-unit"],
    );
    // Machine-readable record committed at the repo root (BENCH_micro.json):
    // the perf trajectory optimisation PRs refresh and CI schema-validates.
    let mut rec = BenchRecorder::new("micro", "benches/perf_micro.rs");
    let mut add = |name: &str, stats: pa_rl::util::bench::Stats, unit: String| {
        t.row(&[
            name.to_string(),
            format!("{:.1} us", stats.mean.as_secs_f64() * 1e6),
            format!("{:.1} us", stats.p95.as_secs_f64() * 1e6),
            unit,
        ]);
    };

    // SPA packing: one G=32 group, realistic lengths
    let mut rng = Pcg64::seeded(1);
    let prompt: Vec<u32> = (0..64).map(|_| 3 + rng.next_u64() as u32 % 20).collect();
    let responses: Vec<Vec<u32>> =
        (0..32).map(|_| (0..rng.range(4, 16)).map(|_| 5u32).collect()).collect();
    let samples: Vec<Sample> =
        responses.iter().map(|r| Sample { prompt: &prompt, response: r, advantage: 0.5 }).collect();
    let tokens: usize = 64 + responses.iter().map(|r| r.len()).sum::<usize>();
    let s = bench("spa_pack", 50, 500, || {
        std::hint::black_box(build_spa(&samples, 640).unwrap());
    });
    add("SPA pack (G=32 group)", s.clone(), format!("{:.0} ns/token", s.mean_secs() * 1e9 / tokens as f64));
    rec.push("spa_pack_ns_per_token", s.mean_secs() * 1e9 / tokens as f64, "ns/token", s.n);

    let s = bench("std_pack", 50, 500, || {
        std::hint::black_box(build_standard(&samples[..8], 8, 96));
    });
    add("standard pack (8 rows)", s, String::new());

    // advantages
    let rewards: Vec<f32> = (0..32).map(|i| (i % 2) as f32).collect();
    let s = bench("advantages", 100, 2000, || {
        std::hint::black_box(group_advantages(&rewards));
    });
    add("group advantages (G=32)", s, String::new());

    // reward scoring
    let tok = Tokenizer::new();
    let resp = tok.encode("1234").unwrap();
    let s = bench("reward", 100, 2000, || {
        std::hint::black_box(reward::score(&tok, &resp, 1234));
    });
    add("reward score", s, String::new());

    // sampler over a 32k-vocab logits row (production-scale vocab)
    let logits: Vec<f32> = (0..32_000).map(|i| ((i * 2654435761u64 as usize) % 1000) as f32 / 100.0).collect();
    let cfg = SamplerCfg { temperature: 1.0, top_p: 0.95, top_k: 20 };
    let mut srng = Pcg64::seeded(2);
    let sampler = bench("sampler32k", 20, 200, || {
        std::hint::black_box(sample(&logits, &cfg, &mut srng));
    });
    add("host sampler (V=32k, top-p+top-k)", sampler.clone(), String::new());
    rec.push("sampler_32k_p50_us", sampler.p50.as_secs_f64() * 1e6, "us/call", sampler.n);

    // prompt generation
    let gen = TaskGen::new(pa_rl::config::DataConfig { few_shot: 2, shared_few_shot: false, max_operand: 99, seed: 0 });
    let mut i = 0u64;
    let s = bench("taskgen", 100, 2000, || {
        i += 1;
        std::hint::black_box(gen.prompt(i));
    });
    add("prompt generation (few-shot 2)", s, String::new());

    // dataloader batch
    let mut dl = DataLoader::new(pa_rl::config::DataConfig { few_shot: 1, shared_few_shot: false, max_operand: 99, seed: 0 });
    let s = bench("loader", 20, 200, || {
        std::hint::black_box(dl.next_batch(32));
    });
    add("dataloader batch (N=32)", s, String::new());

    // trace recording (multi-thread contention is the concern)
    let trace = Trace::new();
    let s = bench("trace", 100, 5000, || {
        trace.record("lane", "x", 0.0);
    });
    rec.push("trace_record_p50_ns", s.p50.as_secs_f64() * 1e9, "ns/span", s.n);
    add("trace span record", s, String::new());

    // queue send/recv roundtrip (through the check::sync shim, which must
    // compile down to plain std::sync::mpsc with the feature off — this
    // metric doubles as the shim-overhead guard in the bench-diff CI gate)
    let (tx, rx) = pa_rl::check::sync::mpsc::sync_channel::<u64>(1024);
    let s = bench("queue", 100, 5000, || {
        tx.send(1).unwrap();
        std::hint::black_box(rx.recv().unwrap());
    });
    add("bounded queue send+recv", s, String::new());

    // json parse of a manifest-sized document
    let doc = {
        let mut arr = Vec::new();
        for i in 0..200 {
            arr.push(Json::obj(vec![
                ("name", Json::str(&format!("t{i}"))),
                ("shape", Json::arr((0..3).map(|d| Json::num((d * i) as f64)))),
            ]));
        }
        Json::obj(vec![("params", Json::Arr(arr))]).to_string()
    };
    let s = bench("json", 20, 500, || {
        std::hint::black_box(Json::parse(&doc).unwrap());
    });
    rec.push("json_parse_manifest_p50_us", s.p50.as_secs_f64() * 1e6, "us/parse", s.n);
    add(&format!("json parse ({} B)", doc.len()), s, String::new());

    // prefill sharing: G=8 identical prompts admitted through the prefix
    // cache (1 miss + 7 hits, including the KV gather/scatter the engine
    // does) vs the cache-off baseline of G compiled prefills.
    {
        use pa_rl::engine::kvcache::{self, EvictPolicy, KvGeometry, PrefixCache, PrefixCacheCfg};
        let geom = KvGeometry { n_layers: 4, n_slots: 8, cache_len: 96, kv_heads: 2, head_dim: 16 };
        let lp = 64usize;
        let g = 8usize;
        let prompt: Vec<u32> = (0..lp as u32).map(|i| 3 + (i * 7) % 50).collect();
        let kv_len = geom.n_layers * geom.n_slots * 2 * geom.cache_len * geom.kv_heads * geom.head_dim;
        let mut kv: Vec<f32> = (0..kv_len).map(|i| (i % 997) as f32).collect();
        let mut tokens_prefilled = 0u64;
        let s = bench("prefix_admit", 20, 200, || {
            let mut cache = PrefixCache::new(
                geom.clone(),
                PrefixCacheCfg { block_tokens: 16, capacity_blocks: 64, policy: EvictPolicy::Lru },
            );
            let mut leases = Vec::new();
            for slot in 0..g {
                match cache.match_prompt(&prompt) {
                    Some(hit) => {
                        kvcache::scatter_prompt_rows(&mut kv, &geom, slot, &hit.rows);
                        leases.push(hit.lease);
                    }
                    None => {
                        let rows = kvcache::gather_prompt_rows(&kv, &geom, slot, lp);
                        leases.extend(cache.insert(&prompt, &rows, vec![0.0; 64]));
                    }
                }
            }
            tokens_prefilled = cache.stats.miss_tokens;
            for l in leases {
                cache.release(l);
            }
            std::hint::black_box(&kv);
        });
        add(
            "prefix-cache admit (G=8, Lp=64: 1 miss + 7 hits)",
            s.clone(),
            format!(
                "{:.1} us/rollout; prefilled {tokens_prefilled} vs {} tokens",
                s.mean_secs() * 1e6 / g as f64,
                g * lp
            ),
        );
        rec.push("prefix_admit_us_per_rollout", s.mean_secs() * 1e6 / g as f64, "us/rollout", s.n);
    }

    // chunked partial-prefix admission: a warm 48-token few-shot template
    // shared by 8 prompts with distinct 16-token questions. Cache-side cost
    // of the engine's resumable admission (match + restore + per-chunk
    // publication), reporting `prefill_tokens_saved` — the KV rows restored
    // instead of recomputed, the tentpole saving of chunked prefill.
    {
        use pa_rl::engine::chunked::{plan_chunks, resume_point};
        use pa_rl::engine::kvcache::{self, EvictPolicy, KvGeometry, PrefixCache, PrefixCacheCfg};
        let geom = KvGeometry { n_layers: 4, n_slots: 8, cache_len: 96, kv_heads: 2, head_dim: 16 };
        let tpl = 48usize;
        let lp = 64usize;
        let n_prompts = 8usize;
        let cb = 16usize;
        let prompts: Vec<Vec<u32>> = (0..n_prompts as u32)
            .map(|q| {
                (0..lp as u32)
                    .map(|i| if (i as usize) < tpl { 3 + (i * 7) % 50 } else { 60 + q * 31 + i })
                    .collect()
            })
            .collect();
        let kv_len = geom.n_layers * geom.n_slots * 2 * geom.cache_len * geom.kv_heads * geom.head_dim;
        let mut kv: Vec<f32> = (0..kv_len).map(|i| (i % 991) as f32).collect();
        let mut tokens_saved = 0u64;
        let mut chunk_calls = 0u64;
        let s = bench("chunked_admit", 20, 200, || {
            let mut cache = PrefixCache::new(
                geom.clone(),
                PrefixCacheCfg { block_tokens: cb, capacity_blocks: 128, policy: EvictPolicy::Lru },
            );
            tokens_saved = 0;
            chunk_calls = 0;
            let mut leases = Vec::new();
            for (slot, prompt) in prompts.iter().enumerate() {
                let m = cache.match_prefix(prompt);
                if let Some(logits) = m.logits {
                    kvcache::scatter_prompt_rows(&mut kv, &geom, slot, &m.rows);
                    tokens_saved += prompt.len() as u64;
                    leases.extend(m.lease);
                    std::hint::black_box(logits);
                    continue;
                }
                let resume = resume_point(m.matched, prompt.len());
                let re = geom.row_elems();
                let mut rows_acc = m.rows[..resume * re].to_vec();
                kvcache::scatter_prompt_rows(&mut kv, &geom, slot, &rows_acc);
                tokens_saved += resume as u64;
                let mut lease = m.lease;
                for c in plan_chunks(prompt.len(), resume, cb) {
                    // (compiled `prefill_chunk` would run here; the bench
                    // measures the cache-side admission machinery around it)
                    chunk_calls += 1;
                    let end = c.start + c.len;
                    rows_acc.extend_from_slice(&kvcache::gather_rows_range(
                        &kv, &geom, slot, c.start, end,
                    ));
                    let term = (end == prompt.len()).then(|| vec![0.0f32; 64]);
                    if let Some(nl) = cache.insert_prefix(&prompt[..end], &rows_acc, term) {
                        if let Some(old) = lease.take() {
                            cache.release(old);
                        }
                        lease = Some(nl);
                    }
                }
                leases.extend(lease);
            }
            for l in leases {
                cache.release(l);
            }
            std::hint::black_box(&kv);
        });
        add(
            "chunked admit (8 prompts, warm 48-tok template)",
            s.clone(),
            format!(
                "{:.1} us/prompt; prefill_tokens_saved {tokens_saved}/{} ({chunk_calls} chunks)",
                s.mean_secs() * 1e6 / n_prompts as f64,
                n_prompts * lp
            ),
        );
        rec.push("chunked_admit_us_per_prompt", s.mean_secs() * 1e6 / n_prompts as f64, "us/prompt", s.n);
    }

    // Dispatch-policy comparison: group-pinned round-robin (per-engine
    // caches, PR-2) vs prompt-affinity routing + cross-engine shared store.
    // 12 groups over 3 templates on 4 engines: round-robin scatters every
    // template cold onto every engine; affinity keeps templates warm and
    // spilled groups import them from the store. Reports the cache-side
    // admission cost and, per policy, `prefill_tokens_saved` and the
    // cross-engine import count — affinity+store must save strictly more.
    {
        use pa_rl::coordinator::route;
        use pa_rl::engine::chunked::{plan_chunks, resume_point};
        use pa_rl::engine::kvcache::{EvictPolicy, KvGeometry, PrefixCache, PrefixCacheCfg};
        use pa_rl::store::{SharedKvStore, StoreCfg};

        let geom = KvGeometry { n_layers: 4, n_slots: 8, cache_len: 96, kv_heads: 2, head_dim: 16 };
        let re = geom.row_elems();
        let (n_engines, bt, tpl, lp, g) = (4usize, 16usize, 48usize, 64usize, 4usize);
        let (n_templates, n_groups) = (3usize, 12usize);
        let prompts: Vec<Vec<u32>> = (0..n_groups as u32)
            .map(|gi| {
                let t = gi % n_templates as u32;
                (0..lp as u32)
                    .map(|i| if (i as usize) < tpl { 2 + (t * 53 + i * 7) % 40 } else { 50 + gi * 17 + i })
                    .collect()
            })
            .collect();
        let fill_rows = |len: usize| vec![0.5f32; len * re];

        // The engine's cache-side admission flow (import -> match -> chunked
        // publication), minus the compiled calls; returns restored tokens.
        let admit = |cache: &mut PrefixCache, store: Option<&SharedKvStore>, prompt: &[u32]| -> (u64, u64) {
            let mut imports = 0u64;
            if let Some(s) = store {
                let local = cache.resident_tokens(prompt);
                if local < prompt.len() {
                    if let Some(f) = s.fetch_longest(prompt, local, 1) {
                        if let Some(l) = cache.insert_prefix(&prompt[..f.len], &f.rows, f.logits.clone()) {
                            cache.release(l);
                            imports = 1;
                        }
                        s.release(f.lease);
                    }
                }
            }
            let m = cache.match_prefix(prompt);
            if m.matched == prompt.len() && m.logits.is_some() {
                if let Some(l) = m.lease {
                    cache.release(l);
                }
                return (prompt.len() as u64, imports);
            }
            let resume = resume_point(m.matched, prompt.len());
            let mut lease = m.lease;
            let mut rows_acc = m.rows[..resume * re].to_vec();
            for c in plan_chunks(prompt.len(), resume, bt) {
                let end = c.start + c.len;
                rows_acc.extend_from_slice(&fill_rows(c.len));
                let term = (end == prompt.len()).then(|| vec![0.0f32; 8]);
                if let Some(nl) = cache.insert_prefix(&prompt[..end], &rows_acc, term) {
                    if let Some(old) = lease.take() {
                        cache.release(old);
                    }
                    lease = Some(nl);
                }
            }
            if let Some(l) = lease {
                cache.release(l);
            }
            if let Some(s) = store {
                s.publish_aligned(prompt, &rows_acc, Some(&[0.0f32; 8]), 1, true);
            }
            (resume as u64, imports)
        };

        let mk_caches = || -> Vec<PrefixCache> {
            (0..n_engines)
                .map(|_| {
                    PrefixCache::new(
                        geom.clone(),
                        PrefixCacheCfg { block_tokens: bt, capacity_blocks: 128, policy: EvictPolicy::Lru },
                    )
                })
                .collect()
        };

        let mut pinned_saved = 0u64;
        let s = bench("dispatch_pinned", 20, 200, || {
            let mut caches = mk_caches();
            pinned_saved = 0;
            for (gi, prompt) in prompts.iter().enumerate() {
                let e = gi % n_engines; // the round-robin group pin
                for _ in 0..g {
                    pinned_saved += admit(&mut caches[e], None, prompt).0;
                }
            }
            std::hint::black_box(&caches);
        });
        add(
            "dispatch: group-pinned round-robin (12 groups x G=4)",
            s.clone(),
            format!("prefill_tokens_saved {pinned_saved}/{}", n_groups * g * lp),
        );

        let mut affinity_saved = 0u64;
        let mut cross_imports = 0u64;
        let mut spills = 0u64;
        let s = bench("dispatch_affinity", 20, 200, || {
            let mut caches = mk_caches();
            let store = SharedKvStore::new(StoreCfg {
                block_tokens: bt,
                capacity_blocks: 256,
                policy: EvictPolicy::Lru,
                shards: 1,
            });
            store.set_version(1);
            affinity_saved = 0;
            cross_imports = 0;
            spills = 0;
            let mut load = vec![0usize; n_engines];
            for prompt in &prompts {
                let (e, preferred) = route::route_group(prompt, bt, &load, 2 * g);
                if !preferred {
                    spills += 1;
                }
                load[e] += g;
                for _ in 0..g {
                    let (saved, imp) = admit(&mut caches[e], Some(&store), prompt);
                    affinity_saved += saved;
                    cross_imports += imp;
                }
            }
            std::hint::black_box(&caches);
        });
        add(
            "dispatch: affinity + cross-engine store (same workload)",
            s.clone(),
            format!(
                "prefill_tokens_saved {affinity_saved}/{} ({cross_imports} cross-engine imports, {spills} spills)",
                n_groups * g * lp
            ),
        );
        assert!(
            affinity_saved > pinned_saved,
            "affinity+store must beat pinned dispatch: {affinity_saved} vs {pinned_saved}"
        );
        assert!(cross_imports > 0, "spilled groups must import from the store");
    }

    // one simulator iteration (bench-harness cost)
    let sim = pa_rl::sim::SimSetup {
        cluster: pa_rl::sim::ClusterSpec::npu(16),
        model: pa_rl::sim::ModelSpec::qwen(8.0),
        workload: pa_rl::sim::WorkloadSpec::deepscaler(32, 16384),
        eff: pa_rl::sim::EfficiencySpec::ours(),
        framework: pa_rl::sim::Framework::PeriodicAsync,
        infer_fraction: 0.75,
        infer_tp: 2,
        spa: false,
        prefix_cache: false,
        template_frac: 0.0,
        cross_engine: false,
        store_shards: 1,
        elastic_warmup_frac: 0.0,
        train_micro_bs: 1,
        micro_launch_s: 0.5,
        iters: 1,
        seed: 1,
    };
    let s = bench("sim_iter", 5, 50, || {
        std::hint::black_box(sim.run());
    });
    rec.push("sim_iteration_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/iteration", s.n);
    add("simulator iteration (1024 rollouts)", s, String::new());
    // Analytic pipeline efficiency for the same setup: useful device-seconds
    // (inference busy + trainer busy) over deployed device-seconds — the
    // simulator's view of the gauge the driver now measures per iteration
    // (IterReport.phases.pipeline_efficiency). Unit "ratio": higher is
    // better in the bench-diff gate.
    {
        let r = sim.run();
        let d = sim.cluster.n_devices as f64;
        let d_inf = (d * r.infer_fraction).round().max(1.0);
        let useful = d_inf * r.t_infer_mean + (d - d_inf) * r.t_train_mean;
        let eff = (useful / (d * r.wall_seconds)).clamp(0.0, 1.0);
        rec.push("pipeline_efficiency", eff, "ratio", 0);
        println!(
            "  pipeline efficiency (analytic, same sim): {:.1}% ({d_inf:.0} infer + {:.0} train devices)",
            eff * 100.0,
            d - d_inf
        );
    }

    // Store contention: 8 worker threads hammer publish+fetch on one shared
    // store. With shards=1 every operation serializes on a single mutex
    // (the PR-3 topology); shards=8 range-partitions unrelated templates
    // across independent locks. Fixed total work per configuration, best of
    // 3 runs; the sharded store must sustain strictly higher throughput.
    // Also asserts the heap-eviction acceptance bound: candidate probes per
    // eviction stay O(1) instead of scaling with the resident entry count.
    {
        use pa_rl::engine::kvcache::EvictPolicy;
        use pa_rl::store::{SharedKvStore, StoreCfg, StoreStats};
        use std::sync::Arc;

        let quick = pa_rl::util::bench::quick_mode();
        let (n_threads, ops, bt, re) = (8usize, if quick { 60 } else { 300 }, 16usize, 256usize);
        let run_once = |shards: usize| -> (f64, StoreStats) {
            let store = Arc::new(SharedKvStore::new(StoreCfg {
                block_tokens: bt,
                capacity_blocks: 384,
                policy: EvictPolicy::Lru,
                shards,
            }));
            store.set_version(1);
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for th in 0..n_threads {
                let store = store.clone();
                handles.push(pa_rl::check::thread::spawn(move || {
                    // Deterministic per-thread workload: built from (th, i)
                    // only, so both topologies do byte-identical work.
                    for i in 0..ops {
                        // Per-thread template family: distinct heads spread
                        // over the hash ranges; suffixes vary per op. 32
                        // templates x 8 threads x 3 blocks ≈ 2x capacity,
                        // so the workload continuously evicts.
                        let t = (th * 64 + i % 32) as u32;
                        let mut p: Vec<u32> = (0..48u32).map(|j| t * 131 + j).collect();
                        p.extend((0..(i % 5) as u32).map(|q| 7000 + q));
                        let rows = vec![0.5f32; p.len() * re];
                        if i % 2 == 0 {
                            store.publish_aligned(&p, &rows, None, 1, true);
                        } else if let Some(f) = store.fetch_longest(&p, 0, 1) {
                            store.release(f.lease);
                        }
                    }
                }));
            }
            for h in handles {
                h.join().expect("contention worker panicked");
            }
            (t0.elapsed().as_secs_f64(), store.stats())
        };
        // Best-of-3 wall clock per topology smooths scheduler noise (one
        // run in quick mode — the smoke only exercises the harness).
        let best = |shards: usize| -> (f64, StoreStats) {
            (0..if quick { 1 } else { 3 })
                .map(|_| run_once(shards))
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap()
        };
        let (wall1, stats1) = best(1);
        let (wall8, stats8) = best(8);
        let total_ops = (n_threads * ops) as f64;
        let (tput1, tput8) = (total_ops / wall1, total_ops / wall8);
        rec.push("store_contention_ops_per_s_s1", tput1, "ops/s", 3);
        rec.push("store_contention_ops_per_s_s8", tput8, "ops/s", 3);
        t.row(&[
            "store contention: publish+fetch, 8 threads".to_string(),
            format!("{:.2} ms (S=1)", wall1 * 1e3),
            format!("{:.2} ms (S=8)", wall8 * 1e3),
            format!("{:.0} vs {:.0} ops/s ({:.2}x)", tput1, tput8, tput8 / tput1),
        ]);
        // Timing-sensitive gate: skipped in quick mode, where the shrunken
        // workload is too small for the shard advantage to rise above noise.
        assert!(
            quick || tput8 > tput1,
            "sharded store must out-run the single mutex at 8 threads: {tput8:.0} vs {tput1:.0} ops/s"
        );
        assert!(
            stats1.evictions > 0 && stats8.evictions > 0,
            "contention workload must actually churn the store"
        );

        // Eviction scaling: publish 4x capacity of distinct chains through
        // stores of growing size and compare heap probes per eviction. The
        // old O(n) scan examined every resident entry per eviction (cost
        // rising linearly with capacity); the lazily-invalidated heap must
        // stay flat — probes bounded by pushes, amortised O(1) per eviction
        // at every size.
        let probes_per_eviction = |cap: usize| -> (f64, u64) {
            let store = SharedKvStore::new(StoreCfg {
                block_tokens: 2,
                capacity_blocks: cap,
                policy: EvictPolicy::Lru,
                shards: 1,
            });
            store.set_version(1);
            for i in 0..(cap as u32 * 4) {
                let p = [i * 2, i * 2 + 1];
                store.publish(&p, &[0.25f32; 2 * 4], None, 1);
            }
            let s = store.stats();
            assert!(s.evictions as usize > cap, "scaling workload must churn");
            (s.evict_probes as f64 / s.evictions as f64, s.evictions)
        };
        let (small, _) = probes_per_eviction(256);
        let (large, large_evictions) = probes_per_eviction(4096);
        t.row(&[
            "store eviction cost (heap probes / eviction)".to_string(),
            format!("{small:.2} @ cap 256"),
            format!("{large:.2} @ cap 4096"),
            format!("{large_evictions} evictions"),
        ]);
        assert!(
            large < 4.0,
            "heap eviction examined {large:.2} candidates/eviction at 4096 entries — the O(n) scan would examine ~4096"
        );
        assert!(
            large < small * 4.0 + 1.0,
            "eviction cost grew with entry count ({small:.2} -> {large:.2} probes/eviction): heap path not amortising"
        );
    }

    // Full-telemetry overhead: the entire per-request cost of
    // `metrics.level = "full"` is six clock stamps plus one RequestMetrics
    // fold. Measured against the cheapest compiled-free work any request
    // already pays — a single 32k-vocab sampler call (every request samples
    // at least one token, and real requests pay far more: admission, one
    // sampler call per decoded token, scoring). Telemetry under 3% of that
    // floor is under 3% of any real request — the acceptance bound.
    {
        use pa_rl::metrics::{Clock, RequestMetrics, RequestTimeline};
        let clock = Clock::new();
        let mut rm = RequestMetrics::default();
        let s = bench("telemetry", 200, 5000, || {
            let tl = RequestTimeline {
                enqueue_s: clock.now(),
                dispatch_s: clock.now(),
                admit_s: clock.now(),
                first_token_s: clock.now(),
                finish_s: clock.now(),
                consume_s: clock.now(),
                decode_tokens: 32,
                ..Default::default()
            };
            rm.observe(std::hint::black_box(&tl), 1);
        });
        std::hint::black_box(rm.completed);
        let per_req_us = s.p50.as_secs_f64() * 1e6;
        let floor_us = sampler.p50.as_secs_f64() * 1e6;
        let pct = 100.0 * per_req_us / floor_us;
        add(
            "full-telemetry request cost (6 stamps + histogram fold)",
            s.clone(),
            format!("{pct:.2}% of one sampler call"),
        );
        rec.push("telemetry_per_request_p50_us", per_req_us, "us/request", s.n);
        rec.push("telemetry_overhead_pct_of_sampler_call", pct, "%", s.n);
        assert!(
            pct < 3.0,
            "full telemetry costs {pct:.2}% of a single sampler call — the <3% overhead bound regressed"
        );
    }

    t.print();
    let path = rec.write().expect("write BENCH_micro.json at the repo root");
    println!("bench record ({} metrics) written to {}", rec.len(), path.display());
}
