//! Paper Figure 5 — reward trajectories across training steps for the
//! synchronous and asynchronous variants (plus the staleness ablation),
//! demonstrating comparable training effectiveness.
//!
//! Runs the real mini-cluster on `artifacts/tiny` with a shared seed; the
//! full-scale curves live in EXPERIMENTS.md (train_grpo runs on the small
//! config). Skips gracefully without artifacts.

use pa_rl::config::Config;
use pa_rl::coordinator::{Driver, DriverOpts, Mode};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let tiny = Path::new("artifacts/tiny");
    if !tiny.join("manifest.json").exists() {
        println!("SKIP fig5: artifacts/tiny missing — run `make artifacts`");
        return Ok(());
    }
    let cfg = Config::load(Path::new("configs/tiny.json"))?;
    let iters = 6u64;

    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    for (name, mode) in [
        ("sync", Mode::Sync),
        ("async", Mode::Async),
        ("stale(eta=1)", Mode::StaleAsync { max_staleness: 1 }),
    ] {
        let opts = DriverOpts { mode, spa: false, seed: 77 };
        let mut driver = Driver::new(cfg.clone(), tiny, opts)?;
        let report = driver.run(iters)?;
        curves.push((name, report.iters.iter().map(|i| i.reward_mean).collect()));
    }

    println!("== Fig. 5 — mean reward per iteration (tiny model, random init) ==");
    print!("{:>14}", "iter");
    for t in 0..iters {
        print!("{t:>8}");
    }
    println!();
    for (name, curve) in &curves {
        print!("{name:>14}");
        for v in curve {
            print!("{v:>8.3}");
        }
        println!();
    }
    println!(
        "\nnote: rewards are near-zero from random init (the tiny model can't answer yet);\n\
         the signal here is that sync and async trajectories track each other step-for-step.\n\
         EXPERIMENTS.md records the SFT-warm-started small-model curves where reward climbs."
    );

    // Step-wise closeness of sync vs async (the paper's overlap claim).
    let sync = &curves[0].1;
    let asyn = &curves[1].1;
    let max_gap = sync
        .iter()
        .zip(asyn)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |sync - async| per-step reward gap: {max_gap:.4}");
    Ok(())
}
