//! Paper Table 2 — DeepSeek-R1-Distill-Qwen-32B on DeepScaleR.
//! Group 1: ours on 48 NPUs vs MindSpeed-RL on 64 (resource economy);
//! Group 2: 64 NPUs at 8K context (the largest VERL could fit).

use pa_rl::sim::experiments::{render_rows, table2};

fn main() {
    let (g1, g2) = table2(4);
    println!("{}", render_rows("Table 2 group 1 — 32B, 16K ctx, GBS 32", &g1));
    println!("{}", render_rows("Table 2 group 2 — 32B, 8K ctx, GBS 64, 64 NPUs", &g2));

    let checks = [
        (
            "async(48 NPU) beats MindSpeed(64 NPU) by a large factor (paper: 5.05x)",
            g1[2].sim.tpspd / g1[0].sim.tpspd > 2.5,
        ),
        ("async > sync at 48 NPUs (paper: 1.28x)", g1[2].sim.tpspd > g1[1].sim.tpspd),
        ("async beats VERL at 8K (paper: 1.76x)", g2[2].sim.tpspd > g2[0].sim.tpspd),
        ("async > sync at 64 NPUs (paper: 1.66x)", g2[2].sim.tpspd > g2[1].sim.tpspd),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
