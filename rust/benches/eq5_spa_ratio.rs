//! Paper Eq. 5 — the Shared-Prompt Attention complexity-reduction ratio
//!
//!   rho = (Lp^2 + K*Lr*(Lp + Lr)) / (K * (Lp + Lr)^2)
//!
//! Validated two ways:
//! 1. analytically (the closed form, sweeping K and Lp/Lr — showing the
//!    rho -> 1/K limit for Lp >> Lr);
//! 2. *measured* from the actual packed batches: attention-pair counts under
//!    the SPA mask vs the standard per-sample causal mask, built by the same
//!    rust packers the trainer uses.

use pa_rl::grpo::{build_spa, build_standard, spa_ratio, Sample};
use pa_rl::util::bench::Table;

/// Count allowed attention pairs in a packed batch under the SPA mask rules
/// (mirrors python/compile/kernels/ref.py::spa_mask).
fn spa_pairs(batch: &pa_rl::grpo::TrainBatch, prompt_len: i32) -> u64 {
    let s = batch.seq;
    let seg = &batch.seg;
    let pos = &batch.pos;
    let mut count = 0u64;
    for i in 0..s {
        for j in 0..s {
            if seg[i] < 0 {
                continue; // padding contributes no compute
            }
            let causal_same = seg[i] == seg[j] && j <= i;
            let prompt_key = seg[i] >= 1 && seg[j] == 0 && pos[j] < prompt_len - 1;
            if causal_same || prompt_key {
                count += 1;
            }
        }
    }
    count
}

/// Count causal pairs in a standard batch (per-row causal over valid tokens).
fn standard_pairs(batch: &pa_rl::grpo::TrainBatch) -> u64 {
    let s = batch.seq;
    let mut count = 0u64;
    for row in 0..batch.rows {
        let valid = (0..s).filter(|&i| batch.seg[row * s + i] >= 0).count();
        count += (valid as u64 * (valid as u64 + 1)) / 2;
    }
    count
}

fn main() {
    // ---- analytic sweep ---------------------------------------------------
    let mut t = Table::new(
        "Eq. 5 analytic: rho(K, Lp, Lr) and the 1/K limit",
        &["Lp", "Lr", "K", "rho", "1/K", "token saving"],
    );
    for &(lp, lr, k) in &[
        (512usize, 16usize, 16usize),
        (512, 64, 16),
        (512, 512, 16),
        (64, 512, 16),
        (1024, 32, 32),
        (1024, 32, 8),
    ] {
        let rho = spa_ratio(lp, lr, k);
        let tok_saving = (lp + k * lr) as f64 / (k * (lp + lr)) as f64;
        t.row(&[
            format!("{lp}"),
            format!("{lr}"),
            format!("{k}"),
            format!("{rho:.4}"),
            format!("{:.4}", 1.0 / k as f64),
            format!("{tok_saving:.3}"),
        ]);
    }
    t.note("Lp >> Lr drives rho toward 1/K (paper: 'approximately K-fold reduction')");
    t.print();

    // ---- measured from real packed batches --------------------------------
    // Eq. 5 is the paper's *asymptotic* form (it counts full squares, L^2,
    // where exact causal attention computes triangles, L(L+1)/2; the square
    // and triangle conventions cancel only when the cross term L_p·L_r is
    // negligible). We therefore assert the measured pair counts against the
    // exact combinatorial prediction of the packed layout, and report Eq. 5
    // alongside to show where its approximation sits.
    let tri = |n: usize| (n * (n + 1) / 2) as u64;
    let exact_spa = |lp: usize, lr: usize, k: usize| {
        // prompt causal triangle + per segment: (Lp-1) prompt keys per token
        // (the original last prompt token is replaced by the in-segment
        // duplicate) + the segment's own causal triangle
        tri(lp) + (k as u64) * ((lr as u64) * (lp as u64 - 1) + tri(lr))
    };
    let exact_std = |lp: usize, lr: usize, k: usize| (k as u64) * tri(lp + lr);

    let mut t2 = Table::new(
        "Measured attention pairs: SPA pack vs standard layout",
        &["Lp", "Lr", "K", "std pairs", "SPA pairs", "measured", "exact model", "Eq.5 (asymptotic)"],
    );
    let mut exact_ok = true;
    for &(lp, lr, k) in &[(64usize, 8usize, 8usize), (128, 8, 16), (96, 24, 8), (32, 32, 4)] {
        let prompt: Vec<u32> = (0..lp as u32).map(|i| 3 + (i % 20)).collect();
        let responses: Vec<Vec<u32>> = (0..k).map(|_| vec![5u32; lr]).collect();
        let samples: Vec<Sample> = responses
            .iter()
            .map(|r| Sample { prompt: &prompt, response: r, advantage: 0.0 })
            .collect();
        let spa = build_spa(&samples, lp + k * lr + 4).expect("pack fits");
        let std_batch = build_standard(&samples, k, lp + lr);
        let sp = spa_pairs(&spa, lp as i32);
        let st = standard_pairs(&std_batch);
        let measured = sp as f64 / st as f64;
        let exact = exact_spa(lp, lr, k) as f64 / exact_std(lp, lr, k) as f64;
        exact_ok &= sp == exact_spa(lp, lr, k) && st == exact_std(lp, lr, k);
        t2.row(&[
            format!("{lp}"),
            format!("{lr}"),
            format!("{k}"),
            format!("{st}"),
            format!("{sp}"),
            format!("{measured:.4}"),
            format!("{exact:.4}"),
            format!("{:.4}", spa_ratio(lp, lr, k)),
        ]);
    }
    t2.note("'exact model' = combinatorial count of the packed layout; Eq. 5 is its large-L limit");
    t2.print();

    // Asymptotic agreement: at Lp >> Lr the exact ratio converges to Eq. 5.
    let (lp, lr, k) = (4096usize, 16usize, 16usize);
    let exact = exact_spa(lp, lr, k) as f64 / exact_std(lp, lr, k) as f64;
    let asym = spa_ratio(lp, lr, k);
    let rel = (exact - asym).abs() / asym;
    println!("asymptotic check (Lp=4096, Lr=16, K=16): exact {exact:.4} vs Eq.5 {asym:.4} ({:.1}% apart)", rel * 100.0);

    let checks = [
        ("measured pair counts match the exact combinatorial model", exact_ok),
        ("exact ratio converges to Eq. 5 in the long-prompt limit (<12%)", rel < 0.12),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
