//! Paper Table 4 — Qwen2.5-1.5B on GSM8K, 8xA100-40G, data-parallel only:
//! the GPU cross-architecture validation, including the AReaL (fully-async,
//! off-policy) comparison.

use pa_rl::sim::experiments::{render_rows, table4};

fn main() {
    let rows = table4(5);
    println!("{}", render_rows("Table 4 — 1.5B on GSM8K, 8 A100 GPUs", &rows));

    let t = |i: usize| rows[i].sim.tpspd;
    let checks = [
        ("async beats VERL (paper: 3.09x)", t(3) / t(0) > 1.3),
        ("async beats AReaL (paper: 1.41x)", t(3) > t(1)),
        ("AReaL beats VERL (paper: 2.18x)", t(1) > t(0)),
        ("async beats sync (paper: 2.40x)", t(3) > t(2)),
    ];
    println!(
        "  note: AReaL wins throughput over VERL but pays accuracy (paper: 0.681 vs 0.782);\n        the real-run staleness ablation in EXPERIMENTS.md covers the accuracy side."
    );
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
