//! Paper Table 5 + Figure 6 — scalability of the periodic-async framework
//! over 16/32/64 NPUs: per-device TPSPD declines moderately while total
//! throughput scales near-linearly.

use pa_rl::sim::experiments::table5;
use pa_rl::util::bench::{f3, Table};

fn main() {
    let rows = table5(4);
    let mut t = Table::new(
        "Table 5 / Fig. 6 — Qwen3-8B scalability",
        &["NPUs", "Paper TPSPD", "Sim TPSPD", "Paper total tok/s", "Sim total tok/s", "Sim scaling"],
    );
    let mut prev: Option<f64> = None;
    for (n, paper, sim) in &rows {
        let total = sim.tpspd * *n as f64;
        let scaling = prev.map(|p| format!("{:.2}x", total / p)).unwrap_or_else(|| "-".into());
        t.row(&[
            format!("{n}"),
            paper.map(f3).unwrap_or_default(),
            f3(sim.tpspd),
            paper.map(|p| f3(p * *n as f64)).unwrap_or_default(),
            f3(total),
            scaling,
        ]);
        prev = Some(total);
    }
    t.note("paper: 1.83x (16->32) and 1.90x (32->64) total-throughput scaling");
    t.print();

    // Fig. 6 as ASCII bars (total throughput).
    println!("Fig. 6 — total throughput (tokens/s):");
    let max_total = rows.iter().map(|(n, _, s)| s.tpspd * *n as f64).fold(0.0, f64::max);
    for (n, _, sim) in &rows {
        let total = sim.tpspd * *n as f64;
        let bar = "█".repeat(((total / max_total) * 50.0).round() as usize);
        println!("  {n:>3} NPUs |{bar} {total:.0}");
    }

    let totals: Vec<f64> = rows.iter().map(|(n, _, s)| s.tpspd * *n as f64).collect();
    let checks = [
        ("TPSPD declines moderately with scale", rows[0].2.tpspd > rows[2].2.tpspd),
        ("16->32 scaling near-linear (paper 1.83x)", totals[1] / totals[0] > 1.4),
        ("32->64 scaling near-linear (paper 1.90x)", totals[2] / totals[1] > 1.25),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
