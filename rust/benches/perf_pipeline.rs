//! End-to-end pipeline benchmark over the real artifacts: per-stage PJRT
//! costs (prefill, decode chunk, tri-model micro-step std vs SPA, Adam
//! update, weight sync) and one full iteration in each mode. This is the
//! §Perf driver — run before/after optimisation changes.
//!
//! Requires `artifacts/tiny` (skips politely otherwise).

use pa_rl::config::Config;
use pa_rl::coordinator::{Driver, DriverOpts, Mode};
use pa_rl::engine::{Engine, GenRequest};
use pa_rl::grpo::{build_spa, build_standard, Sample};
use pa_rl::runtime::Runtime;
use pa_rl::train::{IterStats, Trainer};
use pa_rl::util::bench::{bench, BenchRecorder, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        println!("SKIP perf_pipeline: artifacts/tiny missing — run `make artifacts`");
        write_analytic_record_if_missing()?;
        return Ok(());
    }
    let mut rec = BenchRecorder::new("pipeline", "benches/perf_pipeline.rs (artifacts/tiny, CPU PJRT)");
    let cfg = Config::load(Path::new("configs/tiny.json"))?;
    let mut t = Table::new(
        "Pipeline stage costs (tiny config, CPU PJRT)",
        &["Stage", "mean (ms)", "p95 (ms)", "notes"],
    );
    let mut add = |name: &str, stats: pa_rl::util::bench::Stats, notes: String| {
        t.row(&[
            name.to_string(),
            format!("{:.2}", stats.mean.as_secs_f64() * 1e3),
            format!("{:.2}", stats.p95.as_secs_f64() * 1e3),
            notes,
        ]);
    };

    // ---- engine stages ----------------------------------------------------
    {
        let rt = Runtime::load_validated(dir, &cfg)?;
        rt.prepare(&["init", "prefill", "decode"])?;
        let params = rt.init_params(1)?;
        let mut engine = Engine::new(cfg.clone(), rt, 1);
        engine.set_weights(&params)?;
        let mut loader = pa_rl::data::DataLoader::new(cfg.data.clone());
        let prompts = loader.next_batch(256);
        let mut i = 0usize;
        // prefill+first-token via single-request generate (isolates admission)
        let s = bench("prefill", 3, 20, || {
            let p = &prompts[i % prompts.len()];
            i += 1;
            engine.submit(GenRequest { request_id: i as u64, prompt: p.tokens.clone(), ..Default::default() });
            engine.step().unwrap(); // one admission + one decode chunk
            while !engine.idle() {
                engine.step().unwrap();
            }
        });
        let toks = engine.stats.tokens_generated;
        rec.push("rollout_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/rollout", s.n);
        add(
            "rollout (prefill + full decode)",
            s,
            format!("{toks} tokens total, {} chunks", engine.stats.decode_chunks),
        );
    }

    // ---- trainer stages ---------------------------------------------------
    {
        let rt = Runtime::load_validated(dir, &cfg)?;
        rt.prepare(&["init", "train_step", "train_step_spa", "adam_update"])?;
        let mut trainer = Trainer::new(cfg.clone(), rt, 2)?;
        let g = cfg.rl.group_size;
        let prompt: Vec<u32> = (0..cfg.engine.prompt_max as u32).map(|i| 3 + i % 20).collect();
        let responses: Vec<Vec<u32>> = (0..g).map(|_| vec![5u32; cfg.engine.max_new / 2]).collect();
        let samples: Vec<Sample> =
            responses.iter().map(|r| Sample { prompt: &prompt, response: r, advantage: 0.3 }).collect();
        let std_batch = build_standard(&samples[..cfg.train.micro_bs], cfg.train.micro_bs, cfg.train.seq_len);
        let spa_batch = build_spa(&samples, cfg.train.spa.pack_len).expect("fits");

        trainer.begin_iteration()?;
        let mut stats = IterStats::default();
        let s = bench("micro_std", 3, 15, || {
            trainer.train_micro(&std_batch, false, 0, &mut stats).unwrap();
        });
        rec.push("micro_std_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/micro-step", s.n);
        add(
            &format!("tri-model micro (std, {} rows x {})", std_batch.rows, std_batch.seq),
            s,
            format!("{} input tokens", std_batch.n_input_tokens),
        );
        let s = bench("micro_spa", 3, 15, || {
            trainer.train_micro(&spa_batch, true, prompt.len(), &mut stats).unwrap();
        });
        rec.push("micro_spa_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/micro-step", s.n);
        add(
            &format!("tri-model micro (SPA, 1 x {})", spa_batch.seq),
            s,
            format!("{} input tokens (G={g} packed)", spa_batch.n_input_tokens),
        );
        let s = bench("adam", 1, 8, || {
            trainer.end_iteration(&mut IterStats::default()).unwrap();
            trainer.begin_iteration().unwrap();
        });
        rec.push("adam_update_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/update", s.n);
        add("adam update + re-upload tri-model", s, format!("{} params", cfg.model.param_count()));
        trainer.end_iteration(&mut IterStats::default())?;
    }

    // ---- weight sync -------------------------------------------------------
    {
        let rt = Runtime::load_validated(dir, &cfg)?;
        rt.prepare(&["init", "prefill", "decode"])?;
        let params = rt.init_params(3)?;
        let mut engine = Engine::new(cfg.clone(), rt, 3);
        let s = bench("sync", 2, 20, || {
            engine.set_weights(&params).unwrap();
        });
        rec.push("weight_sync_p50_ms", s.p50.as_secs_f64() * 1e3, "ms/engine", s.n);
        add("weight sync (1 engine upload)", s, format!("{:.2} MB", params.bytes() as f64 / 1e6));
    }
    t.print();

    // ---- full iterations in each mode ---------------------------------------
    let mut t2 = Table::new(
        "Full-iteration wall clock by mode (2 iterations each)",
        &["Mode", "wall (s)", "TPSPD", "consumer wait (s)", "efficiency"],
    );
    for (name, slug, mode, spa) in [
        ("sync", "sync", Mode::Sync, false),
        ("async", "async", Mode::Async, false),
        ("async + SPA", "async_spa", Mode::Async, true),
        ("stale eta=1", "stale1", Mode::StaleAsync { max_staleness: 1 }, false),
    ] {
        let opts = DriverOpts { mode, spa, seed: 9 };
        let mut driver = Driver::new(cfg.clone(), dir, opts)?;
        let report = driver.run(2)?;
        // Phase attribution is computed at every metrics level, so the
        // measured pipeline efficiency rides the perf trajectory too.
        let eff = report.iters.iter().map(|i| i.phases.pipeline_efficiency).sum::<f64>()
            / report.iters.len().max(1) as f64;
        rec.push(&format!("{slug}_wall_s"), report.wall_seconds, "s/2 iterations", 2);
        rec.push(&format!("{slug}_tpspd"), report.tpspd(), "tokens/s/device", 2);
        rec.push(&format!("{slug}_pipeline_efficiency"), eff, "ratio", 2);
        t2.row(&[
            name.to_string(),
            format!("{:.2}", report.wall_seconds),
            format!("{:.1}", report.tpspd()),
            format!("{:.2}", report.iters.iter().map(|i| i.consumer_wait_seconds).sum::<f64>()),
            format!("{:.1}%", eff * 100.0),
        ]);
    }
    t2.print();
    let path = rec.write()?;
    println!("bench record ({} metrics) written to {}", rec.len(), path.display());
    Ok(())
}

/// With no artifacts to measure, leave an analytically-sourced
/// `BENCH_pipeline.json` behind for the perf-trajectory convention — but
/// only when no record exists yet: a committed, *measured* record must
/// never be clobbered by the skip path.
fn write_analytic_record_if_missing() -> anyhow::Result<()> {
    use pa_rl::sim::{
        ClusterSpec, EfficiencySpec, Framework, ModelSpec, SimResult, SimSetup, WorkloadSpec,
    };
    let mut rec = BenchRecorder::new("pipeline", "simulator (analytic; artifacts/tiny missing)");
    if rec.path().exists() {
        println!("(BENCH_pipeline.json already present — leaving it untouched)");
        return Ok(());
    }
    let run = |framework: Framework| {
        SimSetup {
            cluster: ClusterSpec::npu(16),
            model: ModelSpec::qwen(8.0),
            workload: WorkloadSpec::deepscaler(32, 16384),
            eff: EfficiencySpec::ours(),
            framework,
            infer_fraction: 0.8,
            infer_tp: 2,
            spa: false,
            prefix_cache: false,
            template_frac: 0.0,
            cross_engine: false,
            store_shards: 1,
            elastic_warmup_frac: 0.0,
            train_micro_bs: 16,
            micro_launch_s: 0.5,
            iters: 5,
            seed: 7,
        }
        .run_tuned()
    };
    let sync = run(Framework::DecoupledSync);
    let asyn = run(Framework::PeriodicAsync);
    rec.push("sim_sync_wall_s", sync.wall_seconds, "s/5 iterations", 0);
    rec.push("sim_async_wall_s", asyn.wall_seconds, "s/5 iterations", 0);
    rec.push("sim_sync_tpspd", sync.tpspd, "tokens/s/device", 0);
    rec.push("sim_async_tpspd", asyn.tpspd, "tokens/s/device", 0);
    rec.push("sim_async_speedup_x", asyn.tpspd / sync.tpspd, "x", 0);
    rec.push("sim_async_consumer_idle_s", asyn.consumer_idle_mean, "s/iteration", 0);
    // Analytic pipeline efficiency (useful / deployed device-seconds), the
    // simulator counterpart of IterReport.phases.pipeline_efficiency. The
    // setup above runs 5 iterations, so wall_seconds / 5 is one iteration.
    let eff = |r: &SimResult| -> f64 {
        let d = 16.0; // ClusterSpec::npu(16) above
        let d_inf = (d * r.infer_fraction).round().max(1.0);
        let useful = d_inf * r.t_infer_mean + (d - d_inf) * r.t_train_mean;
        (useful / (d * (r.wall_seconds / 5.0))).clamp(0.0, 1.0)
    };
    rec.push("sim_sync_pipeline_efficiency", eff(&sync), "ratio", 0);
    rec.push("sim_async_pipeline_efficiency", eff(&asyn), "ratio", 0);
    let path = rec.write()?;
    println!("analytic bench record written to {}", path.display());
    Ok(())
}
