//! Paper Table 1 — Qwen3-8B on DeepScaleR, 16 NPUs, batch 32, G=32, 16K ctx.
//! Regenerates the four-way framework comparison at paper scale via the
//! cluster simulator and checks the paper's win-factor shape.

use pa_rl::sim::experiments::{render_rows, table1};

fn main() {
    let rows = table1(5);
    println!("{}", render_rows("Table 1 — 8B model on DeepScaleR (16 NPUs, 16K context)", &rows));

    let t = |i: usize| rows[i].sim.tpspd;
    let checks = [
        ("async >= VERL (paper: 1.24x)", t(3) >= t(1) * 0.95),
        ("VERL > sync ours (paper: 1.56x)", t(1) > t(2)),
        ("sync ours > MindSpeed (paper: 1.62x)", t(2) > t(0)),
        ("async/sync approaches 2x (paper: 1.92x)", (1.3..=2.1).contains(&(t(3) / t(2)))),
        ("async/MindSpeed large (paper: 3.12x)", t(3) / t(0) > 2.0),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
