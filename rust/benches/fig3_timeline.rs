//! Paper Figure 3 — wall-clock execution timelines, synchronous vs
//! periodically asynchronous.
//!
//! Two reproductions:
//! 1. simulator-traced at paper scale (always runs);
//! 2. the real mini-cluster (when `artifacts/tiny` exists): one iteration in
//!    each mode, rendering the actual thread spans.
//!
//! Emits machine-readable traces to `target/bench-out/fig3_*.json`.

use pa_rl::config::Config;
use pa_rl::coordinator::{Driver, DriverOpts, Mode};
use pa_rl::metrics::{RequestMetrics, Trace};
use pa_rl::sim::{ClusterSpec, EfficiencySpec, Framework, ModelSpec, SimSetup, WorkloadSpec};
use std::path::Path;

fn sim_setup(framework: Framework) -> SimSetup {
    SimSetup {
        cluster: ClusterSpec::npu(16),
        model: ModelSpec::qwen(8.0),
        workload: WorkloadSpec::deepscaler(8, 16384),
        eff: EfficiencySpec::ours(),
        framework,
        infer_fraction: 0.75,
        infer_tp: 2,
        spa: false,
        prefix_cache: false,
        template_frac: 0.0,
        cross_engine: false,
        store_shards: 1,
        elastic_warmup_frac: 0.0,
        train_micro_bs: 1,
        micro_launch_s: 0.5,
        iters: 1,
        seed: 3,
    }
}

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("target/bench-out")?;

    println!("== Fig. 3 (simulated, paper scale: 8B / 16 NPUs / one iteration) ==\n");
    for (name, fw) in [("synchronous", Framework::DecoupledSync), ("async", Framework::PeriodicAsync)] {
        let trace = Trace::new();
        let mut requests = RequestMetrics::default();
        let result = sim_setup(fw).run_traced_metrics(Some(&trace), Some(&mut requests));
        println!(
            "[{name}] iteration wall {:.0}s  (T_inf {:.0}s, T_train {:.0}s, consumer idle {:.0}s)",
            result.wall_seconds, result.t_infer_mean, result.t_train_mean, result.consumer_idle_mean
        );
        println!("[{name}] requests: {}", requests.summary());
        // Same "requests" lane annotations the real driver attaches in full
        // telemetry mode, so sim and real fig3 JSON share one schema.
        trace.annotate("requests", "ttft_p50", requests.ttft.quantile(0.50));
        trace.annotate("requests", "ttft_p99", requests.ttft.quantile(0.99));
        trace.annotate("requests", "queue_p99", requests.queue_wait.quantile(0.99));
        trace.annotate("requests", "stale_p99", requests.staleness.quantile(0.99));
        println!("{}", trace.render_ascii(100));
        std::fs::write(
            format!("target/bench-out/fig3_sim_{name}.json"),
            trace.to_json().to_pretty(),
        )?;
        // Perfetto-loadable twin of the same span tree — CI schema-checks
        // this file with `pa-report trace` (no artifacts needed).
        std::fs::write(
            format!("target/bench-out/fig3_sim_{name}.trace.json"),
            trace.to_chrome_json().to_pretty(),
        )?;
    }

    let tiny = Path::new("artifacts/tiny");
    if tiny.join("manifest.json").exists() {
        println!("== Fig. 3 (real mini-cluster, artifacts/tiny) ==\n");
        let cfg = Config::load(Path::new("configs/tiny.json"))?;
        for (name, mode) in [("synchronous", Mode::Sync), ("async", Mode::Async)] {
            let opts = DriverOpts { mode, spa: false, seed: 5 };
            let mut driver = Driver::new(cfg.clone(), tiny, opts)?;
            let report = driver.run(2)?;
            let kv_hit = report.iters.iter().map(|i| i.kv_hit_rate).sum::<f64>()
                / report.iters.len().max(1) as f64;
            println!(
                "[{name}] wall {:.2}s, consumer wait {:.2}s, kv-hit {:.0}%, prefill tokens saved {}",
                report.wall_seconds,
                report.iters.iter().map(|i| i.consumer_wait_seconds).sum::<f64>(),
                kv_hit * 100.0,
                report.iters.iter().map(|i| i.prefill_tokens_saved).sum::<u64>()
            );
            // Phase attribution (computed at every metrics level): surface
            // the run means on the driver lane so the fig3 JSON carries the
            // bubble breakdown alongside the spans.
            let n = report.iters.len().max(1) as f64;
            let mean = |f: fn(&pa_rl::coordinator::IterReport) -> f64| {
                report.iters.iter().map(f).sum::<f64>() / n
            };
            let eff = mean(|i| i.phases.pipeline_efficiency);
            println!(
                "[{name}] phases: idle {:.2}s  wait {:.2}s  sync {:.2}s  efficiency {:.1}%",
                mean(|i| i.phases.producer_idle_s),
                mean(|i| i.phases.consumer_wait_s),
                mean(|i| i.phases.sync_overhead_s),
                eff * 100.0,
            );
            report.trace.annotate("driver", "pipeline_efficiency", eff);
            report.trace.annotate("driver", "producer_idle_s", mean(|i| i.phases.producer_idle_s));
            println!("{}", report.trace.render_ascii(100));
            std::fs::write(
                format!("target/bench-out/fig3_real_{name}.json"),
                report.trace.to_json().to_pretty(),
            )?;
            std::fs::write(
                format!("target/bench-out/fig3_real_{name}.trace.json"),
                report.trace.to_chrome_json().to_pretty(),
            )?;
        }
    } else {
        println!("(skipping real-cluster trace: run `make artifacts` first)");
    }
    println!("traces written to target/bench-out/fig3_*.json");
    Ok(())
}
