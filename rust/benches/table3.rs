//! Paper Table 3 — Qwen2.5-7B on GSM8K at 1K context: the training-dominated
//! regime. The clean two-factor ablation: Shared-Prompt Attention (trainer
//! cost) x periodic asynchrony (overlap).

use pa_rl::sim::experiments::{render_rows, table3};

fn main() {
    let rows = table3(5);
    println!("{}", render_rows("Table 3 — 7B on GSM8K, 16 NPUs, 1K context (SPA ablation)", &rows));

    let by = |label: &str| rows.iter().find(|r| r.setting.contains(label)).unwrap().sim.tpspd;
    let spa_win = by("Async ours, w/ SPA") / by("Async ours, w/o SPA");
    let async_win = by("Async ours, w/ SPA") / by("Sync ours, w/ SPA");
    println!("  SPA effect (paper: 8.35x): {spa_win:.2}x");
    println!("  async effect under SPA (paper: 2.00x): {async_win:.2}x");

    let checks = [
        ("async w/ SPA is fastest overall", rows.iter().all(|r| by("Async ours, w/ SPA") >= r.sim.tpspd)),
        ("sync w/ SPA alone beats VERL (paper: 1.31x)", by("Sync ours, w/ SPA") > by("VERL")),
        ("SPA effect is multiplicative and large (>3x)", spa_win > 3.0),
        ("async effect approaches 2x", (1.2..=2.1).contains(&async_win)),
        ("micro-bs-1 w/o SPA collapses (paper: 52 TPSPD)", by("Async ours, w/o SPA") < by("MindSpeed-RL")),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
