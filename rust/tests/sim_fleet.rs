//! Fleet-scale property suite over the simulated coordinator
//! (`pa_rl::sim::fleet`): seeded random join/drain/straggler schedules
//! driven through the *real* control-loop code (`coordinator::ctrl`) on the
//! deterministic executor, asserting the paper's machine-checkable
//! invariants — no job lost or duplicated, drains always terminate, and
//! Sync-mode staleness stays 0 (Prop. 1).
//!
//! On failure the offending schedule string is appended to
//! `target/tmp/sim-fleet/failing_schedules.txt` (uploaded as a CI artifact)
//! and printed with replay instructions: paste it into
//! `pa_rl::sim::fleet::replay(...)` to reproduce the identical event trace.
//!
//! `PA_RL_SIM_FLEET_QUICK=1` clamps case counts for CI wall-clock, same
//! convention as `PA_RL_BENCH_QUICK`.

use std::io::Write as _;
use std::path::PathBuf;

use pa_rl::sim::fleet::{self, FleetOp, FleetScript, SimFleetCfg, SimFleetReport};

fn quick() -> bool {
    std::env::var("PA_RL_SIM_FLEET_QUICK").is_ok()
}

/// Persist the failing schedule for the CI artifact sweep, then panic with
/// the schedule and replay instructions front and center.
fn fail_with_schedule(schedule: &str, why: &str) -> ! {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("sim-fleet");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("failing_schedules.txt");
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = writeln!(f, "{schedule}\t# {why}");
    }
    panic!(
        "sim-fleet invariant violated: {why}\n\
         schedule: {schedule}\n\
         replay:   pa_rl::sim::fleet::replay(\"{schedule}\")\n\
         (also appended to {})",
        path.display()
    );
}

/// Run one schedule and enforce the property-test invariants. The harness
/// itself already fails a run that loses jobs, duplicates a request id,
/// wedges a drain or leaves a group incomplete; this layer turns either
/// kind of failure into a replayable artifact.
fn check_invariants(script: &FleetScript) -> SimFleetReport {
    let schedule = script.to_string();
    match fleet::run(script) {
        Ok(r) => {
            if r.consumed != r.minted {
                fail_with_schedule(
                    &schedule,
                    &format!("job conservation: minted {} consumed {}", r.minted, r.consumed),
                );
            }
            if r.max_staleness != 0 {
                fail_with_schedule(
                    &schedule,
                    &format!("Sync-mode staleness reached {}", r.max_staleness),
                );
            }
            r
        }
        Err(e) => fail_with_schedule(&schedule, &format!("{e:#}")),
    }
}

/// Random schedules across fleet shapes, TTLs and queue bounds. Seeds are
/// fixed: the suite explores the same schedule corpus on every run.
#[test]
fn random_schedules_preserve_jobs_and_stay_on_policy() {
    let cases: u64 = if quick() { 6 } else { 24 };
    for case in 0..cases {
        let cfg = SimFleetCfg {
            engines: 3 + (case as usize % 5) * 4,
            iters: 3,
            batch_prompts: 8,
            group_size: 2,
            templates: 4,
            seed: 1_000 + case,
            warmth_ttl: case % 3,
            // Odd cases run with a tiny queue bound so senders park and the
            // drain pump's backpressure path is exercised, not just covered.
            queue_cap: if case % 2 == 0 { 64 } else { 2 },
            ..Default::default()
        };
        let script = FleetScript::random(cfg, 7_700 + case);
        check_invariants(&script);
    }
}

/// The acceptance-criteria case: a 1000-engine fleet under joins, drains
/// and stragglers, run twice — same seed must give the same event trace,
/// poll count and virtual-time span, verbatim.
#[test]
fn thousand_engine_fleet_runs_deterministically() {
    let cfg = SimFleetCfg {
        engines: 1000,
        iters: if quick() { 2 } else { 3 },
        batch_prompts: 250,
        group_size: 2,
        templates: 32,
        seed: 4_242,
        ..Default::default()
    };
    let mut script = FleetScript::random(cfg, 99);
    // Guarantee the interesting events regardless of what the random
    // schedule drew: a mid-batch tail drain, a joiner, a hard straggler.
    script.ops.push(FleetOp::Drain { iter: 0 });
    script.ops.push(FleetOp::Join { iter: 1 });
    script.ops.push(FleetOp::Straggle { iter: 0, engine: 500, factor: 16.0 });

    let a = check_invariants(&script);
    let b = check_invariants(&script);
    assert_eq!(a.trace, b.trace, "same seed must produce the same event trace");
    assert_eq!(a.polls, b.polls, "same seed must produce the same executor poll count");
    assert_eq!(a.virtual_s, b.virtual_s, "same seed must produce the same virtual-time span");
    // 250 prompts × 2 rollouts × ≥2 iterations, even under the quick clamp.
    assert!(a.minted >= 1000, "the big fleet actually dispatched work");
}

/// The reported schedule string replays to the identical trace — the
/// debugging loop a failing property case depends on.
#[test]
fn reported_schedule_replays_to_identical_trace() {
    let script = FleetScript::random(
        SimFleetCfg { engines: 8, iters: 3, seed: 5, ..Default::default() },
        123,
    );
    let a = fleet::run(&script).expect("schedule runs");
    let b = fleet::replay(&a.schedule).expect("reported schedule replays");
    assert_eq!(a.trace, b.trace);
    assert_eq!(a.polls, b.polls);
}

/// Satellite-2 regression, fleet-level: an engine that crashes instead of
/// acking its drain must surface `pump_drain_ack`'s liveness error — with
/// the schedule attached — not hang the control loop.
#[test]
fn engine_death_mid_drain_surfaces_an_error_not_a_hang() {
    let script = FleetScript {
        cfg: SimFleetCfg { engines: 3, iters: 2, seed: 13, ..Default::default() },
        ops: vec![FleetOp::KillOnDrain { iter: 0, engine: 2 }, FleetOp::Drain { iter: 0 }],
    };
    let err = fleet::run(&script).expect_err("killed drain must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("exited without acking the drain"), "got: {msg}");
    assert!(msg.contains("simfleet/v1"), "error must carry the replay schedule: {msg}");
}

/// Satellite-2 regression, fleet-level: when every worker dies with work
/// outstanding, `recv_step`'s liveness poll must fail the run — the
/// simulated analogue of the driver's `recv_rollout` dead-fleet check.
#[test]
fn whole_fleet_death_surfaces_the_liveness_error() {
    let script = FleetScript {
        cfg: SimFleetCfg { engines: 2, iters: 1, seed: 3, ..Default::default() },
        ops: vec![FleetOp::Die { iter: 0, engine: 0 }, FleetOp::Die { iter: 0, engine: 1 }],
    };
    let err = fleet::run(&script).expect_err("dead fleet must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("all engine workers exited"), "got: {msg}");
}

/// Satellite 3, fleet-level: 64-engine runs with warmth TTLs, drains and a
/// joiner keep every invariant, and the router's belief set stays bounded
/// by the template population (beliefs are per-template, rebalanced on
/// `remove_engine`, decayed by TTL). `sync_every=0` keeps caches warm
/// across iterations so the TTL clock is what governs expiry.
#[test]
fn sixty_four_engine_fleet_with_ttl_and_drains_stays_consistent() {
    for &ttl in &[0u64, 1, 3] {
        let cfg = SimFleetCfg {
            engines: 64,
            iters: 4,
            batch_prompts: 48,
            group_size: 2,
            templates: 12,
            seed: 21,
            warmth_ttl: ttl,
            sync_every: 0,
            ..Default::default()
        };
        let script = FleetScript {
            cfg,
            ops: vec![
                FleetOp::Drain { iter: 0 },
                FleetOp::Drain { iter: 1 },
                FleetOp::Join { iter: 2 },
                FleetOp::Straggle { iter: 1, engine: 7, factor: 8.0 },
            ],
        };
        let r = check_invariants(&script);
        assert_eq!(r.engines, 63, "ttl={ttl}: 64 - 2 drains + 1 join");
        assert!(
            r.warm_beliefs <= 12,
            "ttl={ttl}: beliefs are per-template, got {}",
            r.warm_beliefs
        );
        assert!(r.warm_beliefs > 0, "ttl={ttl}: the stats sweep must have fed the warmth map");
    }
}
