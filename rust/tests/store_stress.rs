//! Multi-threaded stress harness for the sharded cross-engine KV store.
//!
//! N publisher / fetcher / evictor threads (real OS threads through the
//! `check::thread` shim) hammer a deliberately small-capacity
//! [`SharedKvStore`] and assert,
//! *under real contention*, the invariants the single-threaded proptests pin:
//!
//! * **bit-exact fetch** — every fetched prefix equals the deterministic
//!   prefix-dependent row oracle, always (rows are copied under the shard
//!   lock, so no reader can observe a torn or evicted segment);
//! * **lease pinning** — while a fetch lease is held, re-fetching the same
//!   prefix returns the identical coverage and bytes (the leased chain
//!   cannot be evicted underneath the holder);
//! * **capacity budget** — `live_blocks() <= capacity_blocks()` at every
//!   observation point (each shard enforces its slice under its own lock);
//! * **drain** — after all threads join and release, no leases leak and the
//!   structural `check()` (including the heap covering invariant) passes.
//!
//! Deterministic per-thread seeds (`SEED` + thread index) make a failure
//! reproducible; the *interleaving* is of course free to vary — that is the
//! point. CI runs this in `--release` with `RUST_TEST_THREADS` unpinned so
//! the scheduler genuinely interleaves the workers.

use pa_rl::check::thread;
use pa_rl::engine::kvcache::EvictPolicy;
use pa_rl::store::{SharedKvStore, StoreCfg};
use pa_rl::util::rng::Pcg64;
use std::sync::Arc;

const SEED: u64 = 0x57AE55;
const RE: usize = 8; // f32 row elements per token
const OPS_PER_THREAD: usize = 600;
const N_THREADS: usize = 9; // 3 publishers + 3 fetchers + 3 evictors

/// Deterministic prefix-dependent rows (row p depends on tokens[..=p] only),
/// mirroring real KV — the bit-exactness oracle.
fn rows_for(seq: &[u32]) -> Vec<f32> {
    let mut acc = 11u64;
    let mut out = Vec::with_capacity(seq.len() * RE);
    for &t in seq {
        acc = acc.wrapping_mul(2862933555777941757).wrapping_add(u64::from(t) + 1);
        for e in 0..RE {
            out.push(((acc >> (e * 7 % 50)) & 0xFF) as f32);
        }
    }
    out
}

fn logits_for(seq: &[u32]) -> Vec<f32> {
    vec![seq.iter().sum::<u32>() as f32, seq.len() as f32]
}

/// Template-sharing prompt: a shared few-shot head plus a short random tail.
fn prompt_for(rng: &mut Pcg64, templates: &[Vec<u32>]) -> Vec<u32> {
    let mut p = templates[rng.range(0, templates.len())].clone();
    p.extend((0..rng.range(0, 6)).map(|_| rng.range(0, 9) as u32));
    p
}

fn stress(shards: usize) {
    let bt = 4usize;
    let store = Arc::new(SharedKvStore::new(StoreCfg {
        block_tokens: bt,
        capacity_blocks: 48, // small on purpose: constant eviction pressure
        policy: EvictPolicy::Lru,
        shards,
    }));
    store.set_version(1);
    let templates: Arc<Vec<Vec<u32>>> =
        Arc::new((0..6u32).map(|t| (0..8).map(|i| t * 16 + i).collect()).collect());

    let mut handles = Vec::new();
    for th in 0..N_THREADS {
        let store = store.clone();
        let templates = templates.clone();
        handles.push(thread::spawn(move || {
            let mut rng = Pcg64::new(SEED, th as u64 + 1);
            for op in 0..OPS_PER_THREAD {
                match th % 3 {
                    0 => {
                        // Publisher: template-sharing prefixes, terminal
                        // logits included — the cross-engine publish path.
                        let p = prompt_for(&mut rng, &templates);
                        let logits = logits_for(&p);
                        store.publish(&p, &rows_for(&p), Some(&logits), 1);
                    }
                    1 => {
                        // Fetcher: verify bit-exactness, then exercise lease
                        // pinning — a held lease must keep the chain intact
                        // against the evictors on the other threads.
                        let p = prompt_for(&mut rng, &templates);
                        if let Some(f) = store.fetch_longest(&p, 0, 1) {
                            assert_eq!(
                                f.rows,
                                rows_for(&p[..f.len]),
                                "fetched rows diverge from the oracle under contention"
                            );
                            if let Some(l) = &f.logits {
                                assert_eq!(f.len, p.len(), "logits without full coverage");
                                assert_eq!(*l, logits_for(&p), "terminal logits corrupt");
                            }
                            let again = store
                                .fetch_longest(&p[..f.len], 0, 1)
                                .expect("leased chain must stay fetchable");
                            assert_eq!(again.len, f.len, "leased coverage shrank");
                            assert_eq!(again.rows, f.rows, "leased chain mutated");
                            store.release(again.lease);
                            store.release(f.lease);
                        }
                    }
                    _ => {
                        // Evictor: distinct cold prefixes churn the heap and
                        // force victim selection under every interleaving.
                        let len = rng.range(1, 10);
                        let cold: Vec<u32> =
                            (0..len).map(|_| 100 + rng.range(0, 60) as u32).collect();
                        store.publish(&cold, &rows_for(&cold), None, 1);
                    }
                }
                // The block budget must hold at every observation point.
                assert!(
                    store.live_blocks() <= store.capacity_blocks(),
                    "capacity budget violated mid-run"
                );
                if op % 128 == 0 {
                    store.check().expect("structural invariants broke mid-run");
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("stress thread panicked");
    }
    assert_eq!(store.leased_blocks(), 0, "leases leaked past thread exit");
    assert!(store.live_blocks() <= store.capacity_blocks());
    store.check().expect("post-run structural invariants");
    let stats = store.stats();
    assert!(stats.publishes > 0 && stats.fetches > 0, "workload degenerated");
    // Eviction churn depends on which publishes the scheduler actually let
    // through before the fetch-heavy threads finished, so it is asserted
    // deterministically in the model-check suite
    // (tests/modelcheck.rs::store_two_publishers_one_evictor_invariants)
    // instead of here; a zero here is only worth a note.
    if stats.evictions == 0 {
        eprintln!("note: stress(shards={shards}) saw no evictions this run");
    }
}

#[test]
fn sharded_store_survives_publisher_fetcher_evictor_contention() {
    // The same harness at the unsharded baseline and two shard widths: the
    // invariants are topology-independent.
    for shards in [1usize, 4, 8] {
        stress(shards);
    }
}

/// Version bumps racing generation traffic: flushes mid-stream must never
/// corrupt state — stale-version publishes/fetches are rejected, stale
/// leases are ignored, and whatever *is* fetched is still bit-exact.
#[test]
fn version_churn_under_contention_stays_consistent() {
    let store = Arc::new(SharedKvStore::new(StoreCfg {
        block_tokens: 4,
        capacity_blocks: 32,
        policy: EvictPolicy::Lru,
        shards: 4,
    }));
    store.set_version(1);
    let templates: Arc<Vec<Vec<u32>>> =
        Arc::new((0..4u32).map(|t| (0..8).map(|i| t * 16 + i).collect()).collect());
    let mut handles = Vec::new();
    for th in 0..6usize {
        let store = store.clone();
        let templates = templates.clone();
        handles.push(thread::spawn(move || {
            let mut rng = Pcg64::new(SEED ^ 0xBEEF, th as u64 + 1);
            for _ in 0..300 {
                let p = prompt_for(&mut rng, &templates);
                if th == 0 {
                    // The "coordinator": occasionally announce a version in
                    // a small window, so flushes interleave with traffic.
                    let v = 1 + rng.range(0, 3) as u64;
                    store.set_version(v);
                } else if th % 2 == 0 {
                    // Publish under a guessed version: rejected when stale.
                    let v = 1 + rng.range(0, 3) as u64;
                    let logits = logits_for(&p);
                    store.publish(&p, &rows_for(&p), Some(&logits), v);
                } else {
                    let v = 1 + rng.range(0, 3) as u64;
                    if let Some(f) = store.fetch_longest(&p, 0, v) {
                        assert_eq!(f.rows, rows_for(&p[..f.len]), "stale bytes leaked");
                        store.release(f.lease);
                    }
                }
                assert!(store.live_blocks() <= store.capacity_blocks());
            }
        }));
    }
    for h in handles {
        h.join().expect("version-churn thread panicked");
    }
    store.check().expect("post-run invariants");
}
