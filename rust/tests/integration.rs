//! Integration tests over the real AOT artifacts (`artifacts/tiny`).
//!
//! Skipped (with a loud message) when artifacts are missing — run
//! `make artifacts` first. `make test` guarantees the ordering.

use pa_rl::config::Config;
use pa_rl::coordinator::{evaluate, Driver, DriverOpts, Mode};
use pa_rl::data::DataLoader;
use pa_rl::engine::{Engine, GenRequest, SamplerCfg};
use pa_rl::grpo::{group_advantages, Group, Rollout};
use pa_rl::runtime::Runtime;
use pa_rl::train::{IterStats, Trainer};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<(Config, PathBuf)> {
    let dir = PathBuf::from("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts` first");
        return None;
    }
    let cfg = Config::load(Path::new("configs/tiny.json")).expect("load tiny config");
    Some((cfg, dir))
}

#[test]
fn engine_generates_and_tags_versions() {
    let Some((cfg, dir)) = artifacts() else { return };
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let params = rt.init_params(7).unwrap();
    let mut engine = Engine::new(cfg.clone(), rt, 1);
    let mut p = params;
    p.version = 42;
    engine.set_weights(&p).unwrap();

    let mut loader = DataLoader::new(cfg.data.clone());
    let prompts = loader.next_batch(6);
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest { request_id: i as u64, prompt: p.tokens.clone(), ..Default::default() })
        .collect();
    let results = engine.generate_all(reqs).unwrap();
    assert_eq!(results.len(), 6);
    for r in &results {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= cfg.engine.max_new, "len {} > max_new", r.tokens.len());
        assert_eq!(r.weight_version, 42, "Prop. 1 version tag");
        assert_eq!(r.tokens.len(), r.logprobs.len());
    }
    // engine must be reusable afterwards
    assert!(engine.idle());
    assert!(engine.stats.tokens_generated > 0);
}

/// Acceptance: a G-rollout group costs exactly one compiled prefill, and the
/// shared-prefix cache reports a (G-1)/G prompt-token hit rate.
#[test]
fn grouped_prompts_trigger_one_prefill_per_group() {
    let Some((cfg, dir)) = artifacts() else { return };
    assert!(cfg.engine.prefix_cache, "tiny config should default the cache on");
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let params = rt.init_params(7).unwrap();
    let mut engine = Engine::new(cfg.clone(), rt, 1);
    engine.set_weights(&params).unwrap();

    let mut loader = DataLoader::new(cfg.data.clone());
    let prompts = loader.next_batch(2);
    let g = cfg.rl.group_size;
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .flat_map(|(pi, p)| {
            let p = p.tokens.clone();
            (0..g).map(move |s| GenRequest {
                request_id: (pi * g + s) as u64,
                prompt: p.clone(),
                ..Default::default()
            })
        })
        .collect();
    let results = engine.generate_all(reqs).unwrap();
    assert_eq!(results.len(), 2 * g);

    assert_eq!(engine.stats.prefills, 2, "one compiled prefill per unique prompt");
    assert_eq!(engine.stats.prefills_skipped, 2 * (g as u64 - 1));
    let cache = engine.cache_stats().expect("cache enabled");
    let want = (g - 1) as f64 / g as f64;
    assert!(
        cache.hit_rate() >= want - 1e-9,
        "hit rate {} below (G-1)/G = {want}",
        cache.hit_rate()
    );
    let total_prompt_tokens: usize = prompts.iter().map(|p| g * p.tokens.len()).sum();
    assert_eq!(
        cache.hit_tokens + cache.miss_tokens,
        total_prompt_tokens as u64,
        "every prompt token accounted hit or miss"
    );
}

/// Acceptance (partial-prefix reuse): a warm few-shot template survives
/// across differing suffixes — after the first (cold, monolithic) prefill,
/// every admission runs compiled chunk calls over its uncached suffix only,
/// and `prefill_tokens_saved` grows by the restored template each time.
#[test]
fn warm_template_prefix_reused_across_suffixes() {
    let Some((cfg, dir)) = artifacts() else { return };
    assert!(cfg.engine.prefix_cache, "tiny config should default the cache on");
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    if !rt.manifest().artifacts.contains_key("prefill_chunk") {
        eprintln!("SKIP: artifacts predate chunked prefill — re-run `make artifacts`");
        return;
    }
    let params = rt.init_params(7).unwrap();
    let mut engine = Engine::new(cfg.clone(), rt, 1);
    engine.set_weights(&params).unwrap();

    // Shared template (most of the prompt budget), distinct 1-token suffixes.
    let cb = cfg.engine.cache_block;
    let tpl_len = cfg.engine.prompt_max - 1;
    let template: Vec<u32> = (0..tpl_len as u32).map(|i| 3 + (i % 11)).collect();
    let n = 4usize;
    let reqs: Vec<GenRequest> = (0..n)
        .map(|i| {
            let mut p = template.clone();
            p.push(20 + i as u32);
            GenRequest { request_id: i as u64, prompt: p, ..Default::default() }
        })
        .collect();
    let results = engine.generate_all(reqs).unwrap();
    assert_eq!(results.len(), n);
    for r in &results {
        assert!(!r.tokens.is_empty());
        assert_eq!(r.tokens.len(), r.logprobs.len());
    }

    // One monolithic prefill (the cold leader); every warm admission covers
    // its 1-token uncached suffix with a single compiled chunk.
    assert_eq!(engine.stats.prefills, 1, "only the cold prompt pays a full prefill");
    assert_eq!(
        engine.stats.prefill_chunks,
        (n - 1) as u64,
        "each warm prompt needs exactly one chunk for its suffix (cb = {cb})"
    );
    assert!(
        engine.stats.prefill_tokens_saved >= ((n - 1) * tpl_len) as u64,
        "restored tokens {} below the warm template floor {}",
        engine.stats.prefill_tokens_saved,
        (n - 1) * tpl_len
    );
    let cache = engine.cache_stats().expect("cache enabled");
    assert_eq!(cache.partial_hits, (n - 1) as u64);
    // Prefill compute scaled with uncached tokens only: every prompt token
    // is accounted, and the misses are the cold prompt + (n-1) suffixes.
    assert_eq!(
        cache.miss_tokens,
        (tpl_len + 1 + (n - 1)) as u64,
        "compiled prefill compute must cover uncached tokens only"
    );
}

/// Acceptance (cross-engine KV sharing): two engine instances sharing the
/// host-side segment store on a template-sharing workload — the second
/// engine imports the first engine's published template instead of
/// recomputing it (`cross_engine_hits > 0`, strictly more
/// `prefill_tokens_saved` than the same engine without a store), and its
/// rollouts are value-identical to a store-less engine with the same seed.
#[test]
fn cross_engine_store_shares_templates_across_engines() {
    use pa_rl::store::{SharedKvStore, StoreCfg};
    use std::sync::Arc;
    let Some((cfg, dir)) = artifacts() else { return };
    assert!(cfg.engine.prefix_cache, "tiny config should default the cache on");
    let rt_probe = Runtime::load_validated(&dir, &cfg).unwrap();
    if !rt_probe.manifest().artifacts.contains_key("prefill_chunk") {
        eprintln!("SKIP: artifacts predate chunked prefill — re-run `make artifacts`");
        return;
    }
    drop(rt_probe);

    // Shared template filling most of the prompt; distinct 1-token suffixes.
    // The store shares at block granularity: skip when the template doesn't
    // span a full block (degenerate single-block geometry).
    let tpl_len = cfg.engine.prompt_max - 1;
    let aligned_tpl = tpl_len / cfg.engine.cache_block * cfg.engine.cache_block;
    if aligned_tpl == 0 {
        eprintln!("SKIP: template shorter than one store block (cache_block too large)");
        return;
    }
    let template: Vec<u32> = (0..tpl_len as u32).map(|i| 3 + (i % 11)).collect();
    let n = 3usize;
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|i| {
            let mut p = template.clone();
            p.push(20 + i as u32);
            p
        })
        .collect();
    let reqs = |prompts: &[Vec<u32>]| -> Vec<GenRequest> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, p)| GenRequest { request_id: i as u64, prompt: p.clone(), ..Default::default() })
            .collect()
    };

    let store = Arc::new(SharedKvStore::new(StoreCfg {
        block_tokens: cfg.engine.cache_block,
        capacity_blocks: cfg.engine.store_blocks,
        policy: cfg.engine.store_evict,
        shards: cfg.engine.store_shards,
    }));
    let mk_engine = |seed: u64, store: Option<Arc<SharedKvStore>>| {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let params = rt.init_params(7).unwrap();
        let mut e = Engine::new(cfg.clone(), rt, seed);
        e.set_weights(&params).unwrap();
        if let Some(s) = store {
            e.set_shared_store(s);
        }
        e
    };

    // Engine A warms the store with the template prompts.
    let mut a = mk_engine(1, Some(store.clone()));
    a.generate_all(reqs(&prompts)).unwrap();
    assert_eq!(a.stats.cross_engine_hits, 0, "nothing published before A ran");
    assert!(a.stats.store_publishes > 0, "A must publish its prefixes");

    // Engine B (different instance, same store) admits different suffixes of
    // the same template: every admission imports instead of recomputing.
    let b_prompts: Vec<Vec<u32>> = (0..n)
        .map(|i| {
            let mut p = template.clone();
            p.push(40 + i as u32);
            p
        })
        .collect();
    let mut b = mk_engine(2, Some(store.clone()));
    let mut with_store = b.generate_all(reqs(&b_prompts)).unwrap();
    with_store.sort_by_key(|r| r.request_id);
    assert!(
        b.stats.cross_engine_hits > 0,
        "B never imported from the store on a template-sharing workload"
    );
    assert!(b.stats.cross_engine_tokens > 0);
    assert_eq!(b.stats.prefills, 0, "the template import leaves only chunked suffixes");

    // Same seed, no store: identical rollouts, strictly fewer tokens saved.
    let mut c = mk_engine(2, None);
    let mut without = c.generate_all(reqs(&b_prompts)).unwrap();
    without.sort_by_key(|r| r.request_id);
    let strip = |rs: Vec<pa_rl::engine::GenResult>| {
        rs.into_iter().map(|r| (r.tokens, r.logprobs)).collect::<Vec<_>>()
    };
    assert_eq!(
        strip(with_store),
        strip(without),
        "cross-engine import must not change generated rollouts"
    );
    assert!(
        b.stats.prefill_tokens_saved > c.stats.prefill_tokens_saved,
        "store must save strictly more prefill tokens ({} vs {})",
        b.stats.prefill_tokens_saved,
        c.stats.prefill_tokens_saved
    );
}

/// A no-op weight sync (identical params version) keeps the prefix cache
/// warm; a real bump flushes it — the cache-generation tag end-to-end.
#[test]
fn noop_weight_sync_keeps_cache_warm() {
    let Some((cfg, dir)) = artifacts() else { return };
    assert!(cfg.engine.prefix_cache);
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let mut params = rt.init_params(7).unwrap();
    params.version = 5;
    let mut engine = Engine::new(cfg.clone(), rt, 3);
    assert!(engine.set_weights(&params).unwrap(), "first install uploads");
    let mut loader = DataLoader::new(cfg.data.clone());
    let p = loader.next_batch(1).remove(0);
    engine
        .generate_all(vec![GenRequest { request_id: 0, prompt: p.tokens.clone(), ..Default::default() }])
        .unwrap();
    assert_eq!(engine.stats.prefills, 1);

    // Identical version: skipped, and the cached prompt still full-hits.
    assert!(!engine.set_weights(&params).unwrap(), "no-op sync must be skipped");
    assert_eq!(engine.stats.weight_syncs_skipped, 1);
    engine
        .generate_all(vec![GenRequest { request_id: 1, prompt: p.tokens.clone(), ..Default::default() }])
        .unwrap();
    assert_eq!(engine.stats.prefills, 1, "warm cache must survive the no-op sync");
    assert_eq!(engine.stats.prefills_skipped, 1);

    // Real bump: flushed, the same prompt prefills again.
    params.version = 6;
    assert!(engine.set_weights(&params).unwrap());
    engine
        .generate_all(vec![GenRequest { request_id: 2, prompt: p.tokens, ..Default::default() }])
        .unwrap();
    assert_eq!(engine.stats.prefills, 2, "version bump must flush the cache");
}

/// Driver-level smoke: >= 2 engines with affinity routing and the shared
/// store on a shared-template workload — the run stays on-policy and the
/// new IterReport fields are populated and self-consistent.
#[test]
fn driver_multi_engine_affinity_and_store() {
    let Some((mut cfg, dir)) = artifacts() else { return };
    {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        if !rt.manifest().artifacts.contains_key("prefill_chunk") {
            eprintln!("SKIP: artifacts predate chunked prefill — re-run `make artifacts`");
            return;
        }
    }
    cfg.rl.n_engines = 2;
    cfg.data.shared_few_shot = true;
    let opts = DriverOpts { mode: Mode::Async, spa: false, seed: 23 };
    let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
    let report = driver.run(2).unwrap();
    for it in &report.iters {
        assert_eq!(it.staleness_mean, 0.0, "multi-engine async stays on-policy");
        assert_eq!(
            it.affinity_hits + it.affinity_spills,
            cfg.rl.batch_prompts as u64,
            "every group routes exactly once"
        );
    }
    let stats = driver.store_stats().expect("store active with 2 engines");
    assert!(stats.fetches > 0, "admissions must consult the store");
    // Engines publish only block-aligned heads; with sub-block prompts
    // there is nothing shareable to publish.
    let probe = DataLoader::new(cfg.data.clone()).next_batch(1).remove(0);
    if probe.tokens.len() >= cfg.engine.cache_block {
        assert!(stats.publishes > 0, "engines must publish to the store");
    }
}
#[test]
fn cache_on_and_off_produce_identical_rollouts() {
    let Some((cfg, dir)) = artifacts() else { return };
    let mut outs = Vec::new();
    for cache_on in [true, false] {
        let mut cfg = cfg.clone();
        cfg.engine.prefix_cache = cache_on;
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let params = rt.init_params(3).unwrap();
        let mut engine = Engine::new(cfg.clone(), rt, 9);
        engine.set_weights(&params).unwrap();
        let mut loader = DataLoader::new(cfg.data.clone());
        let p = loader.next_batch(1).remove(0);
        let g = cfg.rl.group_size;
        let reqs: Vec<GenRequest> = (0..g)
            .map(|i| GenRequest { request_id: i as u64, prompt: p.tokens.clone(), ..Default::default() })
            .collect();
        let mut results = engine.generate_all(reqs).unwrap();
        results.sort_by_key(|r| r.request_id);
        if cache_on {
            assert_eq!(engine.stats.prefills, 1);
        } else {
            assert!(engine.cache_stats().is_none());
            assert_eq!(engine.stats.prefills, g as u64);
            assert_eq!(engine.stats.prefills_skipped, 0);
        }
        outs.push(
            results
                .into_iter()
                .map(|r| (r.tokens, r.logprobs))
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(outs[0], outs[1], "prefix cache must not change generated rollouts");
}

#[test]
fn greedy_decode_is_deterministic() {
    let Some((cfg, dir)) = artifacts() else { return };
    let mut outs = Vec::new();
    for _ in 0..2 {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let params = rt.init_params(3).unwrap();
        let mut engine = Engine::new(cfg.clone(), rt, 9);
        engine.set_sampler(SamplerCfg::greedy());
        engine.set_weights(&params).unwrap();
        let mut loader = DataLoader::new(cfg.data.clone());
        let prompts = loader.next_batch(3);
        let reqs: Vec<GenRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| GenRequest { request_id: i as u64, prompt: p.tokens.clone(), ..Default::default() })
            .collect();
        let mut results = engine.generate_all(reqs).unwrap();
        results.sort_by_key(|r| r.request_id);
        outs.push(results.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>());
    }
    assert_eq!(outs[0], outs[1], "greedy decoding must be deterministic");
}

/// Remark 1 at the systems level: feeding identical groups to two trainers in
/// different orders yields (float-tolerance) identical updated parameters.
#[test]
fn gradient_permutation_invariance_end_to_end() {
    let Some((cfg, dir)) = artifacts() else { return };

    // Generate one batch of groups once.
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let params = rt.init_params(5).unwrap();
    let mut engine = Engine::new(cfg.clone(), rt, 2);
    engine.set_weights(&params).unwrap();
    let mut loader = DataLoader::new(cfg.data.clone());
    let prompts = loader.next_batch(4);
    let g = cfg.rl.group_size;
    let mut reqs = Vec::new();
    for (pi, p) in prompts.iter().enumerate() {
        for s in 0..g {
            reqs.push(GenRequest {
                request_id: (pi * g + s) as u64,
                prompt: p.tokens.clone(),
                ..Default::default()
            });
        }
    }
    let results = engine.generate_all(reqs).unwrap();
    let mut groups: Vec<Group> = Vec::new();
    for (pi, p) in prompts.iter().enumerate() {
        let mut rollouts: Vec<Rollout> = results
            .iter()
            .filter(|r| (r.request_id as usize) / g == pi)
            .map(|r| Rollout {
                sample_idx: (r.request_id as usize) % g,
                weight_version: r.weight_version,
                tokens: r.tokens.clone(),
                logprobs: r.logprobs.clone(),
                reward: (r.request_id % 2) as f32, // synthetic mixed rewards
                timeline: r.timeline,
            })
            .collect();
        rollouts.sort_by_key(|r| r.sample_idx);
        let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
        groups.push(Group {
            prompt: prompts[pi].clone(),
            weight_version: rollouts[0].weight_version,
            advantages: group_advantages(&rewards),
            rollouts,
            gen_seconds: 0.0,
        });
    }

    // Train in forward and reverse order.
    let train = |order: Vec<&Group>| -> Vec<Vec<f32>> {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let mut trainer = Trainer::with_params(cfg.clone(), rt, params.clone()).unwrap();
        let mut stats = IterStats::default();
        trainer.begin_iteration().unwrap();
        for g in order {
            trainer.train_group(g, false, &mut stats).unwrap();
        }
        trainer.end_iteration(&mut stats).unwrap();
        trainer
            .policy()
            .tensors
            .iter()
            .map(|t| t.as_f32().unwrap().to_vec())
            .collect()
    };
    let fwd = train(groups.iter().collect());
    let rev = train(groups.iter().rev().collect());
    let mut max_diff = 0.0f32;
    for (a, b) in fwd.iter().zip(&rev) {
        for (x, y) in a.iter().zip(b) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    assert!(
        max_diff < 1e-5,
        "permutation changed the update by {max_diff} (Remark 1 violated)"
    );
}

#[test]
fn driver_async_runs_and_stays_on_policy() {
    let Some((cfg, dir)) = artifacts() else { return };
    let opts = DriverOpts { mode: Mode::Async, spa: false, seed: 11 };
    let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
    let report = driver.run(2).unwrap();
    assert_eq!(report.iters.len(), 2);
    for it in &report.iters {
        assert!(it.reward_mean >= 0.0 && it.reward_mean <= 1.0);
        assert!(it.stats.micro_steps > 0);
        assert!(it.train_input_tokens > 0);
        assert_eq!(it.staleness_mean, 0.0, "async mode must be strictly on-policy");
    }
    assert!(report.tpspd() > 0.0);
    // policy actually moved
    assert_eq!(driver.trainer().policy_version(), 2);
}

#[test]
fn driver_sync_and_spa_modes_run() {
    let Some((cfg, dir)) = artifacts() else { return };
    for (mode, spa) in [(Mode::Sync, false), (Mode::Async, true)] {
        let opts = DriverOpts { mode, spa, seed: 13 };
        let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
        let report = driver.run(1).unwrap();
        assert_eq!(report.iters.len(), 1);
        assert!(report.iters[0].stats.micro_steps > 0);
        if spa {
            // SPA packs each group into a single micro-batch.
            assert_eq!(report.iters[0].stats.micro_steps, cfg.rl.batch_prompts);
        }
    }
}

#[test]
fn driver_stale_mode_tracks_staleness() {
    let Some((cfg, dir)) = artifacts() else { return };
    let opts = DriverOpts { mode: Mode::StaleAsync { max_staleness: 1 }, spa: false, seed: 17 };
    let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
    let report = driver.run(3).unwrap();
    assert_eq!(report.iters.len(), 3);
    // Batch 1 is generated while batch 0 trains -> staleness 1 when consumed.
    let stale: f64 = report.iters.iter().map(|i| i.staleness_mean).sum();
    assert!(stale > 0.0, "stale mode should exhibit nonzero staleness");
}

#[test]
fn evaluation_runs() {
    let Some((cfg, dir)) = artifacts() else { return };
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let params = rt.init_params(1).unwrap();
    drop(rt);
    let report = evaluate(&cfg, &dir, &params, 8).unwrap();
    assert_eq!(report.n, 8);
    assert!(report.accuracy >= 0.0 && report.accuracy <= 1.0);
    assert!(report.mean_response_len > 0.0);
}

#[test]
fn engine_weight_versions_update_between_batches() {
    let Some((cfg, dir)) = artifacts() else { return };
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let mut p1 = rt.init_params(1).unwrap();
    p1.version = 10;
    let mut engine = Engine::new(cfg.clone(), rt, 4);
    engine.set_weights(&p1).unwrap();
    let mut loader = DataLoader::new(cfg.data.clone());
    let p = loader.next_batch(1).remove(0);
    let r1 = engine
        .generate_all(vec![GenRequest { request_id: 0, prompt: p.tokens.clone(), ..Default::default() }])
        .unwrap();
    assert_eq!(r1[0].weight_version, 10);
    // new weights only installable when idle; version propagates
    let mut p2 = p1.clone();
    p2.version = 11;
    engine.set_weights(&p2).unwrap();
    let r2 = engine
        .generate_all(vec![GenRequest { request_id: 1, prompt: p.tokens, ..Default::default() }])
        .unwrap();
    assert_eq!(r2[0].weight_version, 11);
}

#[test]
fn set_weights_rejected_while_busy() {
    let Some((cfg, dir)) = artifacts() else { return };
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let params = rt.init_params(2).unwrap();
    let mut engine = Engine::new(cfg.clone(), rt, 5);
    engine.set_weights(&params).unwrap();
    let mut loader = DataLoader::new(cfg.data.clone());
    let p = loader.next_batch(1).remove(0);
    engine.submit(GenRequest { request_id: 0, prompt: p.tokens, ..Default::default() });
    engine.step().unwrap(); // admits; likely still active
    if !engine.idle() {
        assert!(
            engine.set_weights(&params).is_err(),
            "mid-flight weight swap must be refused (on-policy guard)"
        );
        while !engine.idle() {
            engine.step().unwrap();
        }
    }
    engine.set_weights(&params).unwrap();
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    use pa_rl::train::Checkpoint;
    let Some((cfg, dir)) = artifacts() else { return };
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let mut trainer = Trainer::new(cfg.clone(), rt, 9).unwrap();
    // advance one iteration so optimizer state is non-trivial
    let mut engine = {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let mut e = Engine::new(cfg.clone(), rt, 6);
        e.set_weights(trainer.policy()).unwrap();
        e
    };
    let mut loader = DataLoader::new(cfg.data.clone());
    let p = loader.next_batch(1).remove(0);
    let results = engine
        .generate_all(
            (0..2)
                .map(|i| GenRequest { request_id: i, prompt: p.tokens.clone(), ..Default::default() })
                .collect(),
        )
        .unwrap();
    let rollouts: Vec<Rollout> = results
        .iter()
        .enumerate()
        .map(|(i, r)| Rollout {
            sample_idx: i,
            weight_version: r.weight_version,
            tokens: r.tokens.clone(),
            logprobs: r.logprobs.clone(),
            reward: i as f32,
            timeline: r.timeline,
        })
        .collect();
    let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
    let group = Group {
        prompt: p,
        weight_version: 0,
        advantages: group_advantages(&rewards),
        rollouts,
        gen_seconds: 0.0,
    };
    let mut stats = IterStats::default();
    trainer.begin_iteration().unwrap();
    trainer.train_group(&group, false, &mut stats).unwrap();
    trainer.end_iteration(&mut stats).unwrap();

    let (m, v) = trainer.adam_state();
    let ck = Checkpoint::from_params(trainer.policy(), m, v, trainer.step_count());
    let path = std::env::temp_dir().join("pa_rl_int_ckpt").join("t.ckpt");
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.step, 1);
    assert_eq!(back.policy_version, 1);
    assert_eq!(back.policy, trainer.policy().tensors);
    // restored params drive a fresh trainer
    let rt = Runtime::load_validated(&dir, &cfg).unwrap();
    let t2 = Trainer::with_params(cfg.clone(), rt, back.to_host_params()).unwrap();
    assert_eq!(t2.policy().version, 1);
}

/// Acceptance (elastic fleet): a training run that joins an engine at one
/// boundary and drains one at a later boundary completes with zero lost
/// rollouts and produces **bit-identical per-iteration rewards** to a static
/// fleet of the final size, while the trace's fleet lane records both
/// resizes.
///
/// Greedy sampling makes every rollout a function of (prompt, weights)
/// alone — independent of which engine serves it, in which slot, next to
/// which batch-mates — so fleet elasticity is equivalence-checkable
/// bit-for-bit. Sync mode then trains the collected groups in prompt order,
/// making the weight trajectory deterministic too.
#[test]
fn elastic_join_and_drain_matches_static_fleet() {
    use pa_rl::config::FleetEvent;
    let Some((mut cfg, dir)) = artifacts() else { return };
    cfg.engine.temperature = 0.0;
    cfg.rl.n_engines = 2;
    let iters = 3u64;

    let run = |cfg: &Config| {
        let opts = DriverOpts { mode: Mode::Sync, spa: false, seed: 41 };
        let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
        let rep = driver.run(iters).unwrap();
        // Zero lost rollouts: every batch fully assembled and trained.
        assert_eq!(rep.iters.len(), iters as usize);
        assert_eq!(driver.trainer().policy_version(), iters);
        let rewards: Vec<f64> = rep.iters.iter().map(|i| i.reward_mean).collect();
        let engines: Vec<usize> = rep.iters.iter().map(|i| i.engines).collect();
        (rewards, engines, rep.trace)
    };

    let (static_rewards, static_engines, _) = run(&cfg);
    assert_eq!(static_engines, vec![2, 2, 2]);

    let mut elastic = cfg.clone();
    elastic.rl.fleet_schedule = vec![
        FleetEvent { iter: 1, join: 1, leave: 0 },
        FleetEvent { iter: 2, join: 0, leave: 1 },
    ];
    let (rewards, engines, trace) = run(&elastic);
    assert_eq!(engines, vec![2, 3, 2], "fleet must resize at the scheduled boundaries");
    assert_eq!(
        rewards, static_rewards,
        "elastic fleet must be bit-identical to the static fleet of the final size"
    );
    // The fig3 trace carries the fleet-size change on its own lane.
    let spans = trace.spans();
    assert!(
        spans.iter().any(|s| s.lane == "fleet" && s.name.contains("join")),
        "trace must record the join"
    );
    assert!(
        spans.iter().any(|s| s.lane == "fleet" && s.name.contains("drain")),
        "trace must record the drain"
    );
    let fleet_gauge = trace
        .annotations()
        .into_iter()
        .find(|(lane, key, _)| lane == "fleet" && key == "engines")
        .expect("fleet-size gauge annotated");
    assert_eq!(fleet_gauge.2, 2.0, "gauge ends at the final fleet size");
}

/// Acceptance (per-request sampling streams): the elastic bit-identity
/// guarantee above no longer needs the greedy caveat. At temperature 1.0
/// every random draw is keyed by `(run_seed, request_id, decode_step)`, so a
/// single-engine fleet, a static two-engine fleet, and an elastic fleet that
/// joins and drains mid-run all produce **bit-identical per-request token
/// and logprob streams** — compared record-by-record via the driver's
/// rollout recorder, keyed by the dispatch-order request id.
#[test]
fn stochastic_sampling_is_placement_independent_across_fleets() {
    use pa_rl::config::FleetEvent;
    use pa_rl::coordinator::RolloutRecord;
    let Some((mut cfg, dir)) = artifacts() else { return };
    cfg.engine.temperature = 1.0;
    cfg.rl.n_engines = 1;
    let iters = 3u64;

    // Strip the engine index — it is the one field *allowed* to differ.
    let strip = |recs: Vec<RolloutRecord>| -> Vec<(u64, u64, Vec<u32>, Vec<f32>)> {
        recs.into_iter()
            .map(|r| (r.request_id, r.weight_version, r.tokens, r.logprobs))
            .collect()
    };
    let run = |cfg: &Config| {
        let opts = DriverOpts { mode: Mode::Sync, spa: false, seed: 41 };
        let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
        driver.record_rollouts(true);
        let rep = driver.run(iters).unwrap();
        assert_eq!(rep.iters.len(), iters as usize);
        strip(driver.take_rollout_records())
    };

    let oracle = run(&cfg);
    assert_eq!(
        oracle.len(),
        (iters as usize) * cfg.rl.batch_prompts * cfg.rl.group_size,
        "recorder must capture every dispatched rollout"
    );
    // Stochastic runs must actually be stochastic: a degenerate all-greedy
    // stream would make the equality below vacuous.
    let first_tokens: std::collections::HashSet<&Vec<u32>> =
        oracle.iter().map(|(_, _, t, _)| t).collect();
    assert!(first_tokens.len() > 1, "temperature 1.0 should diversify rollouts");

    let mut two = cfg.clone();
    two.rl.n_engines = 2;
    assert_eq!(run(&two), oracle, "static 2-engine fleet must match the 1-engine oracle");

    let mut elastic = two.clone();
    elastic.rl.fleet_schedule = vec![
        FleetEvent { iter: 1, join: 1, leave: 0 },
        FleetEvent { iter: 2, join: 0, leave: 1 },
    ];
    assert_eq!(
        run(&elastic),
        oracle,
        "elastic join/drain fleet must match the 1-engine oracle at temperature 1.0"
    );
}

/// Property (real engines): permuting admission order and the 2-engine
/// assignment of a fixed request set never changes any request's sampled
/// token or logprob stream. The oracle is a single engine serving the
/// requests in id order; each case re-serves them across two engines in a
/// random order with a random split. Engines are reused across cases, so
/// warm prefix caches are part of the property (cache hits must not shift
/// the streams either).
#[test]
fn admission_order_and_placement_never_change_request_streams() {
    use pa_rl::util::prop::{self, PropConfig};
    let Some((cfg, dir)) = artifacts() else { return };
    let sampler = SamplerCfg { temperature: 1.0, top_p: 0.95, top_k: 8 };
    let run_seed = 0xA11CE;
    let mk_engine = || {
        let rt = Runtime::load_validated(&dir, &cfg).unwrap();
        let params = rt.init_params(7).unwrap();
        let mut e = Engine::new(cfg.clone(), rt, run_seed);
        e.set_sampler(sampler);
        e.set_weights(&params).unwrap();
        e
    };

    let mut loader = DataLoader::new(cfg.data.clone());
    let prompts = loader.next_batch(6);
    let n = prompts.len();
    let req = |id: usize| GenRequest {
        request_id: id as u64,
        prompt: prompts[id].tokens.clone(),
        ..Default::default()
    };

    let mut oracle_engine = mk_engine();
    let results = oracle_engine.generate_all((0..n).map(req).collect()).unwrap();
    let mut oracle: Vec<(Vec<u32>, Vec<f32>)> = vec![(vec![], vec![]); n];
    for r in &results {
        oracle[r.request_id as usize] = (r.tokens.clone(), r.logprobs.clone());
    }

    let mut fleet = [mk_engine(), mk_engine()];
    prop::check(
        "admission-permutation-placement-independence",
        // Real-engine cases are expensive; a handful suffice — the mock-level
        // sweep lives in engine::chunked's proptests.
        PropConfig { cases: 6, shrink_rounds: 2, ..PropConfig::default() },
        |rng, _| {
            let mut perm: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                perm.swap(i, rng.range(0, i + 1));
            }
            let assign: Vec<usize> = (0..n).map(|_| rng.range(0, 2)).collect();
            (perm, assign)
        },
        |(perm, assign)| {
            for (e, engine) in fleet.iter_mut().enumerate() {
                let reqs: Vec<GenRequest> =
                    perm.iter().filter(|&&id| assign[id] == e).map(|&id| req(id)).collect();
                if reqs.is_empty() {
                    continue;
                }
                for r in engine.generate_all(reqs).map_err(|er| er.to_string())? {
                    let id = r.request_id as usize;
                    if (r.tokens.clone(), r.logprobs.clone()) != oracle[id] {
                        return Err(format!(
                            "request {id} diverged on engine {e}: {:?} vs oracle {:?}",
                            r.tokens, oracle[id].0
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Elastic async smoke: join + drain mid-run under periodic asynchrony keeps
/// the run strictly on-policy (the joiner is weight-synced before work) and
/// the per-iteration engine counts and metric deltas stay self-consistent —
/// in particular cumulative counters must not run backwards when the drained
/// engine stops reporting (its history moves to the retired baseline).
#[test]
fn elastic_async_run_stays_on_policy_and_consistent() {
    use pa_rl::config::FleetEvent;
    let Some((mut cfg, dir)) = artifacts() else { return };
    cfg.rl.n_engines = 1; // the join crosses the store-activation threshold
    cfg.rl.fleet_schedule = vec![
        FleetEvent { iter: 1, join: 2, leave: 0 },
        FleetEvent { iter: 2, join: 0, leave: 1 },
    ];
    let opts = DriverOpts { mode: Mode::Async, spa: false, seed: 29 };
    let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
    assert_eq!(driver.n_engines(), 1);
    let report = driver.run(3).unwrap();
    assert_eq!(driver.n_engines(), 2);
    let engines: Vec<usize> = report.iters.iter().map(|i| i.engines).collect();
    assert_eq!(engines, vec![1, 3, 2]);
    assert_eq!(report.iters[1].engines_joined, 2);
    assert_eq!(report.iters[2].engines_left, 1);
    for it in &report.iters {
        assert_eq!(it.staleness_mean, 0.0, "elastic async must stay on-policy");
        assert!(it.train_input_tokens > 0);
    }
    // The 1 -> 3 join activated the shared store mid-run.
    let stats = driver.store_stats().expect("store active after the join");
    assert!(stats.fetches > 0, "post-join admissions must consult the store");
}

/// Acceptance (bubble attribution): a `metrics.level = "full"` run accounts
/// every deployed engine device-second — per iteration, producer idle plus
/// measured engine busy time covers `wall * E` within 1% — and exports a
/// Perfetto-loadable `trace.json` plus per-iteration snapshots carrying the
/// same phase split it reports.
#[test]
fn full_run_phase_attribution_accounts_wall_clock_and_exports_trace() {
    use pa_rl::metrics::{validate_chrome_trace, MetricsLevel};
    use pa_rl::util::json::Json;
    let Some((mut cfg, dir)) = artifacts() else { return };
    cfg.name = "it_phases".into();
    cfg.metrics.level = MetricsLevel::Full;
    let opts = DriverOpts { mode: Mode::Async, spa: false, seed: 19 };
    let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
    let report = driver.run(2).unwrap();
    assert_eq!(report.iters.len(), 2);
    for it in &report.iters {
        let p = &it.phases;
        let deployed_engine_s = it.wall_seconds * it.engines as f64;
        let trainer_busy = it.stats.train_seconds + it.stats.update_seconds;
        let engine_busy = p.useful_compute_s - trainer_busy;
        assert!(engine_busy >= 0.0, "negative engine busy time");
        // The attribution identity: idle + busy covers the engines' deployed
        // device-seconds. 1% slack absorbs engine-counter vs driver-wall
        // clock skew (the only way the two sides can disagree).
        assert!(
            (p.producer_idle_s + engine_busy - deployed_engine_s).abs()
                <= 0.01 * deployed_engine_s + 1e-9,
            "iter {}: idle {:.4} + busy {:.4} != deployed {:.4} (past 1%)",
            it.iter,
            p.producer_idle_s,
            engine_busy,
            deployed_engine_s
        );
        assert!(
            p.pipeline_efficiency > 0.0 && p.pipeline_efficiency <= 1.0,
            "efficiency {} out of (0, 1]",
            p.pipeline_efficiency
        );
        assert!(p.consumer_wait_s >= 0.0 && p.sync_overhead_s >= 0.0);
        assert!(it.requests.is_some(), "full mode must stamp request timelines");
    }

    // trace.json: written by the run, parses, Chrome trace-event schema-valid.
    let runs = dir.parent().unwrap().join("runs").join("it_phases");
    let trace_txt =
        std::fs::read_to_string(runs.join("trace.json")).expect("full run writes trace.json");
    let doc = Json::parse(&trace_txt).expect("trace.json must parse");
    validate_chrome_trace(&doc).expect("trace.json must satisfy the Perfetto schema");

    // Per-iteration snapshots carry the exact phase split the report carries.
    for it in &report.iters {
        let snap = std::fs::read_to_string(runs.join(format!("iter_{:04}.json", it.iter)))
            .expect("full run writes per-iteration snapshots");
        let j = Json::parse(&snap).unwrap();
        let eff = j
            .req("phases")
            .and_then(|p| p.req_f64("pipeline_efficiency"))
            .expect("snapshot carries the phase split");
        assert!(
            (eff - it.phases.pipeline_efficiency).abs() < 1e-9,
            "snapshot/report efficiency mismatch at iter {}",
            it.iter
        );
    }
    assert!(runs.join("metrics.prom").exists(), "Prometheus export written");
}

#[test]
fn spa_driver_matches_standard_training_direction() {
    // SPA and standard async runs from the same seed should produce similar
    // (not identical — different micro-batch partitioning changes nothing
    // mathematically, SPA == per-sample exactly, so updates should be CLOSE
    // up to adam noise) first-iteration losses.
    let Some((cfg, dir)) = artifacts() else { return };
    let mut losses = Vec::new();
    for spa in [false, true] {
        let opts = DriverOpts { mode: Mode::Async, spa, seed: 31 };
        let mut driver = Driver::new(cfg.clone(), &dir, opts).unwrap();
        let rep = driver.run(1).unwrap();
        losses.push(rep.iters[0].stats.loss);
    }
    assert!(
        (losses[0] - losses[1]).abs() < 0.05,
        "SPA vs standard first-iteration loss diverged: {losses:?}"
    );
}
