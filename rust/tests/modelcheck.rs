//! Schedule-exploring model-check suite for the producer-consumer pipeline.
//!
//! Compiled only under `--features pa_modelcheck`: the `check::sync` /
//! `check::thread` shims then route every lock, channel and atomic through
//! the deterministic cooperative scheduler ([`pa_rl::check::Checker`]),
//! which explores thousands of distinct thread interleavings per test and
//! reports any deadlock, lock-order inversion or assertion failure together
//! with a replayable schedule string (see `docs/CONCURRENCY.md`).
//!
//! The scenarios pin the cross-thread invariants the stress tests can only
//! sample probabilistically:
//!
//! * store: 2 publishers + 1 evictor on a 2-shard store — lease pinning and
//!   the capacity budget hold under *every* explored interleaving, and the
//!   post-run churn deterministically exercises eviction;
//! * drain handshake: the bounded-queue flush + ack protocol between a
//!   worker and the driver pump never deadlocks even when the queue is
//!   shallower than the backlog;
//! * metrics: registry snapshots interleaved with writers are coherent
//!   (monotone counters, bounded mid-flight reads, exact final totals);
//! * stall watchdog: the one-shot latch plus the full diagnostic snapshot
//!   path (registry snapshot → `stall_snapshot_json` → re-parse, Prometheus
//!   export, last-span-per-lane) never deadlocks and stays internally
//!   consistent while engine threads race counter/gauge/span writes;
//! * drain re-route: jobs regrouped after an engine drain are re-dispatched
//!   group-affine with no loss, no duplication, and only to live engines;
//! * shared control loops: the *same* `ctrl::pump_drain_ack` /
//!   `ctrl::recv_step` code the driver and the deterministic-executor
//!   fleet harness (`sim::fleet`) both run is explored here over modeled
//!   threads — drain handshakes never deadlock, and a worker dying
//!   mid-drain (or a whole fleet dying mid-batch) surfaces an error
//!   instead of hanging the control loop;
//! * seeded deadlock: an intentionally inverted shard-lock order is caught —
//!   as a lock-order inversion by the static cycle check, and as an actual
//!   deadlock (with a schedule that replays) when that check is disabled.

#![cfg(feature = "pa_modelcheck")]

use pa_rl::check::sync::mpsc;
use pa_rl::check::thread;
use pa_rl::check::{replay, Checker, FailureKind};
use pa_rl::coordinator::ctrl::{pump_drain_ack, recv_step, AckPoll, ChannelSource};
use pa_rl::coordinator::driver::group_jobs_by_prompt;
use pa_rl::coordinator::route::{affinity_key, route_group_residency, RouteKind, WarmthMap};
use pa_rl::coordinator::{
    stall_snapshot_json, DrainAck, FleetCtrl, GenJob, RecvStep, ScoredRollout, StallWatchdog,
    WorkerStats,
};
use pa_rl::engine::kvcache::EvictPolicy;
use pa_rl::engine::{EngineStats, GenRequest};
use pa_rl::metrics::{Registry, Trace};
use pa_rl::util::json::Json;
use pa_rl::store::{SharedKvStore, StoreCfg};
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// Schedules each scenario must explore (the acceptance floor); the cap is
/// set above it so exploration is cut off only after clearing the bar.
const MIN_SCHEDULES: usize = 1000;
const MAX_SCHEDULES: usize = 2048;

const RE: usize = 4; // row elements per token

fn rows_for(seq: &[u32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(seq.len() * RE);
    for (i, &t) in seq.iter().enumerate() {
        for e in 0..RE {
            out.push((t as usize * 31 + i * 7 + e) as f32);
        }
    }
    out
}

/// Two publishers sharing templates race one evictor on a deliberately tiny
/// two-shard store. Under every interleaving: a held lease pins its chain
/// (re-fetch returns identical bytes), the block budget holds at every
/// observation point, and the structural `check()` passes after the joins.
/// The single-threaded churn tail then overflows the budget with no leases
/// outstanding, so eviction is exercised *deterministically* — the property
/// `tests/store_stress.rs` can only observe by luck.
#[test]
fn store_two_publishers_one_evictor_invariants() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let store = Arc::new(SharedKvStore::new(StoreCfg {
            block_tokens: 2,
            capacity_blocks: 4,
            policy: EvictPolicy::Lru,
            shards: 2,
        }));
        store.set_version(1);
        let mut handles = Vec::new();
        for t in 0..2u32 {
            let store = store.clone();
            handles.push(thread::spawn(move || {
                let p: Vec<u32> = vec![t * 16 + 1, t * 16 + 2];
                store.publish(&p, &rows_for(&p), None, 1);
                if let Some(f) = store.fetch_longest(&p, 0, 1) {
                    // Lease pinning: while `f.lease` is held the chain must
                    // stay fetchable and bit-identical, whatever the evictor
                    // thread is doing on the other shard lock.
                    let again = store
                        .fetch_longest(&p[..f.len], 0, 1)
                        .expect("leased chain must stay fetchable");
                    assert_eq!(again.len, f.len, "leased coverage shrank");
                    assert_eq!(again.rows, f.rows, "leased chain mutated");
                    store.release(again.lease);
                    store.release(f.lease);
                }
                assert!(store.live_blocks() <= store.capacity_blocks());
            }));
        }
        {
            let store = store.clone();
            handles.push(thread::spawn(move || {
                // Evictor: cold prefixes churn the heap under the tiny budget.
                let cold: Vec<u32> = vec![100, 101, 102, 103];
                store.publish(&cold, &rows_for(&cold), None, 1);
                assert!(store.live_blocks() <= store.capacity_blocks());
            }));
        }
        for h in handles {
            h.join().expect("model thread panicked");
        }
        assert_eq!(store.leased_blocks(), 0, "leases leaked past join");
        store.check().expect("structural invariants after joins");

        // Deterministic eviction tail: 4 distinct 2-block prefixes into a
        // 4-block store with zero leases held — some shard must evict.
        for c in 0..4u32 {
            let p: Vec<u32> = vec![200 + c * 8, 201 + c * 8, 202 + c * 8, 203 + c * 8];
            store.publish(&p, &rows_for(&p), None, 1);
        }
        assert!(store.stats().evictions > 0, "overflow churn must evict");
        assert!(store.live_blocks() <= store.capacity_blocks());
        store.check().expect("structural invariants after churn");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// Miniature of [`Driver::drain_engine`]'s handshake with the exact channel
/// topology of the real pipeline: an unbounded control inbox, a bounded
/// rollout queue *shallower than the worker's backlog*, and an ack channel.
/// The driver must keep pumping the queue while waiting for the ack or the
/// worker's blocking flush wedges both sides — the checker proves no
/// explored schedule deadlocks and no rollout is lost.
#[test]
fn drain_handshake_never_deadlocks() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let (inbox_tx, inbox_rx) = mpsc::channel::<u32>();
        let (queue_tx, queue_rx) = mpsc::sync_channel::<u32>(2); // cap < backlog
        let (ack_tx, ack_rx) = mpsc::channel::<u32>();
        let worker = thread::spawn(move || {
            let _drain = inbox_rx.recv().expect("drain request");
            for i in 0..3u32 {
                queue_tx.send(i).expect("queue receiver alive");
            }
            ack_tx.send(0).expect("driver alive");
        });
        inbox_tx.send(7).expect("worker alive");
        // The drain pump: poll for the ack, draining the rollout queue in
        // between so the worker's bounded sends can make progress.
        let mut got = 0u32;
        loop {
            match ack_rx.try_recv() {
                Ok(_) => break,
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
            if queue_rx.recv_timeout(Duration::from_millis(2)).is_ok() {
                got += 1;
            }
        }
        while queue_rx.try_recv().is_ok() {
            got += 1;
        }
        worker.join().expect("worker panicked");
        assert_eq!(got, 3, "drain lost rollouts");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

fn mk_rollout(request_id: u64) -> ScoredRollout {
    ScoredRollout {
        request_id,
        prompt_id: request_id / 2,
        sample_idx: (request_id % 2) as usize,
        weight_version: 1,
        tokens: vec![1, 2],
        logprobs: vec![-0.1, -0.2],
        reward: 0.0,
        gen_seconds: 0.1,
        engine_idx: 0,
        timeline: Default::default(),
    }
}

fn mk_job(prompt_id: u64, sample_idx: usize, prompt: &[u32]) -> GenJob {
    GenJob {
        prompt_id,
        sample_idx,
        request: GenRequest {
            request_id: prompt_id * 10 + sample_idx as u64,
            prompt: prompt.to_vec(),
            timeline: Default::default(),
        },
        answer: 0,
    }
}

/// Probe a drain-ack channel the way both control-loop substrates do.
fn ack_probe(rx: &mpsc::Receiver<DrainAck>) -> AckPoll {
    match rx.try_recv() {
        Ok(a) => AckPoll::Ready(Box::new(a)),
        Err(mpsc::TryRecvError::Empty) => AckPoll::Pending,
        Err(mpsc::TryRecvError::Disconnected) => AckPoll::Gone,
    }
}

/// The drain handshake ported onto the shared control loop: the *same*
/// [`pump_drain_ack`] the driver and the deterministic-executor fleet
/// harness both call, here over modeled threads and real message types.
/// The worker flushes a backlog deeper than the queue bound before acking
/// with pending jobs; under every explored interleaving the pump keeps the
/// queue moving (no deadlock) and nothing — flushed or pending — is lost.
#[test]
fn drain_handshake_through_shared_pump_never_deadlocks() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let (queue_tx, queue_rx) = mpsc::sync_channel::<ScoredRollout>(2); // cap < backlog
        let (ack_tx, ack_rx) = mpsc::channel::<DrainAck>();
        let (inbox_tx, inbox_rx) = mpsc::channel::<u32>();
        let worker = thread::spawn(move || {
            let _drain = inbox_rx.recv().expect("drain request");
            for i in 0..3u64 {
                queue_tx.send(mk_rollout(i)).expect("driver alive");
            }
            ack_tx
                .send(DrainAck {
                    pending: vec![mk_job(7, 0, &[1; 8]), mk_job(7, 1, &[1; 8])],
                    stats: EngineStats::default(),
                    cache: None,
                })
                .expect("driver alive");
        });
        inbox_tx.send(0).expect("worker alive");
        let mut src = ChannelSource { rx: &queue_rx, dead: || false };
        let (ack, pumped) =
            pump_drain_ack(&mut src, 0, || ack_probe(&ack_rx)).expect("pump must not error");
        // Flushed rollouts either arrived during the pump or still sit in
        // the queue; together with the ack's pending jobs all 3+2 survive.
        let mut flushed = pumped.len();
        while queue_rx.try_recv().is_ok() {
            flushed += 1;
        }
        assert_eq!(flushed, 3, "drain lost flushed rollouts");
        assert_eq!(ack.pending.len(), 2, "drain lost pending jobs");
        worker.join().expect("worker panicked");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// Satellite regression: a worker that dies mid-drain — ack channel dropped
/// without a send — must surface [`pump_drain_ack`]'s liveness error on
/// every explored schedule, never hang the control loop.
#[test]
fn worker_death_mid_drain_surfaces_pump_error_not_hang() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let (queue_tx, queue_rx) = mpsc::sync_channel::<ScoredRollout>(1);
        let (ack_tx, ack_rx) = mpsc::channel::<DrainAck>();
        let worker = thread::spawn(move || {
            // One last completion, then death without an ack.
            queue_tx.send(mk_rollout(0)).expect("driver alive");
            drop(ack_tx);
        });
        let mut src = ChannelSource { rx: &queue_rx, dead: || false };
        let err = pump_drain_ack(&mut src, 5, || ack_probe(&ack_rx))
            .expect_err("dead worker must error the pump");
        assert!(
            err.to_string().contains("engine-5 exited without acking the drain"),
            "wrong error: {err:#}"
        );
        worker.join().expect("worker panicked");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// The driver's `recv_rollout` liveness poll, through the shared
/// [`recv_step`]: a worker completes one of its two jobs and then dies.
/// Under every interleaving the completed rollout is consumed, and the
/// loop then fails fast with the dead-fleet error instead of waiting
/// forever on a queue nobody will ever feed again.
#[test]
fn recv_step_fails_fast_when_all_workers_die_with_work_outstanding() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let (queue_tx, queue_rx) = mpsc::sync_channel::<ScoredRollout>(2);
        // Worker liveness the way the driver models it: a handle the dead
        // probe can interrogate — here a channel whose sender dies with
        // the worker.
        let (live_tx, live_rx) = mpsc::channel::<()>();
        let worker = thread::spawn(move || {
            let _live = live_tx; // dropped on exit = worker death
            queue_tx.send(mk_rollout(0)).expect("driver alive");
            // ...dies with the second job still owed.
        });
        let mut src = ChannelSource {
            rx: &queue_rx,
            dead: || matches!(live_rx.try_recv(), Err(mpsc::TryRecvError::Disconnected)),
        };
        let mut watchdog = None;
        let mut got = 0u32;
        let err = loop {
            match recv_step(&mut src, &mut watchdog, 0.002) {
                Ok(RecvStep::Got(_)) => got += 1,
                Ok(RecvStep::Waiting { .. }) => {}
                Err(e) => break e,
            }
        };
        assert_eq!(got, 1, "the completed rollout must be consumed, not dropped");
        assert!(
            err.to_string().contains("all engine workers exited"),
            "wrong error: {err:#}"
        );
        worker.join().expect("worker panicked");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// Registry snapshots racing writer threads: mid-flight reads are bounded
/// by the true totals, two sequential snapshots never observe a counter
/// moving backwards (no torn reads through the shims), and once the writers
/// join the totals are exact.
#[test]
fn registry_snapshot_vs_writers_is_coherent() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let reg = Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..2 {
            let reg = reg.clone();
            handles.push(thread::spawn(move || {
                for _ in 0..2 {
                    reg.counter("writes").inc();
                    reg.histogram("lat").observe(1.0);
                }
            }));
        }
        let counter_of = |s: &pa_rl::metrics::RegistrySnapshot| {
            s.counters.iter().find(|(n, _)| n == "writes").map(|&(_, v)| v).unwrap_or(0)
        };
        let hist_count_of = |s: &pa_rl::metrics::RegistrySnapshot| {
            s.hists.iter().find(|(n, _)| n == "lat").map(|(_, h)| h.count()).unwrap_or(0)
        };
        let s1 = reg.snapshot();
        let s2 = reg.snapshot();
        assert!(counter_of(&s1) <= 4, "counter over-counted mid-flight");
        assert!(hist_count_of(&s1) <= 4, "histogram over-counted mid-flight");
        assert!(
            counter_of(&s2) >= counter_of(&s1),
            "counter moved backwards between snapshots"
        );
        for h in handles {
            h.join().expect("writer panicked");
        }
        let fin = reg.snapshot();
        assert_eq!(counter_of(&fin), 4, "final counter total");
        let lat = fin
            .hists
            .iter()
            .find(|(n, _)| n == "lat")
            .map(|(_, h)| h.clone())
            .expect("lat histogram present");
        assert_eq!(lat.count(), 4, "final histogram count");
        assert!((lat.sum() - 4.0).abs() < 1e-9, "final histogram sum");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// The stall-watchdog diagnostic path raced against live telemetry writers:
/// two "engine" threads hammer the exact metrics the driver maintains
/// (the `request.completed` counter, a `phase.*` gauge, spans on their own
/// lanes) while the "driver" thread crosses the watchdog window and
/// assembles the same payload [`Driver::dump_stall_snapshot`] writes —
/// registry snapshot, last-span-per-lane, [`stall_snapshot_json`], and the
/// Prometheus export. Under every explored interleaving: nothing deadlocks
/// (the snapshot takes the same locks the writers hold), the one-shot latch
/// fires exactly once, mid-flight reads are bounded by the true totals, the
/// JSON round-trips through the parser, and no lane appears twice in the
/// last-span listing.
#[test]
fn watchdog_snapshot_vs_racing_telemetry_writers() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(|| {
        let reg = Arc::new(Registry::new());
        let trace = Arc::new(Trace::new());
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let reg = reg.clone();
            let trace = trace.clone();
            handles.push(thread::spawn(move || {
                let lane = format!("engine{t}");
                for i in 0..2 {
                    reg.counter("request.completed").inc();
                    reg.gauge("phase.producer_idle_s").set((t * 2 + i) as f64);
                    trace.record_abs(&lane, "generate", i as f64, i as f64 + 0.5);
                }
            }));
        }

        // Driver side: accumulate queue silence past the window; the latch
        // must fire exactly once however the writers interleave around it.
        let mut dog = StallWatchdog::new(1.0);
        assert!(!dog.note_timeout(0.6), "fired before the window");
        assert!(dog.note_timeout(0.6), "must latch when silence crosses the window");
        assert!(!dog.note_timeout(5.0), "one-shot latch fired twice");

        // The snapshot taken mid-race: the same sequence of lock
        // acquisitions `dump_stall_snapshot` performs.
        let snap = reg.snapshot();
        let spans = trace.last_span_per_lane();
        let mut lanes = HashSet::new();
        for s in &spans {
            assert!(lanes.insert(s.lane.clone()), "lane {} listed twice", s.lane);
            assert!(s.end_s >= s.start_s, "span ends before it starts");
        }
        let completed = snap
            .counters
            .iter()
            .find(|(n, _)| n == "request.completed")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        assert!(completed <= 4, "counter over-counted mid-flight");

        let stats: Vec<WorkerStats> = (0..2)
            .map(|e| WorkerStats {
                engine_idx: e,
                engine: EngineStats { busy_seconds: 1.5, ..Default::default() },
                cache: None,
                warm: Vec::new(),
                pending: 3,
                active: 1,
            })
            .collect();
        let doc = stall_snapshot_json(
            dog.stalled_s(),
            6,
            1,
            &[2, 1],
            4,
            Some(7),
            2,
            &stats,
            Some(&snap),
            &spans,
        );
        let parsed = Json::parse(&doc.to_pretty()).expect("snapshot must round-trip");
        assert_eq!(parsed.req_usize("responsive_engines").unwrap(), 2);
        assert!(parsed.req_f64("stalled_s").unwrap() >= 1.0, "latched below the window");
        let listed = parsed
            .get("last_span_per_lane")
            .and_then(Json::as_arr)
            .map_or(0, <[Json]>::len);
        assert_eq!(listed, spans.len(), "span listing lost lanes");

        // Prometheus export takes the registry locks once more, still racing
        // any writer that hasn't finished.
        let prom = reg.to_prometheus();
        assert!(prom.contains("pa_rl_request_completed"), "counter missing from export");
        assert!(prom.contains("pa_rl_phase_producer_idle_s"), "gauge missing from export");

        for h in handles {
            h.join().expect("writer panicked");
        }
        let fin = reg.snapshot();
        let total = fin
            .counters
            .iter()
            .find(|(n, _)| n == "request.completed")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        assert_eq!(total, 4, "final counter total");
        assert_eq!(trace.last_span_per_lane().len(), 2, "one last-span per engine lane");
    });
    report.assert_ok();
    assert!(
        report.schedules >= MIN_SCHEDULES,
        "explored only {} schedules",
        report.schedules
    );
}

/// Drain re-route regression: after an engine drains, its returned jobs are
/// regrouped prompt-affine ([`group_jobs_by_prompt`]) and the warmth map
/// forgets the drained engine ([`WarmthMap::remove_engine`]); every group
/// then re-dispatches to a *live* engine with no job lost or duplicated,
/// surviving warm templates keep their affinity, and forgotten ones fall
/// back to the deterministic hash spread. Pure sequential logic — kept here
/// because it is the deterministic half of the drain story the handshake
/// test above model-checks.
#[test]
fn drain_reroute_preserves_jobs_and_targets_live_engines() {
    let bt = 4usize;
    let prompts: Vec<Vec<u32>> = (0..4u32).map(|p| vec![p + 1; 8]).collect();

    // Engine 1 was the warm home for templates 1..4; template 0 lives on
    // engine 0. Engine 1 drains and the fleet compacts to 2 engines.
    let mut warmth = WarmthMap::new();
    for (i, p) in prompts.iter().enumerate() {
        let (key, _) = affinity_key(p, bt);
        warmth.note(key, if i == 0 { 0 } else { 1 }, p.len());
    }
    warmth.remove_engine(1, 2);

    // The drained worker hands back its in-flight jobs in completion order
    // (interleaved across prompts, like a real inbox drain).
    let mk = |prompt_id: u64, sample_idx: usize| GenJob {
        prompt_id,
        sample_idx,
        request: GenRequest {
            request_id: prompt_id * 10 + sample_idx as u64,
            prompt: prompts[prompt_id as usize].clone(),
            timeline: Default::default(),
        },
        answer: 0,
    };
    let jobs: Vec<GenJob> =
        (0..2usize).flat_map(|s| (0..4u64).map(move |p| mk(p, s))).collect();

    let groups = group_jobs_by_prompt(jobs);
    assert_eq!(groups.len(), 4, "one group per prompt");

    let load = vec![0usize; 2];
    let mut seen = HashSet::new();
    for g in &groups {
        assert_eq!(g.len(), 2, "group-affine re-dispatch keeps groups whole");
        let prompt = &g[0].request.prompt;
        assert!(g.iter().all(|j| &j.request.prompt == prompt), "mixed group");
        let (engine, kind) = route_group_residency(prompt, bt, &load, 4, &warmth, 0);
        assert!(engine < 2, "routed to a drained engine");
        let (key, _) = affinity_key(prompt, bt);
        if warmth.lookup(key).is_some() {
            assert_eq!(kind, RouteKind::Warm, "surviving template lost affinity");
            assert_eq!(engine, 0, "warm template left its home");
        } else {
            assert_eq!(kind, RouteKind::Hashed, "forgotten template must hash");
        }
        for j in g {
            assert!(seen.insert(j.request.request_id), "job duplicated");
        }
    }
    assert_eq!(seen.len(), 8, "job lost in re-route");
}

/// The same re-route story through [`FleetCtrl`] — the shared routing and
/// accounting object the driver and the simulated fleet both drive. A
/// drained tail engine hands back interleaved jobs; after
/// `remove_tail_engine` + `reroute_drained` every group lands whole on a
/// live engine, no job is lost or duplicated, the outstanding count is
/// untouched (re-routed jobs never stopped being outstanding) and the load
/// signal moves onto the survivors.
#[test]
fn drain_reroute_via_fleet_ctrl_preserves_jobs_and_accounting() {
    let bt = 4usize;
    let mut ctrl = FleetCtrl::new(3, true, 0, 4, bt);
    let prompts: Vec<Vec<u32>> = (0..4u32).map(|p| vec![p + 1; 8]).collect();

    // Dispatch one group per prompt; remember which landed on the tail.
    let mut on_tail: Vec<GenJob> = Vec::new();
    for (pid, p) in prompts.iter().enumerate() {
        let idx = ctrl.pick_engine(p, true, || 0);
        ctrl.note_dispatch(idx, 2);
        if idx == 2 {
            on_tail.push(mk_job(pid as u64, 0, p));
            on_tail.push(mk_job(pid as u64, 1, p));
        }
    }
    let outstanding_before = ctrl.outstanding();
    assert_eq!(outstanding_before, 8);
    let tail_jobs = on_tail.len();

    let departed = ctrl.remove_tail_engine();
    assert_eq!(departed, 2);
    let placed = ctrl.reroute_drained(on_tail, |_| 0);

    let mut seen = HashSet::new();
    let mut replaced = 0usize;
    for (target, jobs) in &placed {
        assert!(*target < ctrl.engines(), "re-routed to a drained engine");
        let prompt = &jobs[0].request.prompt;
        assert!(jobs.iter().all(|j| &j.request.prompt == prompt), "group split in re-route");
        for j in jobs {
            assert!(seen.insert(j.request.request_id), "job duplicated in re-route");
            replaced += 1;
        }
    }
    assert_eq!(replaced, tail_jobs, "job lost in re-route");
    assert_eq!(
        ctrl.outstanding(),
        outstanding_before,
        "re-route must not change the outstanding count"
    );
    assert_eq!(
        ctrl.load().iter().sum::<usize>(),
        outstanding_before,
        "load signal must move onto the survivors"
    );
}

/// The seeded bug: two threads taking the same pair of shard locks in
/// opposite orders — the classic ABBA deadlock, reachable only under an
/// adversarial preemption between the two acquisitions.
fn inverted_shard_locks() {
    let store = Arc::new(SharedKvStore::new(StoreCfg {
        block_tokens: 2,
        capacity_blocks: 8,
        policy: EvictPolicy::Lru,
        shards: 2,
    }));
    let s2 = store.clone();
    let a = thread::spawn(move || {
        s2.lock_pair_in_order(0, 1);
    });
    let b = thread::spawn(move || {
        store.lock_pair_in_order(1, 0);
    });
    a.join().expect("thread a");
    b.join().expect("thread b");
}

/// With the static cycle check disabled, the checker must *schedule* its
/// way into the ABBA deadlock, report it, and hand back a schedule string
/// that deterministically replays the exact interleaving.
#[test]
fn seeded_shard_deadlock_is_caught_and_replays() {
    let report = Checker::new()
        .detect_lock_order(false)
        .max_schedules(MAX_SCHEDULES)
        .check(inverted_shard_locks);
    let failure = report.failure.expect("checker must find the ABBA deadlock");
    assert_eq!(failure.kind, FailureKind::Deadlock, "wrong failure: {failure}");
    assert!(!failure.schedule.is_empty(), "deadlock without a schedule");

    let again = replay(inverted_shard_locks, &failure.schedule);
    let f2 = again.failure.expect("replay must reproduce the deadlock");
    assert_eq!(f2.kind, FailureKind::Deadlock, "replay found something else");
}

/// With the cycle check on (the default), the same bug is flagged as a
/// lock-order inversion on the *first* schedule that merely acquires the
/// locks in both orders — no adversarial interleaving required.
#[test]
fn inverted_shard_locks_flagged_without_needing_the_deadlock_schedule() {
    let report = Checker::new().max_schedules(MAX_SCHEDULES).check(inverted_shard_locks);
    let failure = report.failure.expect("cycle check must flag the inversion");
    assert_eq!(failure.kind, FailureKind::LockOrderInversion, "wrong failure: {failure}");
}
