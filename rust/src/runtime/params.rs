//! Model parameter sets: host-resident (the currency of weight sync) and
//! device-resident (uploaded once per iteration / per publication).
//!
//! The paper's weight-synchronisation protocol (Algorithm 1 line 3) moves the
//! policy weights from the trainer to every rollout worker at iteration
//! boundaries. Here `HostParams` is the published snapshot — an `Arc` swap in
//! the coordinator — and each engine instance uploads it into its *own* PJRT
//! client's buffers (`DeviceParams`), which models the per-instance weight
//! transfer the paper pays on NPU/GPU clusters (and we measure its cost).

use super::tensor::Tensor;
use super::Runtime;
use anyhow::{bail, Result};

/// A complete set of model parameters on the host, tagged with the policy
/// version that produced it (iteration index). The version tag is what makes
/// the on-policy invariant checkable end-to-end.
#[derive(Debug, Clone)]
pub struct HostParams {
    /// Tensors in manifest param-table order.
    pub tensors: Vec<Tensor>,
    /// Policy version: 0 = initial weights, t = after iteration t's update.
    pub version: u64,
}

impl HostParams {
    /// Validate count/shapes against the runtime's manifest.
    pub fn validate(&self, rt: &Runtime) -> Result<()> {
        let specs = &rt.manifest().params;
        if specs.len() != self.tensors.len() {
            bail!("param count mismatch: {} vs manifest {}", self.tensors.len(), specs.len());
        }
        for (t, s) in self.tensors.iter().zip(specs) {
            if t.shape != s.shape {
                bail!("param '{}' shape {:?} != manifest {:?}", s.name, t.shape, s.shape);
            }
        }
        Ok(())
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Approximate bytes (f32).
    pub fn bytes(&self) -> usize {
        self.elements() * 4
    }

    /// Upload to device buffers on `rt`'s client.
    pub fn upload(&self, rt: &Runtime) -> Result<DeviceParams> {
        let bufs = self
            .tensors
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceParams { bufs, version: self.version })
    }

    /// Zero-valued params with manifest shapes (optimizer-state init).
    pub fn zeros_like(rt: &Runtime) -> HostParams {
        let tensors = rt
            .manifest()
            .params
            .iter()
            .map(|s| Tensor::zeros_f32(&s.shape))
            .collect();
        HostParams { tensors, version: 0 }
    }
}

/// Parameters uploaded to one PJRT client. NOT `Send` (PJRT buffers are tied
/// to their client's thread in this crate); each thread owns its copy.
pub struct DeviceParams {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub version: u64,
}

impl DeviceParams {
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}
