//! Host-side tensors crossing the PJRT boundary.
//!
//! `Tensor` is the thread-safe (plain `Vec`-backed) currency between the
//! coordinator's threads; each thread's [`super::Runtime`] converts it to/from
//! `xla::Literal` at its own PJRT client boundary.

use anyhow::{bail, Context, Result};

/// Element type of a tensor (the subset pa-rl uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Tensor payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: shape + data. Scalars have an empty shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TData,
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TData::F32(data) }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TData::I32(data) }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor { shape: vec![], data: TData::F32(vec![x]) }
    }

    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor { shape: vec![], data: TData::I32(vec![x]) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TData::F32(_) => DType::F32,
            TData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TData::F32(v) => v.len(),
            TData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutable view of f32 data (host-side KV-cache row surgery).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self.data {
            TData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match &self.data {
            TData::F32(v) if v.len() == 1 => Ok(v[0]),
            TData::I32(v) if v.len() == 1 => Ok(v[0] as f32),
            _ => bail!("tensor is not a scalar (shape {:?})", self.shape),
        }
    }

    /// Convert to an `xla::Literal` (host-side copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TData::F32(v) => xla::Literal::vec1(v),
            TData::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).context("reshaping literal")
    }

    /// Read back from an `xla::Literal`.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape().context("literal shape")?;
        let arr = xla::ArrayShape::try_from(&shape).context("literal is not an array")?;
        let dims: Vec<usize> = arr.dims().iter().map(|&d| d as usize).collect();
        match arr.element_type() {
            xla::ElementType::F32 => Ok(Tensor::f32(lit.to_vec::<f32>()?, &dims)),
            xla::ElementType::S32 => Ok(Tensor::i32(lit.to_vec::<i32>()?, &dims)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = Tensor::scalar_i32(-7);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[-7]);
        assert!(back.shape.is_empty());
        assert_eq!(back.scalar().unwrap(), -7.0);
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }

    #[test]
    fn accessor_errors() {
        let t = Tensor::i32(vec![1, 2], &[2]);
        assert!(t.as_f32().is_err());
        assert!(t.scalar().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }
}
