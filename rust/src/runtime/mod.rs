//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! One `Runtime` per thread: the `xla` crate's `PjRtClient` is `Rc`-based
//! (not thread-safe to clone), so every engine instance and the trainer each
//! construct their own client and compile their own executables. This mirrors
//! the paper's decoupled deployment — training and inference run as separate
//! instances whose only coupling is the host-side weight publication at
//! iteration boundaries (plus the rollout queue).
//!
//! Execution model per call: host tensors are validated against the
//! manifest signature, uploaded as device buffers alongside any persistent
//! buffers the caller retained (params, KV cache), executed via
//! `execute_b`, and the tuple result is read back as host tensors.

pub mod manifest;
pub mod params;
pub mod tensor;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use params::{DeviceParams, HostParams};
pub use tensor::{DType, TData, Tensor};

use crate::config::Config;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// An argument to [`Runtime::run`]: either a host tensor (uploaded for this
/// call) or a persistent device buffer the caller keeps across calls.
pub enum Arg<'a> {
    Host(&'a Tensor),
    Buf(&'a xla::PjRtBuffer),
}

impl<'a> From<&'a Tensor> for Arg<'a> {
    fn from(t: &'a Tensor) -> Self {
        Arg::Host(t)
    }
}

impl<'a> From<&'a xla::PjRtBuffer> for Arg<'a> {
    fn from(b: &'a xla::PjRtBuffer) -> Self {
        Arg::Buf(b)
    }
}

/// Cumulative execution statistics (profiling / the timeline trace).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub calls: u64,
    pub exec_seconds: f64,
    pub upload_seconds: f64,
    pub download_seconds: f64,
    pub compile_seconds: f64,
}

/// Per-thread PJRT runtime bound to one artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, RunStats>>,
}

impl Runtime {
    /// Load the manifest and create a PJRT CPU client. Artifacts compile
    /// lazily on first use (see [`Runtime::prepare`] to force).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    /// Load and validate against a rust-side config.
    pub fn load_validated(dir: &Path, cfg: &Config) -> Result<Runtime> {
        let rt = Self::load(dir)?;
        rt.manifest.validate(cfg).context("manifest/config validation")?;
        Ok(rt)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Eagerly compile a set of artifacts (engines: prefill/decode; trainer:
    /// train_step/adam_update/...), so the first iteration isn't skewed.
    pub fn prepare(&self, names: &[&str]) -> Result<()> {
        for name in names {
            self.exe(name)?;
        }
        Ok(())
    }

    fn exe(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_seconds += dt;
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a host tensor to a device buffer on this runtime's client.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        match &t.data {
            TData::F32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.shape, None)
                .context("uploading f32 tensor"),
            TData::I32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.shape, None)
                .context("uploading i32 tensor"),
        }
    }

    /// Execute an artifact. `args` must match the manifest signature
    /// (host tensors are validated; persistent buffers are trusted — they
    /// were validated when uploaded). Returns the flattened tuple outputs.
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let exe = self.exe(name)?;
        let spec = self.manifest.artifact(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': {} args given, signature has {}",
                args.len(),
                spec.inputs.len()
            );
        }

        let t_up = Instant::now();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut ordered: Vec<(bool, usize)> = Vec::new(); // (is_owned, index)
        for (i, (arg, ispec)) in args.iter().zip(&spec.inputs).enumerate() {
            match arg {
                Arg::Host(t) => {
                    if t.shape != ispec.shape {
                        bail!(
                            "artifact '{name}' input {i} ('{}'): shape {:?} != expected {:?}",
                            ispec.name, t.shape, ispec.shape
                        );
                    }
                    if t.dtype() != ispec.dtype {
                        bail!(
                            "artifact '{name}' input {i} ('{}'): dtype {:?} != expected {:?}",
                            ispec.name, t.dtype(), ispec.dtype
                        );
                    }
                    owned.push(self.upload(t)?);
                    ordered.push((true, owned.len() - 1));
                }
                Arg::Buf(_) => ordered.push((false, i)),
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = ordered
            .iter()
            .map(|&(is_owned, idx)| {
                if is_owned {
                    &owned[idx]
                } else {
                    match args[idx] {
                        Arg::Buf(b) => b,
                        _ => unreachable!(),
                    }
                }
            })
            .collect();
        let upload_s = t_up.elapsed().as_secs_f64();

        let t_ex = Instant::now();
        let result = exe
            .execute_b(&refs)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let exec_s = t_ex.elapsed().as_secs_f64();

        let t_dl = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .context("downloading result tuple")?;
        let lits = tuple.to_tuple().context("untupling result")?;
        if lits.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': {} outputs, signature has {}",
                lits.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(lits.len());
        for (lit, ospec) in lits.iter().zip(&spec.outputs) {
            let t = Tensor::from_literal(lit)
                .with_context(|| format!("reading output '{}'", ospec.name))?;
            if t.shape != ospec.shape {
                bail!(
                    "artifact '{name}' output '{}': shape {:?} != manifest {:?}",
                    ospec.name, t.shape, ospec.shape
                );
            }
            out.push(t);
        }
        let download_s = t_dl.elapsed().as_secs_f64();

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_seconds += exec_s;
        s.upload_seconds += upload_s;
        s.download_seconds += download_s;
        Ok(out)
    }

    /// Initialise model parameters via the `init` artifact.
    pub fn init_params(&self, seed: i32) -> Result<HostParams> {
        let seed_t = Tensor::scalar_i32(seed);
        let tensors = self.run("init", &[Arg::Host(&seed_t)])?;
        let hp = HostParams { tensors, version: 0 };
        hp.validate(self)?;
        Ok(hp)
    }

    /// Snapshot of per-artifact execution statistics.
    pub fn stats(&self) -> HashMap<String, RunStats> {
        self.stats.borrow().clone()
    }
}
