//! `manifest.json` — the contract between the python AOT pipeline and the
//! rust runtime.
//!
//! The manifest describes every artifact's input/output signature, the
//! parameter table (names/shapes in flattening order), the KV-cache geometry
//! and the resolved config that was baked into the shapes. The runtime
//! validates arguments against these signatures before every execution and
//! refuses to start if the manifest disagrees with the rust-side config.

use crate::config::Config;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::tensor::DType;

/// Shape + dtype + name of one tensor in a signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape: j
                .req("shape")?
                .as_usize_vec()
                .context("tensor spec shape")?,
            dtype: DType::parse(j.req_str("dtype")?)?,
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub fingerprint: String,
    pub attn_impl: String,
    pub dir: PathBuf,
    pub param_count: usize,
    /// Parameter tensors in flattening order (the PARAM_NAMES contract).
    pub params: Vec<TensorSpec>,
    pub kv_cache: TensorSpec,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    /// Resolved config echoed by the AOT pipeline.
    pub config: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let params = j
            .req("params")?
            .as_arr()
            .context("params must be an array")?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;

        let kv = j.req("kv_cache")?;
        let kv_cache = TensorSpec {
            name: "kv".into(),
            shape: kv.req("shape")?.as_usize_vec().context("kv shape")?,
            dtype: DType::parse(kv.str_or("dtype", "float32"))?,
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts obj")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.req_str("file")?),
                    inputs,
                    outputs,
                },
            );
        }

        let st = j.req("special_tokens")?;
        Ok(Manifest {
            version: j.req_usize("version")?,
            fingerprint: j.str_or("fingerprint", "").to_string(),
            attn_impl: j.str_or("attn_impl", "jnp").to_string(),
            dir: dir.to_path_buf(),
            param_count: j.req_usize("param_count")?,
            params,
            kv_cache,
            artifacts,
            pad_id: st.req_usize("pad")? as i32,
            bos_id: st.req_usize("bos")? as i32,
            eos_id: st.req_usize("eos")? as i32,
            config: j.req("config")?.clone(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest (have: {:?})", self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Total parameter element count (sum over the param table).
    pub fn param_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    /// Cross-check the manifest against the rust-side config resolution.
    /// Any mismatch means artifacts were built from a different config and
    /// every shape downstream would be garbage — fail loudly here.
    pub fn validate(&self, cfg: &Config) -> Result<()> {
        let m = self.config.req("model")?;
        let checks: [(&str, usize, usize); 6] = [
            ("vocab_size", m.req_usize("vocab_size")?, cfg.model.vocab_size),
            ("d_model", m.req_usize("d_model")?, cfg.model.d_model),
            ("n_layers", m.req_usize("n_layers")?, cfg.model.n_layers),
            ("n_heads", m.req_usize("n_heads")?, cfg.model.n_heads),
            ("n_kv_heads", m.req_usize("n_kv_heads")?, cfg.model.n_kv_heads),
            ("d_ff", m.req_usize("d_ff")?, cfg.model.d_ff),
        ];
        for (name, manifest_v, config_v) in checks {
            if manifest_v != config_v {
                bail!("manifest/config mismatch on model.{name}: artifacts built with {manifest_v}, config says {config_v} — re-run `make artifacts`");
            }
        }
        let e = self.config.req("engine")?;
        for (name, mv, cv) in [
            ("n_slots", e.req_usize("n_slots")?, cfg.engine.n_slots),
            ("prompt_max", e.req_usize("prompt_max")?, cfg.engine.prompt_max),
            ("decode_chunk", e.req_usize("decode_chunk")?, cfg.engine.decode_chunk),
            ("max_new", e.req_usize("max_new")?, cfg.engine.max_new),
            // Baked into the `prefill_chunk` token-window shape. Manifests
            // from before chunked prefill (version < 4) lack both the key
            // and the artifact; default to the config's value so they stay
            // loadable (the engine then falls back to full-prompt hits).
            ("cache_block", e.usize_or("cache_block", cfg.engine.cache_block), cfg.engine.cache_block),
        ] {
            if mv != cv {
                bail!("manifest/config mismatch on engine.{name}: {mv} vs {cv} — re-run `make artifacts`");
            }
        }
        let t = self.config.req("train")?;
        for (name, mv, cv) in [
            ("micro_bs", t.req_usize("micro_bs")?, cfg.train.micro_bs),
            ("seq_len", t.req_usize("seq_len")?, cfg.train.seq_len),
            ("spa_k", t.req_usize("spa_k")?, cfg.train.spa.k),
            ("spa_pack_len", t.req_usize("spa_pack_len")?, cfg.train.spa.pack_len),
        ] {
            if mv != cv {
                bail!("manifest/config mismatch on train.{name}: {mv} vs {cv} — re-run `make artifacts`");
            }
        }
        // Manifests older than v5 compiled `decode` with a scalar `seed`
        // drawn from per-engine state — sampled tokens then depend on
        // placement. v5 decode takes per-slot `seeds` [n_slots], one per
        // request stream. Catch the stale artifact here, not as a shape
        // error mid-rollout.
        if let Some(decode) = self.artifacts.get("decode") {
            let seeds_ok = decode
                .inputs
                .iter()
                .any(|t| t.name == "seeds" && t.shape == [cfg.engine.n_slots]);
            if !seeds_ok {
                bail!(
                    "decode artifact predates per-request sampling streams \
                     (needs a `seeds` input of shape [{}]) — re-run `make artifacts`",
                    cfg.engine.n_slots
                );
            }
        }
        if cfg.model.param_count() != self.param_count {
            bail!(
                "param count mismatch: rust computes {}, manifest says {}",
                cfg.model.param_count(),
                self.param_count
            );
        }
        // param table consistency
        let total: usize = self.param_elements();
        if total != self.param_count {
            bail!("manifest param table sums to {total}, param_count says {}", self.param_count);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = r#"{
      "version": 3,
      "fingerprint": "abc",
      "attn_impl": "jnp",
      "config": {"model": {"vocab_size": 8}},
      "param_count": 20,
      "params": [
        {"name": "tok_emb", "shape": [4, 3], "dtype": "float32"},
        {"name": "lm_head", "shape": [8], "dtype": "float32"}
      ],
      "kv_cache": {"shape": [2, 3, 2, 8, 2, 4], "dtype": "float32"},
      "artifacts": {
        "init": {"file": "init.hlo.txt",
                 "inputs": [{"name": "seed", "shape": [], "dtype": "int32"}],
                 "outputs": [{"name": "tok_emb", "shape": [4, 3], "dtype": "float32"}]}
      },
      "special_tokens": {"pad": 0, "bos": 1, "eos": 2}
    }"#;

    #[test]
    fn validate_rejects_pre_v5_scalar_decode_seed() {
        let cfg_json = r#"{
          "name": "unit",
          "model": {"vocab_size": 64, "d_model": 64, "n_layers": 2, "n_heads": 4,
                    "n_kv_heads": 2, "d_ff": 128},
          "engine": {"n_slots": 4, "prompt_max": 16, "decode_chunk": 4, "max_new": 8},
          "train": {"micro_bs": 2, "lr": 0.001},
          "rl": {"batch_prompts": 4, "group_size": 4, "iters": 3, "n_engines": 2},
          "data": {"few_shot": 1, "max_operand": 20, "seed": 7}
        }"#;
        let cfg =
            crate::config::Config::from_json(&crate::util::json::Json::parse(cfg_json).unwrap())
                .unwrap();
        let pc = cfg.model.param_count();
        let mk = |seed_input: &str| {
            format!(
                r#"{{
          "version": 5,
          "fingerprint": "abc",
          "attn_impl": "jnp",
          "config": {{
            "model": {{"vocab_size": 64, "d_model": 64, "n_layers": 2, "n_heads": 4,
                      "n_kv_heads": 2, "d_ff": 128}},
            "engine": {{"n_slots": 4, "prompt_max": 16, "decode_chunk": 4, "max_new": 8,
                       "cache_block": 16}},
            "train": {{"micro_bs": 2, "seq_len": 24, "spa_k": 4, "spa_pack_len": 48}}
          }},
          "param_count": {pc},
          "params": [{{"name": "all", "shape": [{pc}], "dtype": "float32"}}],
          "kv_cache": {{"shape": [2, 4, 2, 24, 2, 16], "dtype": "float32"}},
          "artifacts": {{
            "decode": {{"file": "decode.hlo.txt",
                       "inputs": [{seed_input}],
                       "outputs": []}}
          }},
          "special_tokens": {{"pad": 0, "bos": 1, "eos": 2}}
        }}"#
            )
        };
        let dir = std::env::temp_dir().join("pa_rl_manifest_seeds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let load = |text: &str| {
            std::fs::write(dir.join("manifest.json"), text).unwrap();
            Manifest::load(&dir).unwrap()
        };
        // Pre-v5 artifact: scalar `seed` drawn from per-engine state.
        let stale = load(&mk(r#"{"name": "seed", "shape": [], "dtype": "int32"}"#));
        let err = stale.validate(&cfg).unwrap_err().to_string();
        assert!(err.contains("per-request sampling streams"), "unexpected error: {err}");
        // v5 artifact: per-slot `seeds` [n_slots].
        let fresh = load(&mk(r#"{"name": "seeds", "shape": [4], "dtype": "int32"}"#));
        fresh.validate(&cfg).unwrap();
    }

    #[test]
    fn parses_demo_manifest() {
        let dir = std::env::temp_dir().join("pa_rl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), DEMO).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 3);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![4, 3]);
        assert_eq!(m.param_elements(), 20);
        assert_eq!(m.eos_id, 2);
        let a = m.artifact("init").unwrap();
        assert_eq!(a.inputs[0].name, "seed");
        assert!(a.file.ends_with("init.hlo.txt"));
        assert!(m.artifact("nope").is_err());
    }
}
