//! Run configuration.
//!
//! A single JSON file under `configs/` describes the model architecture, the
//! inference-engine geometry, the trainer hyper-parameters and the RL loop.
//! The same file is read by `python/compile/aot.py` (which bakes the static
//! shapes into the AOT artifacts) and by the rust binary (which must agree
//! with the artifact shapes — checked against `manifest.json` at load time).

use crate::engine::kvcache::EvictPolicy;
use crate::metrics::MetricsLevel;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Transformer architecture (Qwen-mini family: RMSNorm, RoPE, GQA, SwiGLU).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Vocabulary size. Required.
    pub vocab_size: usize,
    /// Residual-stream width. Required.
    pub d_model: usize,
    /// Transformer layers. Required.
    pub n_layers: usize,
    /// Attention query heads. Required.
    pub n_heads: usize,
    /// KV heads for GQA. Default: `n_heads` (plain multi-head attention).
    pub n_kv_heads: usize,
    /// SwiGLU hidden width. Required.
    pub d_ff: usize,
    /// RoPE base frequency. Default: 10000.0.
    pub rope_theta: f64,
    /// RMSNorm epsilon. Default: 1e-5.
    pub rmsnorm_eps: f64,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + layers + head).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        let hd = self.head_dim();
        let per_layer = d // ln1
            + d * (self.n_heads * hd)      // wq
            + d * (self.n_kv_heads * hd)   // wk
            + d * (self.n_kv_heads * hd)   // wv
            + (self.n_heads * hd) * d      // wo
            + d                            // ln2
            + d * f                        // w_gate
            + d * f                        // w_up
            + f * d; // w_down
        self.vocab_size * d + self.n_layers * per_layer + d + d * self.vocab_size
    }

    /// Approximate training FLOPs per token (fwd+bwd ≈ 6 * params, plus
    /// attention quadratic term handled by callers that know seq lengths).
    pub fn train_flops_per_token(&self) -> f64 {
        6.0 * self.param_count() as f64
    }

    /// Approximate inference FLOPs per generated token (2 * params).
    pub fn infer_flops_per_token(&self) -> f64 {
        2.0 * self.param_count() as f64
    }
}

/// Inference-engine geometry (vLLM-like slot-based continuous batching).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Concurrent sequence slots per engine instance. Default: 8.
    pub n_slots: usize,
    /// Maximum prompt length (prefill shape). Required.
    pub prompt_max: usize,
    /// Tokens decoded per compiled decode-chunk call. Default: 16.
    pub decode_chunk: usize,
    /// Maximum generated tokens per sequence. Required.
    pub max_new: usize,
    /// Sampling temperature (<= 1e-6 means greedy). Default: 1.0.
    pub temperature: f64,
    /// Top-p nucleus truncation. Default: 1.0 (off).
    pub top_p: f64,
    /// Top-k truncation; 0 disables top-k. Default: 0.
    pub top_k: usize,
    /// Shared-prefix KV cache on the admission path (`engine::kvcache`).
    /// Off = bit-identical to the pre-cache engine (every request
    /// prefills). Default: on.
    pub prefix_cache: bool,
    /// Partial-prefix reuse: resume admission from the longest cached prefix
    /// via the chunked `prefill_chunk` artifact. Only effective with
    /// `prefix_cache` on and artifacts that ship the chunk program; off =
    /// full-prompt hits only (PR-1 behavior). Default: on.
    pub chunked_prefill: bool,
    /// Prefix-cache block size in tokens; must divide `prompt_max`. Also the
    /// fixed token width of one `prefill_chunk` call and the segment
    /// granularity of the cross-engine shared store. Default:
    /// `gcd(prompt_max, 16)`.
    pub cache_block: usize,
    /// Prefix-cache pool capacity in blocks; must be >= `n_slots`.
    /// Default: four prompt-sets' worth
    /// (`n_slots * ceil(prompt_max / cache_block) * 4`).
    pub cache_blocks: usize,
    /// Which refcount-zero leaf the prefix cache evicts first.
    /// Default: `lru`.
    pub cache_evict: EvictPolicy,
    /// Cross-engine shared segment store (`store::SharedKvStore`): dedupe
    /// prompt prefixes across engine instances. Effective with
    /// `prefix_cache` on and >= 2 engines; off = PR-2 behavior (per-engine
    /// caches only). Default: on.
    pub shared_store: bool,
    /// Shared-store capacity in block entries of `cache_block` tokens.
    /// Default: `cache_blocks * 2`.
    pub store_blocks: usize,
    /// Independent hash-range shards of the shared store, each behind its
    /// own lock with its own capacity slice and eviction heap. 1 (the
    /// default) is bit-identical to the unsharded store; raise it for
    /// many-engine fleets so publishes/fetches of unrelated templates stop
    /// serializing on one mutex.
    pub store_shards: usize,
    /// Per-engine budget of *displacing* publishes per weight-sync interval:
    /// only a publish that had to evict resident segments consumes a credit
    /// (dedup and free-space growth are free), bounding how hard one engine
    /// can churn a full store. 0 disables publishing — engines become
    /// read-only store consumers. Default: 256.
    pub store_publish: usize,
    /// Which unleased store segment eviction removes first. Default: `lru`.
    pub store_evict: EvictPolicy,
}

impl EngineConfig {
    /// KV-cache sequence capacity.
    pub fn cache_len(&self) -> usize {
        self.prompt_max + self.max_new
    }

    /// Pool blocks one full-length prompt occupies.
    pub fn blocks_per_prompt(&self) -> usize {
        self.prompt_max.div_ceil(self.cache_block)
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Shared-prompt attention settings.
#[derive(Debug, Clone, PartialEq)]
pub struct SpaConfig {
    /// Responses per packed group (K in the paper; equals RL group size
    /// here). Default: `rl.group_size`.
    pub k: usize,
    /// Packed sequence length. Default: `prompt_max + k * max_new`.
    pub pack_len: usize,
}

/// Trainer hyper-parameters (paper Table 7/8 analog).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Micro-batch rows for the standard (non-SPA) train step. Default: 4.
    pub micro_bs: usize,
    /// Padded sample length for the standard train step.
    /// Default: `prompt_max + max_new`.
    pub seq_len: usize,
    /// Shared-prompt attention settings. Defaults: see [`SpaConfig`].
    pub spa: SpaConfig,
    /// Learning rate. Default: 1e-4.
    pub lr: f64,
    /// Adam first-moment decay. Default: 0.9.
    pub beta1: f64,
    /// Adam second-moment decay. Default: 0.95.
    pub beta2: f64,
    /// Adam denominator epsilon. Default: 1e-8.
    pub adam_eps: f64,
    /// Decoupled weight decay. Default: 0.01.
    pub weight_decay: f64,
    /// Global gradient-norm clip. Default: 1.0.
    pub grad_clip: f64,
    /// KL penalty coefficient beta. Default: 0.02 (the paper's value).
    pub kl_beta: f64,
    /// PPO lower clip range. Default: 0.2 (paper: eps_low = eps_high).
    pub clip_eps_low: f64,
    /// PPO upper clip range. Default: 0.2 (paper: eps_low = eps_high).
    pub clip_eps_high: f64,
}

/// One scheduled fleet resize, applied at the iteration boundary *before*
/// iteration `iter` syncs weights and dispatches: `join` engines spawn
/// (each weight-synced at the current params version before it can receive
/// work), then `leave` engines drain from the tail of the fleet (in-flight
/// rollouts finish, never-admitted jobs re-route over the survivors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetEvent {
    /// Iteration whose boundary this event fires at (0 = before the
    /// first). Required.
    pub iter: u64,
    /// Engines to spawn at this boundary. Default: 0.
    pub join: usize,
    /// Engines to drain at this boundary (applied after `join`). Default: 0.
    pub leave: usize,
}

/// RL loop shape (Algorithm 1).
#[derive(Debug, Clone, PartialEq)]
pub struct RlConfig {
    /// Prompts per iteration (N; paper "batch size"). Required.
    pub batch_prompts: usize,
    /// Rollouts per prompt (G; paper "answers per prompt" = 32). Required.
    pub group_size: usize,
    /// Training iterations (T). Default: 10.
    pub iters: usize,
    /// Inference engine instances at construction (the paper's
    /// training:rollout ratio). The starting point of `fleet_schedule`.
    /// Default: 1.
    pub n_engines: usize,
    /// Bounded rollout-queue capacity (groups). Default: 64.
    pub queue_cap: usize,
    /// Prompt-affinity group routing (`coordinator::route`): prefer the
    /// engine whose cache holds the template warm, spill to least-loaded.
    /// Off = the original round-robin group pin. Default: on.
    pub affinity_routing: bool,
    /// Backlog slack for affinity routing, in groups: the preferred engine
    /// may run this many groups ahead of the least-loaded engine before a
    /// group spills. Default: 2.
    pub affinity_slack_groups: usize,
    /// Scheduled elastic fleet resizes (sorted by iteration). `train_grpo
    /// --join iter:N` / `--leave iter:N` merge into this list. Default:
    /// empty (the static fleet).
    pub fleet_schedule: Vec<FleetEvent>,
    /// Routing warmth-belief TTL in decay epochs (an iteration in the
    /// driver, a dispatched group in `serve_infer`); a belief unconfirmed
    /// for longer — scaled up for long resident prefixes — expires and its
    /// template re-routes by hash. 0 (default) disables decay, bit-identical
    /// to the PR-4 router.
    pub warmth_ttl: u64,
}

impl RlConfig {
    /// Check the fleet schedule never drains the fleet below one engine
    /// (within an event, joins apply before leaves) and that every event
    /// does something. Called at parse time; callers that merge CLI flags
    /// into the schedule (e.g. `train_grpo`) must re-validate.
    pub fn validate_fleet_schedule(&self) -> Result<()> {
        let mut sched = self.fleet_schedule.clone();
        sched.sort_by_key(|e| e.iter);
        let mut n = self.n_engines as i64;
        for e in &sched {
            if e.join == 0 && e.leave == 0 {
                bail!("rl.fleet_schedule event at iter {} neither joins nor leaves", e.iter);
            }
            n += e.join as i64;
            n -= e.leave as i64;
            if n < 1 {
                bail!(
                    "rl.fleet_schedule drains the fleet below one engine at iter {} (size would be {n})",
                    e.iter
                );
            }
        }
        Ok(())
    }
}

/// Synthetic-task data settings.
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// Few-shot examples prepended to each prompt (lengthens prompts to
    /// reach the paper's long-prompt/short-response SPA regime). Default: 0.
    pub few_shot: usize,
    /// Draw one fixed few-shot template shared by *every* prompt instead of
    /// per-prompt examples — the template-sharing serving workload where
    /// chunked prefill and cross-engine KV sharing bite. Default: off.
    pub shared_few_shot: bool,
    /// Operands drawn uniformly from [0, max_operand]. Default: 99.
    pub max_operand: u64,
    /// Data-generation RNG seed. Default: 0.
    pub seed: u64,
}

/// Telemetry settings (`metrics::Registry` / request timelines — see
/// `docs/OBSERVABILITY.md`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsConfig {
    /// `"basic"` (default) keeps the seed output surfaces bit-identical;
    /// `"full"` stamps per-request lifecycle timelines, aggregates
    /// TTFT / queue-wait / decode-throughput / staleness histograms into
    /// `IterReport` + the fig3 JSON, and writes per-iteration registry
    /// snapshots (JSON + Prometheus text) plus a Perfetto-loadable
    /// `trace.json` under `artifacts/runs/`.
    pub level: MetricsLevel,
    /// Stall watchdog window in seconds: if no rollout reaches the driver
    /// for this long while work is outstanding, a one-shot diagnostic
    /// snapshot (queue depths, per-engine in-flight counts, registry, last
    /// span per lane) is dumped to stderr and `stall_snapshot.json`.
    /// Default: 0.0 (watchdog off). Independent of `level`.
    pub stall_timeout_s: f64,
}

/// Full run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Run name (names the artifacts directory). Default: `"unnamed"`.
    pub name: String,
    /// Model architecture section. Required.
    pub model: ModelConfig,
    /// Engine geometry section. Required.
    pub engine: EngineConfig,
    /// Trainer hyper-parameter section. Required.
    pub train: TrainConfig,
    /// RL loop section. Required.
    pub rl: RlConfig,
    /// Data section. Default: all defaults (see [`DataConfig`]).
    pub data: DataConfig,
    /// Telemetry section. Default: `basic` (see [`MetricsConfig`]).
    pub metrics: MetricsConfig,
}

impl Config {
    pub fn from_json(j: &Json) -> Result<Config> {
        let name = j.str_or("name", "unnamed").to_string();
        let m = j.req("model").context("config: missing 'model'")?;
        let model = ModelConfig {
            vocab_size: m.req_usize("vocab_size")?,
            d_model: m.req_usize("d_model")?,
            n_layers: m.req_usize("n_layers")?,
            n_heads: m.req_usize("n_heads")?,
            n_kv_heads: m.usize_or("n_kv_heads", m.req_usize("n_heads")?),
            d_ff: m.req_usize("d_ff")?,
            rope_theta: m.f64_or("rope_theta", 10000.0),
            rmsnorm_eps: m.f64_or("rmsnorm_eps", 1e-5),
        };
        if model.d_model % model.n_heads != 0 {
            bail!("d_model must be divisible by n_heads");
        }
        if model.n_heads % model.n_kv_heads != 0 {
            bail!("n_heads must be divisible by n_kv_heads");
        }

        let e = j.req("engine").context("config: missing 'engine'")?;
        let n_slots = e.usize_or("n_slots", 8);
        let prompt_max = e.req_usize("prompt_max")?;
        // Default block size: the largest divisor of prompt_max that is <= 16
        // and a divisor of 16 (so the default always validates).
        let cache_block = e.usize_or("cache_block", gcd(prompt_max, 16));
        if cache_block == 0 || prompt_max % cache_block != 0 {
            bail!(
                "engine.cache_block ({cache_block}) must be >= 1 and divide prompt_max ({prompt_max})"
            );
        }
        // Default capacity: 4 prompt-sets' worth of blocks — enough to keep
        // every slot's group prefix plus a few generations of warm prompts.
        let default_blocks = n_slots * prompt_max.div_ceil(cache_block) * 4;
        let cache_blocks = e.usize_or("cache_blocks", default_blocks);
        if cache_blocks < n_slots {
            bail!(
                "engine.cache_blocks ({cache_blocks}) must be >= n_slots ({n_slots}) so every slot can pin a prefix"
            );
        }
        // A pool that cannot hold one full-length prompt (+1 block for a
        // copy-on-write tail fork) would silently drop every insert and the
        // cache would never hit — reject it rather than limp.
        let min_for_one_prompt = prompt_max.div_ceil(cache_block) + 1;
        if cache_blocks < min_for_one_prompt {
            bail!(
                "engine.cache_blocks ({cache_blocks}) cannot hold one full prompt: need >= {min_for_one_prompt} blocks of {cache_block} tokens for prompt_max {prompt_max}"
            );
        }
        // Shared-store default capacity: two local caches' worth of blocks —
        // enough for several warm templates across the whole fleet without
        // rivaling per-engine pool memory.
        let store_blocks = e.usize_or("store_blocks", cache_blocks * 2);
        let shared_store = e.bool_or("shared_store", true);
        if shared_store && store_blocks < prompt_max.div_ceil(cache_block) {
            bail!(
                "engine.store_blocks ({store_blocks}) cannot hold one full prompt: need >= {} blocks of {cache_block} tokens for prompt_max {prompt_max}",
                prompt_max.div_ceil(cache_block)
            );
        }
        let store_shards = e.usize_or("store_shards", 1);
        if store_shards == 0 {
            bail!("engine.store_shards must be >= 1 (1 = the unsharded store)");
        }
        // A prefix's whole block chain lives in ONE shard (chain-affine
        // partitioning), so the store-holds-one-full-prompt bound above must
        // hold per *slice*, not just in aggregate — a thinner slice would
        // silently truncate every chain in its hash range instead of being
        // rejected like an undersized unsharded store.
        if shared_store && store_blocks / store_shards < prompt_max.div_ceil(cache_block) {
            bail!(
                "engine.store_shards ({store_shards}) leaves shard slices of {} blocks, below the {} blocks one full prompt needs (store_blocks {store_blocks}, cache_block {cache_block}, prompt_max {prompt_max})",
                store_blocks / store_shards,
                prompt_max.div_ceil(cache_block)
            );
        }
        let engine = EngineConfig {
            n_slots,
            prompt_max,
            decode_chunk: e.usize_or("decode_chunk", 16),
            max_new: e.req_usize("max_new")?,
            temperature: e.f64_or("temperature", 1.0),
            top_p: e.f64_or("top_p", 1.0),
            top_k: e.usize_or("top_k", 0),
            prefix_cache: e.bool_or("prefix_cache", true),
            chunked_prefill: e.bool_or("chunked_prefill", true),
            cache_block,
            cache_blocks,
            cache_evict: EvictPolicy::parse(e.str_or("cache_evict", "lru"))
                .context("engine.cache_evict")?,
            shared_store,
            store_blocks,
            store_shards,
            store_publish: e.usize_or("store_publish", 256),
            store_evict: EvictPolicy::parse(e.str_or("store_evict", "lru"))
                .context("engine.store_evict")?,
        };

        let r = j.req("rl").context("config: missing 'rl'")?;
        let mut fleet_schedule = Vec::new();
        if let Some(events) = r.get("fleet_schedule").and_then(Json::as_arr) {
            for ev in events {
                fleet_schedule.push(FleetEvent {
                    iter: ev.req_usize("iter").context("rl.fleet_schedule entry")? as u64,
                    join: ev.usize_or("join", 0),
                    leave: ev.usize_or("leave", 0),
                });
            }
        }
        fleet_schedule.sort_by_key(|e| e.iter);
        let rl = RlConfig {
            batch_prompts: r.req_usize("batch_prompts")?,
            group_size: r.req_usize("group_size")?,
            iters: r.usize_or("iters", 10),
            n_engines: r.usize_or("n_engines", 1),
            queue_cap: r.usize_or("queue_cap", 64),
            affinity_routing: r.bool_or("affinity_routing", true),
            affinity_slack_groups: r.usize_or("affinity_slack_groups", 2),
            fleet_schedule,
            warmth_ttl: r.usize_or("warmth_ttl", 0) as u64,
        };
        rl.validate_fleet_schedule()?;

        let t = j.req("train").context("config: missing 'train'")?;
        let default_seq = engine.prompt_max + engine.max_new;
        let spa_k = t.path(&["spa", "k"]).and_then(Json::as_usize).unwrap_or(rl.group_size);
        let train = TrainConfig {
            micro_bs: t.usize_or("micro_bs", 4),
            seq_len: t.usize_or("seq_len", default_seq),
            spa: SpaConfig {
                k: spa_k,
                pack_len: t
                    .path(&["spa", "pack_len"])
                    .and_then(Json::as_usize)
                    .unwrap_or(engine.prompt_max + spa_k * engine.max_new),
            },
            lr: t.f64_or("lr", 1e-4),
            beta1: t.f64_or("beta1", 0.9),
            beta2: t.f64_or("beta2", 0.95),
            adam_eps: t.f64_or("adam_eps", 1e-8),
            weight_decay: t.f64_or("weight_decay", 0.01),
            grad_clip: t.f64_or("grad_clip", 1.0),
            kl_beta: t.f64_or("kl_beta", 0.02),
            clip_eps_low: t.f64_or("clip_eps_low", 0.2),
            clip_eps_high: t.f64_or("clip_eps_high", 0.2),
        };
        if train.seq_len < engine.prompt_max + engine.max_new {
            bail!(
                "train.seq_len ({}) must cover prompt_max + max_new ({})",
                train.seq_len,
                engine.prompt_max + engine.max_new
            );
        }

        let d = j.get("data").cloned().unwrap_or(Json::Obj(vec![]));
        let data = DataConfig {
            few_shot: d.usize_or("few_shot", 0),
            shared_few_shot: d.bool_or("shared_few_shot", false),
            max_operand: d.f64_or("max_operand", 99.0) as u64,
            seed: d.f64_or("seed", 0.0) as u64,
        };

        let mt = j.get("metrics").cloned().unwrap_or(Json::Obj(vec![]));
        let level_str = mt.str_or("level", "basic");
        let stall_timeout_s = mt.f64_or("stall_timeout_s", 0.0);
        if stall_timeout_s < 0.0 || !stall_timeout_s.is_finite() {
            bail!("metrics.stall_timeout_s must be a finite value >= 0.0 (0 = off), got {stall_timeout_s}");
        }
        let metrics = MetricsConfig {
            level: MetricsLevel::parse(level_str).with_context(|| {
                format!("metrics.level '{level_str}' is not one of: basic, full")
            })?,
            stall_timeout_s,
        };

        Ok(Config { name, model, engine, train, rl, data, metrics })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing config {}", path.display()))?;
        Self::from_json(&j)
    }

    /// Default artifacts directory for this config.
    pub fn artifacts_dir(&self) -> String {
        format!("artifacts/{}", self.name)
    }

    /// Should an `n_engines`-wide deployment run the cross-engine shared
    /// segment store? (One engine's local radix cache already covers
    /// everything a store could offer.) Single source of truth for the
    /// coordinator and the serving examples.
    pub fn store_active(&self, n_engines: usize) -> bool {
        self.engine.prefix_cache && self.engine.shared_store && n_engines > 1
    }

    /// Should an `n_engines`-wide deployment route groups by prompt
    /// affinity? Only pays off when there is a per-engine cache to keep
    /// warm; otherwise the round-robin group pin applies.
    pub fn affinity_active(&self, n_engines: usize) -> bool {
        self.rl.affinity_routing && self.engine.prefix_cache && n_engines > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn demo_json() -> &'static str {
        r#"{
          "name": "unit",
          "model": {"vocab_size": 64, "d_model": 64, "n_layers": 2, "n_heads": 4,
                    "n_kv_heads": 2, "d_ff": 128},
          "engine": {"n_slots": 4, "prompt_max": 16, "decode_chunk": 4, "max_new": 8},
          "train": {"micro_bs": 2, "lr": 0.001},
          "rl": {"batch_prompts": 4, "group_size": 4, "iters": 3, "n_engines": 2},
          "data": {"few_shot": 1, "max_operand": 20, "seed": 7}
        }"#
    }

    #[test]
    fn parses_full_config() {
        let j = Json::parse(demo_json()).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.name, "unit");
        assert_eq!(c.model.head_dim(), 16);
        assert_eq!(c.engine.cache_len(), 24);
        assert_eq!(c.train.seq_len, 24);
        // spa defaults: k = group_size, pack_len = prompt + k*max_new
        assert_eq!(c.train.spa.k, 4);
        assert_eq!(c.train.spa.pack_len, 16 + 4 * 8);
        assert_eq!(c.rl.n_engines, 2);
        assert_eq!(c.data.seed, 7);
        // prefix-cache defaults: on, chunked, block = gcd(prompt_max, 16),
        // capacity = 4 prompt-sets, LRU eviction
        assert!(c.engine.prefix_cache);
        assert!(c.engine.chunked_prefill);
        assert_eq!(c.engine.cache_block, 16);
        assert_eq!(c.engine.blocks_per_prompt(), 1);
        assert_eq!(c.engine.cache_blocks, 4 * 1 * 4);
        assert_eq!(c.engine.cache_evict, EvictPolicy::Lru);
        // cross-engine store defaults: on, 2x the local pool, LRU, budget
        // 256, a single shard (bit-identical to the unsharded store)
        assert!(c.engine.shared_store);
        assert_eq!(c.engine.store_blocks, 2 * c.engine.cache_blocks);
        assert_eq!(c.engine.store_publish, 256);
        assert_eq!(c.engine.store_evict, EvictPolicy::Lru);
        assert_eq!(c.engine.store_shards, 1);
        // routing defaults: affinity on, 2 groups of slack
        assert!(c.rl.affinity_routing);
        assert_eq!(c.rl.affinity_slack_groups, 2);
        assert!(!c.data.shared_few_shot);
        // elastic-fleet defaults: static fleet, no warmth decay
        assert!(c.rl.fleet_schedule.is_empty());
        assert_eq!(c.rl.warmth_ttl, 0);
        // telemetry defaults to basic (bit-identical surfaces), watchdog off
        assert_eq!(c.metrics.level, MetricsLevel::Basic);
        assert_eq!(c.metrics.stall_timeout_s, 0.0);
    }

    #[test]
    fn metrics_level_knob_parses_and_validates() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1},
                "metrics":{"level":"full","stall_timeout_s":2.5}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.metrics.level, MetricsLevel::Full);
        assert!(c.metrics.level.is_full());
        assert_eq!(c.metrics.stall_timeout_s, 2.5);
        // a negative watchdog window is a config mistake, not a silent off
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1},
                "metrics":{"stall_timeout_s":-1.0}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("stall_timeout_s"), "unexpected error: {err}");
        // unknown levels are config mistakes, not silent basics
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1},
                "metrics":{"level":"verbose"}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("metrics.level"), "unexpected error: {err}");
    }

    #[test]
    fn fleet_schedule_parses_sorted_and_warmth_ttl() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"n_engines":2,"warmth_ttl":4,
                      "fleet_schedule":[{"iter":5,"leave":1},{"iter":2,"join":2}]}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.rl.warmth_ttl, 4);
        assert_eq!(
            c.rl.fleet_schedule,
            vec![
                FleetEvent { iter: 2, join: 2, leave: 0 },
                FleetEvent { iter: 5, join: 0, leave: 1 },
            ],
            "schedule must parse and sort by iteration"
        );
    }

    #[test]
    fn rejects_degenerate_fleet_schedules() {
        // Draining below one engine: leave 1 of 1 at iter 0.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"n_engines":1,
                      "fleet_schedule":[{"iter":0,"leave":1}]}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("below one engine"), "unexpected error: {err}");
        // The running size counts earlier joins: join 1 then leave 2 later.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"n_engines":1,
                      "fleet_schedule":[{"iter":1,"join":1},{"iter":3,"leave":2}]}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
        // An event that does nothing is a config mistake, not a no-op.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"n_engines":2,
                      "fleet_schedule":[{"iter":1}]}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("neither joins nor leaves"), "unexpected error: {err}");
        // A join-then-matched-leave schedule on a 2-engine fleet is fine.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"n_engines":2,
                      "fleet_schedule":[{"iter":1,"join":1},{"iter":2,"leave":1}]}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_ok());
    }

    #[test]
    fn store_and_routing_knobs_parse_explicitly() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"n_slots":2,"prompt_max":16,"max_new":4,
                          "shared_store":false,"store_blocks":7,"store_publish":0,
                          "store_evict":"fifo","store_shards":3},
                "train":{},
                "rl":{"batch_prompts":1,"group_size":1,"affinity_routing":false,
                      "affinity_slack_groups":5},
                "data":{"shared_few_shot":true}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(!c.engine.shared_store);
        assert_eq!(c.engine.store_blocks, 7);
        assert_eq!(c.engine.store_publish, 0);
        assert_eq!(c.engine.store_evict, EvictPolicy::Fifo);
        assert_eq!(c.engine.store_shards, 3);
        assert!(!c.rl.affinity_routing);
        assert_eq!(c.rl.affinity_slack_groups, 5);
        assert!(c.data.shared_few_shot);
    }

    #[test]
    fn rejects_degenerate_store_shards() {
        // shards = 0 is meaningless.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"store_shards":0},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("store_shards"), "unexpected error: {err}");
        // Chains are shard-affine: a slice that cannot hold one full prompt
        // (8 blocks / 4 shards = 2-block slices vs a 4-block prompt) would
        // silently truncate every chain in its range — reject, mirroring the
        // unsharded store_blocks bound.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":4,
                          "store_blocks":8,"store_shards":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("shard slices"), "unexpected error: {err}");
        // Sized so every slice holds a prompt, the same shard count is fine.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":4,
                          "store_blocks":16,"store_shards":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert_eq!(Config::from_json(&j).unwrap().engine.store_shards, 4);
        // ...and a disabled store skips the capacity bound (shape-only knob).
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":4,
                          "store_blocks":4,"store_shards":9,"shared_store":false},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_ok());
    }

    #[test]
    fn rejects_store_too_small_for_one_prompt() {
        // A store that cannot hold one full prompt would never produce a
        // cross-engine hit: reject rather than limp (mirrors cache_blocks).
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":4,"store_blocks":3},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("store_blocks"), "unexpected error: {err}");
        // ...but an explicitly disabled store skips the bound.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":4,"store_blocks":3,
                          "shared_store":false},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_ok());
    }

    #[test]
    fn cache_knobs_parse_explicitly() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"n_slots":2,"prompt_max":24,"max_new":4,"prefix_cache":false,
                          "chunked_prefill":false,
                          "cache_block":8,"cache_blocks":9,"cache_evict":"fifo"},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(!c.engine.prefix_cache);
        assert!(!c.engine.chunked_prefill);
        assert_eq!(c.engine.cache_block, 8);
        assert_eq!(c.engine.blocks_per_prompt(), 3);
        assert_eq!(c.engine.cache_blocks, 9);
        assert_eq!(c.engine.cache_evict, EvictPolicy::Fifo);
    }

    #[test]
    fn cache_block_default_divides_odd_prompt_max() {
        // prompt_max = 24: gcd(24, 16) = 8 — the default must always satisfy
        // its own validation.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":24,"max_new":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.engine.cache_block, 8);
        assert_eq!(c.engine.prompt_max % c.engine.cache_block, 0);
    }

    #[test]
    fn rejects_cache_block_not_dividing_prompt_max() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_block":5},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("cache_block"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_cache_blocks_below_n_slots() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"n_slots":8,"prompt_max":16,"max_new":4,"cache_blocks":7},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("cache_blocks"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_cache_too_small_for_one_prompt() {
        // 8 slots satisfies the >= n_slots bound, but 8 one-token blocks
        // cannot hold a 16-token prompt (+1 CoW block): must be rejected,
        // not silently drop every insert.
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"n_slots":8,"prompt_max":16,"max_new":4,"cache_block":1,"cache_blocks":8},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("cannot hold one full prompt"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_unknown_eviction_policy() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":4,"cache_evict":"random"},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn param_count_matches_manual() {
        let j = Json::parse(demo_json()).unwrap();
        let m = Config::from_json(&j).unwrap().model;
        // embeddings 64*64, head 64 + 64*64, layers: 2 * (64 + 64*64 + 64*32 + 64*32 + 64*64 + 64 + 3*64*128)
        let per_layer = 64 + 64 * 64 + 64 * 32 + 64 * 32 + 64 * 64 + 64 + 3 * (64 * 128);
        let expect = 64 * 64 + 2 * per_layer + 64 + 64 * 64;
        assert_eq!(m.param_count(), expect);
    }

    #[test]
    fn rejects_bad_dims() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":65,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":4,"max_new":4},
                "train":{},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn rejects_short_seq_len() {
        let j = Json::parse(
            r#"{"name":"x","model":{"vocab_size":8,"d_model":64,"n_layers":1,"n_heads":4,"d_ff":8},
                "engine":{"prompt_max":16,"max_new":16},
                "train":{"seq_len": 8},"rl":{"batch_prompts":1,"group_size":1}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
    }
}
