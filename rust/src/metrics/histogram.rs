//! Deterministic log-bucketed histograms for latency / throughput
//! distributions (p50/p99 TTFT, queue wait, decode tokens/s, staleness).
//!
//! Values are bucketed by their binary exponent plus the top `SUB_BITS`
//! mantissa bits — `2^SUB_BITS` buckets per power of two, derived directly
//! from the IEEE-754 bit pattern, so bucketing is exact, monotone, and
//! identical on every platform and under every thread interleaving. Counts
//! are exact integers; quantile estimates come from bucket midpoints, so the
//! relative error of a quantile is bounded by one bucket's relative width
//! (`2^(1/32) − 1 ≈ 2.2%`) for in-range values. The proptests in this module
//! check exactly that bound against a sorted-vector oracle.
//!
//! Two forms share the bucketing scheme:
//!
//! * [`Histogram`] — plain single-owner form used for snapshots, merging
//!   (multi-engine aggregation is associative on counts) and JSON export.
//! * [`AtomicHistogram`] — lock-free concurrent form behind the registry's
//!   cloneable handles; `observe` is a couple of relaxed atomic RMWs.

use crate::check::sync::atomic::{AtomicU64, Ordering};
use crate::util::json::Json;

/// Sub-octave resolution: 2^SUB_BITS buckets per power of two.
const SUB_BITS: u32 = 5;
/// Buckets per octave (32).
const SUBS: usize = 1 << SUB_BITS;
/// Smallest resolved exponent; values in `(0, 2^MIN_EXP)` clamp to bucket 0.
const MIN_EXP: i32 = -40;
/// One-past-largest resolved exponent; values `>= 2^MAX_EXP` clamp to the
/// last bucket. The range `[2^-40, 2^40)` spans picoseconds to ~1.1e12,
/// comfortably covering seconds-scale latencies and tokens/s throughputs.
const MAX_EXP: i32 = 40;
/// Total bucket count (80 octaves x 32 sub-buckets).
pub const BUCKETS: usize = ((MAX_EXP - MIN_EXP) as usize) * SUBS;

/// The exact lower bound of bucket `(exp, sub)`: `2^exp * (1 + sub/32)`.
fn bucket_floor(exp: i32, sub: usize) -> f64 {
    debug_assert!((MIN_EXP..=MAX_EXP).contains(&exp) && sub < SUBS);
    f64::from_bits((((1023 + exp) as u64) << 52) | ((sub as u64) << (52 - SUB_BITS)))
}

fn min_value() -> f64 {
    bucket_floor(MIN_EXP, 0)
}

fn max_value() -> f64 {
    bucket_floor(MAX_EXP, 0)
}

/// Histograms record non-negative measurements; NaN / negative observations
/// clamp to 0.0 so min/max can use bit-ordered atomic comparisons.
fn clamp_observation(v: f64) -> f64 {
    if v.is_finite() && v > 0.0 {
        v
    } else {
        0.0
    }
}

/// Map a (clamped) value to its bucket index. Exact and monotone: derived
/// from the float's bit pattern, no transcendental math involved.
pub fn bucket_index(v: f64) -> usize {
    let v = clamp_observation(v);
    if v < min_value() {
        return 0;
    }
    if v >= max_value() {
        return BUCKETS - 1;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7FF) as i32 - 1023;
    let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    ((exp - MIN_EXP) as usize) * SUBS + sub
}

/// Inclusive-lower / exclusive-upper value bounds of bucket `idx`. Bucket 0
/// additionally absorbs `[0, 2^MIN_EXP)`; the last bucket absorbs
/// `[top, +inf)` but reports its nominal one-sub-bucket width.
pub fn bucket_bounds(idx: usize) -> (f64, f64) {
    assert!(idx < BUCKETS);
    let exp = MIN_EXP + (idx / SUBS) as i32;
    let sub = idx % SUBS;
    let lo = if idx == 0 { 0.0 } else { bucket_floor(exp, sub) };
    let hi = if sub + 1 < SUBS {
        bucket_floor(exp, sub + 1)
    } else {
        bucket_floor(exp + 1, 0)
    };
    (lo, hi)
}

/// Exact-count log-bucketed histogram (single-owner / snapshot form).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Record one observation (clamped to `>= 0`; see module docs).
    pub fn observe(&mut self, v: f64) {
        let v = clamp_observation(v);
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold `other` into `self`. Bucket counts add exactly, so merging is
    /// associative and commutative on counts and min/max; the `sum` field is
    /// a float accumulation and exact whenever the observations are (e.g.
    /// integer-valued staleness).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate from bucket counts, nearest-rank convention
    /// (`rank = round((n−1)·q)`, matching `util::bench::Stats`). The returned
    /// bucket midpoint is clamped into `[min, max]`, so `q=0` / `q=1` are
    /// exact and every estimate stays inside the observed range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((self.count - 1) as f64 * q).round() as u64;
        let mut cum = 0u64;
        for (idx, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                let (lo, hi) = bucket_bounds(idx);
                return (0.5 * (lo + hi)).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary form: count, sum, min/max and the headline quantiles. Kept
    /// flat (no raw bucket dump) so per-iteration snapshots stay small.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("min", Json::num(self.min())),
            ("max", Json::num(self.max())),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.quantile(0.50))),
            ("p90", Json::num(self.quantile(0.90))),
            ("p99", Json::num(self.quantile(0.99))),
        ])
    }
}

/// Lock-free histogram for concurrent observation: bucket increments and
/// min/max are single relaxed RMWs (f64 bit patterns of non-negative values
/// order like the values themselves), the running sum is a CAS loop.
#[derive(Debug)]
pub struct AtomicHistogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Record one observation. Wait-free except for the sum's CAS loop.
    pub fn observe(&self, v: f64) {
        let v = clamp_observation(v);
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let bits = v.to_bits();
        self.min_bits.fetch_min(bits, Ordering::Relaxed);
        self.max_bits.fetch_max(bits, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current state into a plain [`Histogram`]. Quiescent reads
    /// (no concurrent writers) are exact; concurrent reads are a consistent
    /// "at least what was fully recorded" view.
    pub fn snapshot(&self) -> Histogram {
        Histogram {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            min: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Pcg64;

    /// Max relative quantile error: one bucket's relative width
    /// (2^(1/32) − 1 ≈ 2.2%) plus slack for the midpoint convention.
    const QUANTILE_REL_ERR: f64 = 0.03;

    fn oracle_quantile(sorted: &[f64], q: f64) -> f64 {
        let rank = ((sorted.len() - 1) as f64 * q).round() as usize;
        sorted[rank]
    }

    #[test]
    fn bucket_bounds_contain_their_values() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..10_000 {
            // log-uniform across the full resolved range
            let v = (rng.range_f64(-39.9, 39.9)).exp2();
            let idx = bucket_index(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && v < hi, "v={v} not in bucket {idx} [{lo}, {hi})");
        }
    }

    #[test]
    fn bucket_index_is_monotone_and_clamped() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(1e300), BUCKETS - 1);
        let mut rng = Pcg64::seeded(12);
        for _ in 0..10_000 {
            let a = rng.range_f64(1e-9, 1e9);
            let b = rng.range_f64(1e-9, 1e9);
            let (a, b) = if a <= b { (a, b) } else { (b, a) };
            assert!(bucket_index(a) <= bucket_index(b));
        }
    }

    #[test]
    fn prop_quantiles_match_sorted_oracle() {
        quick(
            "histogram-quantile-oracle",
            |rng, size| {
                let n = rng.range(1, size.scaled(512) + 2);
                (0..n)
                    .map(|_| rng.range_f64(-6.0, 6.0) * std::f64::consts::LN_10)
                    .map(f64::exp) // log-uniform over [1e-6, 1e6]
                    .collect::<Vec<f64>>()
            },
            |xs| {
                let mut h = Histogram::new();
                for &x in xs {
                    h.observe(x);
                }
                let mut sorted = xs.clone();
                sorted.sort_by(f64::total_cmp);
                for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                    let est = h.quantile(q);
                    let actual = oracle_quantile(&sorted, q);
                    let rel = (est - actual).abs() / actual;
                    if rel > QUANTILE_REL_ERR {
                        return Err(format!(
                            "q={q}: est {est} vs oracle {actual} (rel err {rel:.4})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_merge_is_associative() {
        quick(
            "histogram-merge-associative",
            |rng, size| {
                // dyadic-exact values (k/8 for small k) so f64 sums are exact
                // in any association and the comparison can be bitwise.
                let mk = |rng: &mut Pcg64, n: usize| {
                    (0..n).map(|_| rng.range(0, 4096) as f64 / 8.0).collect::<Vec<f64>>()
                };
                let n = size.scaled(128);
                let (na, nb, nc) =
                    (rng.range(0, n + 1), rng.range(0, n + 1), rng.range(0, n + 1));
                let a = mk(rng, na);
                let b = mk(rng, nb);
                let c = mk(rng, nc);
                (a, b, c)
            },
            |(a, b, c)| {
                let hist = |xs: &[f64]| {
                    let mut h = Histogram::new();
                    for &x in xs {
                        h.observe(x);
                    }
                    h
                };
                let (ha, hb, hc) = (hist(a), hist(b), hist(c));
                // (a ∪ b) ∪ c
                let mut left = ha.clone();
                left.merge(&hb);
                left.merge(&hc);
                // a ∪ (b ∪ c)
                let mut bc = hb.clone();
                bc.merge(&hc);
                let mut right = ha.clone();
                right.merge(&bc);
                if left != right {
                    return Err("merge associativity violated".into());
                }
                // merging is also equivalent to observing the concatenation
                let mut all: Vec<f64> = a.clone();
                all.extend_from_slice(b);
                all.extend_from_slice(c);
                if left != hist(&all) {
                    return Err("merge differs from direct observation".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn atomic_snapshot_is_deterministic_across_interleavings() {
        // Fixed per-thread value sets, integer-valued so the CAS-looped f64
        // sum is exact in every addition order: any interleaving must yield
        // the identical snapshot.
        let values: Vec<Vec<f64>> = (0..4)
            .map(|t| (0..256).map(|k| ((t * 256 + k) % 97 + 1) as f64).collect())
            .collect();
        let run = |chunk: usize| {
            let h = crate::check::sync::Arc::new(AtomicHistogram::new());
            crate::check::thread::scope(|s| {
                for vs in &values {
                    let h = h.clone();
                    s.spawn(move || {
                        for batch in vs.chunks(chunk) {
                            for &v in batch {
                                h.observe(v);
                            }
                            crate::check::thread::yield_now();
                        }
                    });
                }
            });
            h.snapshot()
        };
        // different chunk sizes force different interleavings
        let a = run(1);
        let b = run(64);
        assert_eq!(a, b);
        assert_eq!(a.count(), 1024);
        // and the concurrent result equals the sequential one
        let mut seq = Histogram::new();
        for vs in &values {
            for &v in vs {
                seq.observe(v);
            }
        }
        assert_eq!(a, seq);
    }

    #[test]
    fn empty_and_degenerate_histograms() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);

        let mut z = Histogram::new();
        z.observe(0.0);
        z.observe(-5.0); // clamps to 0
        assert_eq!(z.count(), 2);
        assert_eq!(z.quantile(0.99), 0.0);
        assert_eq!(z.max(), 0.0);
    }

    #[test]
    fn json_summary_has_headline_fields() {
        let mut h = Histogram::new();
        for k in 1..=100 {
            h.observe(k as f64 / 1000.0);
        }
        let j = h.to_json();
        assert_eq!(j.req_f64("count").unwrap(), 100.0);
        let p50 = j.req_f64("p50").unwrap();
        assert!((p50 - 0.0505).abs() / 0.0505 < QUANTILE_REL_ERR, "p50 {p50}");
        assert!(j.req_f64("p99").unwrap() <= j.req_f64("max").unwrap());
    }
}
