//! Per-request lifecycle timelines.
//!
//! A [`RequestTimeline`] rides along with each generation request and is
//! stamped at the stage boundaries the paper's pipeline analysis cares
//! about: **enqueue** (driver creates the request) → **dispatch** (sent to a
//! worker inbox) → **admit** (engine claims a decode slot) → **first token**
//! (prefill sampled its token) → **finish** (sequence retired) →
//! **train-consume** (the trainer folds the rollout into a micro-batch).
//! All stamps come from one shared [`Clock`] (the trace epoch), so
//! differences are meaningful across threads.
//!
//! [`RequestMetrics`] aggregates finished timelines into the deterministic
//! log-bucketed histograms of [`super::histogram`]: TTFT, queue wait, decode
//! tokens/s, and staleness-at-consumption. Aggregation merges associatively,
//! so per-engine or per-iteration partials can be folded fleet-wide.

use super::histogram::Histogram;
use crate::util::json::Json;
use std::time::Instant;

/// Cheap copyable monotonic clock anchored at a shared epoch. All telemetry
/// stamps in one run share the [`crate::metrics::Trace`] epoch so span and
/// timeline timestamps live on the same axis.
#[derive(Debug, Clone, Copy)]
pub struct Clock {
    epoch: Instant,
}

impl Default for Clock {
    fn default() -> Self {
        Clock::new()
    }
}

impl Clock {
    /// A clock anchored at "now".
    pub fn new() -> Clock {
        Clock { epoch: Instant::now() }
    }

    /// A clock anchored at an existing epoch (see `Trace::clock`).
    pub fn from_epoch(epoch: Instant) -> Clock {
        Clock { epoch }
    }

    /// Seconds since the epoch.
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

/// Sentinel for a lifecycle stage that has not been reached (timestamps are
/// seconds since the clock epoch, hence always `>= 0` when stamped).
pub const UNSET: f64 = -1.0;

/// Lifecycle timestamps for one generation request, in seconds since the
/// run's clock epoch; `UNSET` marks stages not (yet) reached. `Copy` and a
/// handful of words wide, so it travels inside requests/results/rollouts
/// without allocation; in `metrics.level = "basic"` runs it stays entirely
/// unstamped and costs nothing.
#[derive(Debug, Clone, Copy)]
pub struct RequestTimeline {
    /// Driver created/queued the request.
    pub enqueue_s: f64,
    /// Driver handed the request to a worker inbox (re-stamped if a drain
    /// re-routes the request to a surviving engine).
    pub dispatch_s: f64,
    /// Engine claimed a decode slot and began admission.
    pub admit_s: f64,
    /// Prefill completed and the first token was sampled.
    pub first_token_s: f64,
    /// Sequence finished and the slot was retired.
    pub finish_s: f64,
    /// Trainer consumed the scored rollout into a micro-batch.
    pub consume_s: f64,
    /// Tokens generated after the first one (decode-phase tokens).
    pub decode_tokens: u32,
    /// Causal parent for the full-telemetry span trace: the id of the
    /// driver-side dispatch span that sent this request out, so the worker's
    /// per-request `gen` span links back to the iteration's span tree
    /// (`crate::metrics::trace`). `0` ([`crate::metrics::NO_PARENT`]) when
    /// unlinked — basic mode never sets it.
    pub parent_span: u64,
}

impl Default for RequestTimeline {
    fn default() -> Self {
        RequestTimeline {
            enqueue_s: UNSET,
            dispatch_s: UNSET,
            admit_s: UNSET,
            first_token_s: UNSET,
            finish_s: UNSET,
            consume_s: UNSET,
            decode_tokens: 0,
            parent_span: 0,
        }
    }
}

impl RequestTimeline {
    fn span(a: f64, b: f64) -> Option<f64> {
        if a >= 0.0 && b >= 0.0 {
            Some((b - a).max(0.0))
        } else {
            None
        }
    }

    /// enqueue → admit: time spent waiting in driver + worker queues.
    pub fn queue_wait(&self) -> Option<f64> {
        Self::span(self.enqueue_s, self.admit_s)
    }

    /// enqueue → first token: time-to-first-token as a client would see it.
    pub fn ttft(&self) -> Option<f64> {
        Self::span(self.enqueue_s, self.first_token_s)
    }

    /// enqueue → finish: whole-request latency.
    pub fn e2e(&self) -> Option<f64> {
        Self::span(self.enqueue_s, self.finish_s)
    }

    /// finish → train-consume: how long a finished rollout sat before the
    /// trainer folded it in (the consumer-side analogue of queue wait).
    pub fn consume_lag(&self) -> Option<f64> {
        Self::span(self.finish_s, self.consume_s)
    }

    /// Decode-phase throughput in tokens/s (first token → finish).
    pub fn decode_tps(&self) -> Option<f64> {
        match Self::span(self.first_token_s, self.finish_s) {
            Some(d) if d > 0.0 && self.decode_tokens > 0 => {
                Some(self.decode_tokens as f64 / d)
            }
            _ => None,
        }
    }
}

/// Aggregated per-request distributions for one scope (an iteration, an
/// engine, a whole run). Each field is a deterministic log-bucketed
/// [`Histogram`]; [`RequestMetrics::merge`] folds scopes associatively.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestMetrics {
    /// Requests folded in (whether or not every stage was stamped).
    pub completed: u64,
    /// Time-to-first-token, seconds.
    pub ttft: Histogram,
    /// Queue wait (enqueue → admit), seconds.
    pub queue_wait: Histogram,
    /// Decode throughput, tokens/s.
    pub decode_tps: Histogram,
    /// Weight-version staleness at train-consumption (0 = strictly
    /// on-policy, the paper's Prop. 1 regime).
    pub staleness: Histogram,
}

impl RequestMetrics {
    /// Fold one finished request in. `staleness` is the consuming trainer's
    /// `installed_version − rollout.weight_version`.
    pub fn observe(&mut self, tl: &RequestTimeline, staleness: u64) {
        self.completed += 1;
        if let Some(x) = tl.ttft() {
            self.ttft.observe(x);
        }
        if let Some(x) = tl.queue_wait() {
            self.queue_wait.observe(x);
        }
        if let Some(x) = tl.decode_tps() {
            self.decode_tps.observe(x);
        }
        self.staleness.observe(staleness as f64);
    }

    /// Fold another scope's aggregate in (associative).
    pub fn merge(&mut self, other: &RequestMetrics) {
        self.completed += other.completed;
        self.ttft.merge(&other.ttft);
        self.queue_wait.merge(&other.queue_wait);
        self.decode_tps.merge(&other.decode_tps);
        self.staleness.merge(&other.staleness);
    }

    pub fn is_empty(&self) -> bool {
        self.completed == 0
    }

    /// The shared schema for real runs and the simulator's synthesized
    /// timelines: one summary object per distribution.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("ttft_s", self.ttft.to_json()),
            ("queue_wait_s", self.queue_wait.to_json()),
            ("decode_tok_per_s", self.decode_tps.to_json()),
            ("staleness", self.staleness.to_json()),
        ])
    }

    /// One-line human summary for full-telemetry stdout surfaces.
    pub fn summary(&self) -> String {
        format!(
            "ttft p50 {:.3}s p99 {:.3}s  queue p50 {:.3}s p99 {:.3}s  decode p50 {:.0} tok/s  stale p99 {:.0}",
            self.ttft.quantile(0.50),
            self.ttft.quantile(0.99),
            self.queue_wait.quantile(0.50),
            self.queue_wait.quantile(0.99),
            self.decode_tps.quantile(0.50),
            self.staleness.quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stamped() -> RequestTimeline {
        RequestTimeline {
            enqueue_s: 1.0,
            dispatch_s: 1.1,
            admit_s: 1.5,
            first_token_s: 2.0,
            finish_s: 4.0,
            consume_s: 4.5,
            decode_tokens: 100,
            ..Default::default()
        }
    }

    #[test]
    fn derived_spans() {
        let tl = stamped();
        assert_eq!(tl.queue_wait(), Some(0.5));
        assert_eq!(tl.ttft(), Some(1.0));
        assert_eq!(tl.e2e(), Some(3.0));
        assert_eq!(tl.consume_lag(), Some(0.5));
        assert_eq!(tl.decode_tps(), Some(50.0));
    }

    #[test]
    fn unset_stages_yield_none() {
        let tl = RequestTimeline::default();
        assert_eq!(tl.queue_wait(), None);
        assert_eq!(tl.ttft(), None);
        assert_eq!(tl.e2e(), None);
        assert_eq!(tl.consume_lag(), None);
        assert_eq!(tl.decode_tps(), None);

        // finish stamped but no decode tokens -> no throughput sample
        let mut tl = stamped();
        tl.decode_tokens = 0;
        assert_eq!(tl.decode_tps(), None);
        // out-of-order stamps clamp to zero-length spans, never negative
        let mut tl = stamped();
        tl.first_token_s = 0.5;
        assert_eq!(tl.ttft(), Some(0.0));
    }

    #[test]
    fn clock_is_monotone_and_shareable() {
        let c = Clock::new();
        let a = c.now();
        let c2 = c; // Copy
        let b = c2.now();
        assert!(a >= 0.0 && b >= a);
    }

    #[test]
    fn metrics_observe_and_merge() {
        let mut a = RequestMetrics::default();
        let mut b = RequestMetrics::default();
        a.observe(&stamped(), 0);
        b.observe(&stamped(), 2);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.completed, 2);
        assert_eq!(merged.ttft.count(), 2);
        assert_eq!(merged.staleness.max(), 2.0);

        // partial timelines only feed the histograms they can support
        let mut c = RequestMetrics::default();
        c.observe(&RequestTimeline::default(), 1);
        assert_eq!(c.completed, 1);
        assert_eq!(c.ttft.count(), 0);
        assert_eq!(c.staleness.count(), 1);

        let j = merged.to_json();
        assert_eq!(j.req_f64("completed").unwrap(), 2.0);
        assert!(j.req("ttft_s").unwrap().req_f64("p50").is_ok());
        let line = merged.summary();
        assert!(line.contains("ttft p50"), "{line}");
    }
}
