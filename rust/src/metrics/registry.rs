//! Named metric registry: counters, gauges and histograms behind cheap
//! cloneable handles, with a snapshot/delta mechanism and two exporters.
//!
//! Components register series by name (`registry.counter("engine.prefills")`)
//! and keep the returned handle; registration is get-or-create, so the same
//! name always resolves to the same underlying cell and independent
//! components (or the several engines of a fleet) accumulate into one
//! fleet-wide series. Handles are `Arc`-backed and lock-free to update —
//! a recorded counter bump is one relaxed `fetch_add`.
//!
//! A [`RegistrySnapshot`] freezes every series at a point in time (sorted by
//! name, so output is deterministic); [`RegistrySnapshot::counter_delta`]
//! subtracts a baseline snapshot, which is how per-iteration movement is
//! derived without threading individual counter fields through report
//! structs. Exporters: [`RegistrySnapshot::to_json`] (the per-iteration
//! `artifacts/runs/` snapshot files) and [`RegistrySnapshot::to_prometheus`]
//! (Prometheus text exposition, histograms as summaries).

use super::histogram::{AtomicHistogram, Histogram};
use crate::check::sync::atomic::{AtomicU64, Ordering};
use crate::check::sync::{lock_or_poison, Arc, Mutex};
use crate::util::json::Json;

/// Monotonic counter handle (clone = same underlying cell).
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value-wins gauge handle (stores f64 bits atomically).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Concurrent histogram handle (see [`AtomicHistogram`]).
#[derive(Debug, Clone)]
pub struct HistogramHandle(Arc<AtomicHistogram>);

impl HistogramHandle {
    pub fn observe(&self, v: f64) {
        self.0.observe(v);
    }

    pub fn count(&self) -> u64 {
        self.0.count()
    }

    pub fn snapshot(&self) -> Histogram {
        self.0.snapshot()
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: Mutex<Vec<(String, Arc<AtomicU64>)>>,
    gauges: Mutex<Vec<(String, Arc<AtomicU64>)>>,
    hists: Mutex<Vec<(String, Arc<AtomicHistogram>)>>,
}

/// The unified metric plane. Cloning shares the same registry; the mutexes
/// guard only the name→cell tables (registration and snapshotting), never
/// the hot update path.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut tab = lock_or_poison(&self.inner.counters);
        if let Some((_, c)) = tab.iter().find(|(n, _)| n == name) {
            return Counter(c.clone());
        }
        let c = Arc::new(AtomicU64::new(0));
        tab.push((name.to_string(), c.clone()));
        Counter(c)
    }

    /// Get-or-create the gauge named `name` (initial value 0.0).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut tab = lock_or_poison(&self.inner.gauges);
        if let Some((_, g)) = tab.iter().find(|(n, _)| n == name) {
            return Gauge(g.clone());
        }
        let g = Arc::new(AtomicU64::new(0.0f64.to_bits()));
        tab.push((name.to_string(), g.clone()));
        Gauge(g)
    }

    /// Get-or-create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> HistogramHandle {
        let mut tab = lock_or_poison(&self.inner.hists);
        if let Some((_, h)) = tab.iter().find(|(n, _)| n == name) {
            return HistogramHandle(h.clone());
        }
        let h = Arc::new(AtomicHistogram::new());
        tab.push((name.to_string(), h.clone()));
        HistogramHandle(h)
    }

    /// Freeze every registered series, sorted by name.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let mut counters: Vec<(String, u64)> = lock_or_poison(&self.inner.counters)
            .iter()
            .map(|(n, c)| (n.clone(), c.load(Ordering::Relaxed)))
            .collect();
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        let mut gauges: Vec<(String, f64)> = lock_or_poison(&self.inner.gauges)
            .iter()
            .map(|(n, g)| (n.clone(), f64::from_bits(g.load(Ordering::Relaxed))))
            .collect();
        gauges.sort_by(|a, b| a.0.cmp(&b.0));
        let mut hists: Vec<(String, Histogram)> = lock_or_poison(&self.inner.hists)
            .iter()
            .map(|(n, h)| (n.clone(), h.snapshot()))
            .collect();
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        RegistrySnapshot { counters, gauges, hists }
    }
}

/// A point-in-time freeze of a [`Registry`], sorted by series name.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, Histogram)>,
}

impl RegistrySnapshot {
    /// Counter movement since `baseline`. Names absent from the baseline
    /// count from zero; subtraction saturates so a swapped pair of
    /// arguments can never underflow.
    pub fn counter_delta(&self, baseline: &RegistrySnapshot) -> Vec<(String, u64)> {
        self.counters
            .iter()
            .map(|(name, v)| {
                let base = baseline
                    .counters
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, b)| *b)
                    .unwrap_or(0);
                (name.clone(), v.saturating_sub(base))
            })
            .collect()
    }

    /// Snapshot as JSON: `{"counters": {...}, "gauges": {...},
    /// "histograms": {name: summary-object}}`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(n, v)| (n.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges.iter().map(|(n, v)| (n.clone(), Json::num(*v))).collect(),
        );
        let hists = Json::Obj(
            self.hists.iter().map(|(n, h)| (n.clone(), h.to_json())).collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }

    /// Prometheus text exposition: counters and gauges as-is, histograms as
    /// summaries (`{quantile="..."}` samples plus `_sum` / `_count`). Series
    /// names are prefixed `pa_rl_` and sanitized to `[a-zA-Z0-9_]`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, h) in &self.hists {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            for q in [0.5, 0.9, 0.99] {
                out.push_str(&format!("{n}{{quantile=\"{q}\"}} {}\n", h.quantile(q)));
            }
            out.push_str(&format!("{n}_sum {}\n", h.sum()));
            out.push_str(&format!("{n}_count {}\n", h.count()));
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 6);
    s.push_str("pa_rl_");
    for ch in name.chars() {
        s.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_resolves_to_same_cell() {
        let reg = Registry::new();
        let a = reg.counter("engine.prefills");
        let b = reg.counter("engine.prefills");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        // a clone of the registry shares the tables
        let c = reg.clone().counter("engine.prefills");
        c.inc();
        assert_eq!(b.get(), 5);
        assert_eq!(reg.snapshot().counters, vec![("engine.prefills".to_string(), 5)]);
    }

    #[test]
    fn snapshot_is_sorted_and_delta_saturates() {
        let reg = Registry::new();
        reg.counter("b.second").add(10);
        reg.counter("a.first").add(1);
        reg.gauge("z.gauge").set(2.5);
        let base = reg.snapshot();
        assert_eq!(base.counters[0].0, "a.first");
        assert_eq!(base.counters[1].0, "b.second");

        reg.counter("b.second").add(5);
        reg.counter("c.new").add(7);
        let now = reg.snapshot();
        let delta = now.counter_delta(&base);
        let get = |name: &str| delta.iter().find(|(n, _)| n == name).unwrap().1;
        assert_eq!(get("a.first"), 0);
        assert_eq!(get("b.second"), 5);
        assert_eq!(get("c.new"), 7); // absent from baseline counts from zero
        // swapped arguments saturate instead of underflowing
        assert!(base.counter_delta(&now).iter().all(|(_, v)| *v == 0));
    }

    #[test]
    fn concurrent_counter_updates_sum_exactly() {
        let reg = Registry::new();
        crate::check::thread::scope(|s| {
            for _ in 0..4 {
                let c = reg.counter("hot");
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(reg.counter("hot").get(), 40_000);
    }

    #[test]
    fn exporters_cover_all_series() {
        let reg = Registry::new();
        reg.counter("store.publishes").add(12);
        reg.gauge("fleet.engines").set(3.0);
        let h = reg.histogram("request.ttft_s");
        for k in 1..=50 {
            h.observe(k as f64 / 100.0);
        }
        let snap = reg.snapshot();

        let j = snap.to_json();
        assert_eq!(
            j.path(&["counters", "store.publishes"]).unwrap().as_f64(),
            Some(12.0)
        );
        assert_eq!(j.path(&["gauges", "fleet.engines"]).unwrap().as_f64(), Some(3.0));
        let ttft = j.path(&["histograms", "request.ttft_s"]).unwrap();
        assert_eq!(ttft.req_f64("count").unwrap(), 50.0);

        let prom = snap.to_prometheus();
        assert!(prom.contains("# TYPE pa_rl_store_publishes counter"), "{prom}");
        assert!(prom.contains("pa_rl_store_publishes 12"), "{prom}");
        assert!(prom.contains("# TYPE pa_rl_fleet_engines gauge"), "{prom}");
        assert!(prom.contains("# TYPE pa_rl_request_ttft_s summary"), "{prom}");
        assert!(prom.contains("pa_rl_request_ttft_s{quantile=\"0.99\"}"), "{prom}");
        assert!(prom.contains("pa_rl_request_ttft_s_count 50"), "{prom}");
        // round-trips through the JSON parser (schema sanity)
        assert!(Json::parse(&j.to_pretty()).is_ok());
    }

    /// The driver's full metric plane (`coordinator/driver.rs`:
    /// `Telemetry::new` histograms + `finish_telemetry` counters/gauges,
    /// including the poisoned-lock counter and the phase-attribution
    /// gauges): every registered series must appear in the Prometheus
    /// exposition exactly once. A series silently dropped from — or
    /// duplicated in — `metrics.prom` passes every other test.
    #[test]
    fn every_registered_metric_exports_exactly_once() {
        const COUNTERS: &[&str] = &[
            "engine.prefills",
            "engine.prefills_skipped",
            "engine.prefill_chunks",
            "engine.prefill_tokens_saved",
            "cache.hit_tokens",
            "cache.miss_tokens",
            "store.cross_engine_hits",
            "store.cross_engine_tokens",
            "store.publishes",
            "store.evictions",
            "route.affinity_hits",
            "route.affinity_spills",
            "request.completed",
            "train.iterations",
            "lock.poisoned",
        ];
        const GAUGES: &[&str] = &[
            "fleet.engines",
            "cache.kv_hit_rate",
            "phase.producer_idle_s",
            "phase.consumer_wait_s",
            "phase.sync_overhead_s",
            "phase.useful_compute_s",
            "phase.pipeline_efficiency",
        ];
        const HISTS: &[&str] = &[
            "request.ttft_s",
            "request.queue_wait_s",
            "request.decode_tok_per_s",
            "request.e2e_s",
            "request.staleness",
        ];
        let reg = Registry::new();
        for (i, n) in COUNTERS.iter().enumerate() {
            reg.counter(n).add(i as u64 + 1);
        }
        for (i, n) in GAUGES.iter().enumerate() {
            reg.gauge(n).set(i as f64 + 0.5);
        }
        for n in HISTS {
            reg.histogram(n).observe(1.0);
        }
        let snap = reg.snapshot();
        assert_eq!(snap.counters.len(), COUNTERS.len());
        assert_eq!(snap.gauges.len(), GAUGES.len());
        assert_eq!(snap.hists.len(), HISTS.len());

        let prom = snap.to_prometheus();
        let count = |needle: &str| prom.matches(needle).count();
        for n in COUNTERS {
            let p = prom_name(n);
            assert_eq!(count(&format!("# TYPE {p} counter\n")), 1, "{p} TYPE");
            // Trailing space excludes longer names sharing the prefix
            // (engine.prefills vs engine.prefills_skipped); the leading
            // newline excludes the TYPE line itself.
            assert_eq!(count(&format!("\n{p} ")), 1, "{p} sample:\n{prom}");
        }
        for n in GAUGES {
            let p = prom_name(n);
            assert_eq!(count(&format!("# TYPE {p} gauge\n")), 1, "{p} TYPE");
            assert_eq!(count(&format!("\n{p} ")), 1, "{p} sample:\n{prom}");
        }
        for n in HISTS {
            let p = prom_name(n);
            assert_eq!(count(&format!("# TYPE {p} summary\n")), 1, "{p} TYPE");
            assert_eq!(count(&format!("{p}{{quantile=")), 3, "{p} quantiles");
            assert_eq!(count(&format!("{p}_sum ")), 1, "{p} sum");
            assert_eq!(count(&format!("{p}_count ")), 1, "{p} count");
        }
    }
}
