//! Wall-clock execution timeline (paper Figure 3).
//!
//! Threads record named spans into lanes ("infer-0", "train", "sync"); the
//! trace renders as JSON (machine-readable) or as an ASCII timeline that
//! makes the sync-vs-async overlap visible exactly like the paper's figure:
//!
//! ```text
//! infer-0 |████████████░░░░░░░░░░░░|
//! train   |░░░░████████████████████|
//! ```

use crate::check::sync::{lock_or_poison, Arc, Mutex};
use crate::metrics::timeline::Clock;
use crate::util::json::Json;
use std::time::Instant;

/// One recorded span.
#[derive(Debug, Clone)]
pub struct Span {
    pub lane: String,
    pub name: String,
    pub start_s: f64,
    pub end_s: f64,
}

/// Thread-safe trace recorder anchored at a run's start instant.
#[derive(Clone)]
pub struct Trace {
    epoch: Instant,
    spans: Arc<Mutex<Vec<Span>>>,
    /// Per-lane scalar annotations, e.g. `("infer-0", "kv_hit", 0.88)` —
    /// latest value wins. Rendered beside the lane's timeline so throughput
    /// lines carry the prefix-cache hit rate.
    notes: Arc<Mutex<Vec<(String, String, f64)>>>,
}

impl Default for Trace {
    fn default() -> Self {
        Self::new()
    }
}

impl Trace {
    pub fn new() -> Trace {
        Trace {
            epoch: Instant::now(),
            spans: Arc::new(Mutex::new(Vec::new())),
            notes: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Attach (or refresh) a named scalar on a lane.
    pub fn annotate(&self, lane: &str, key: &str, value: f64) {
        let mut notes = lock_or_poison(&self.notes);
        match notes.iter_mut().find(|(l, k, _)| l == lane && k == key) {
            Some(entry) => entry.2 = value,
            None => notes.push((lane.to_string(), key.to_string(), value)),
        }
    }

    /// All lane annotations (lane, key, value).
    pub fn annotations(&self) -> Vec<(String, String, f64)> {
        lock_or_poison(&self.notes).clone()
    }

    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// A copyable [`Clock`] sharing this trace's epoch, so request-timeline
    /// stamps and span timestamps live on the same time axis.
    pub fn clock(&self) -> Clock {
        Clock::from_epoch(self.epoch)
    }

    /// Record a span that started at `start_s` (from [`Trace::now`]) and ends
    /// now.
    pub fn record(&self, lane: &str, name: &str, start_s: f64) {
        let end_s = self.now();
        lock_or_poison(&self.spans).push(Span {
            lane: lane.to_string(),
            name: name.to_string(),
            start_s,
            end_s,
        });
    }

    /// Record with explicit bounds (simulator).
    pub fn record_abs(&self, lane: &str, name: &str, start_s: f64, end_s: f64) {
        lock_or_poison(&self.spans).push(Span {
            lane: lane.to_string(),
            name: name.to_string(),
            start_s,
            end_s,
        });
    }

    pub fn spans(&self) -> Vec<Span> {
        lock_or_poison(&self.spans).clone()
    }

    /// Total busy time per lane.
    pub fn lane_busy(&self) -> Vec<(String, f64)> {
        let spans = lock_or_poison(&self.spans);
        let mut lanes: Vec<(String, f64)> = Vec::new();
        for s in spans.iter() {
            match lanes.iter_mut().find(|(l, _)| *l == s.lane) {
                Some((_, acc)) => *acc += s.end_s - s.start_s,
                None => lanes.push((s.lane.clone(), s.end_s - s.start_s)),
            }
        }
        lanes
    }

    /// Machine-readable form: `{"spans": [...], "annotations": [...]}`. The
    /// annotations carry per-lane scalars (notably each engine lane's
    /// `kv_hit` rate) so the fig3 timeline files record cache effectiveness
    /// alongside the spans.
    pub fn to_json(&self) -> Json {
        let spans = Json::arr(self.spans().into_iter().map(|s| {
            Json::obj(vec![
                ("lane", Json::str(&s.lane)),
                ("name", Json::str(&s.name)),
                ("start", Json::num(s.start_s)),
                ("end", Json::num(s.end_s)),
            ])
        }));
        let notes = Json::arr(self.annotations().into_iter().map(|(lane, key, value)| {
            Json::obj(vec![
                ("lane", Json::str(&lane)),
                ("key", Json::str(&key)),
                ("value", Json::num(value)),
            ])
        }));
        Json::obj(vec![("spans", spans), ("annotations", notes)])
    }

    /// ASCII rendering: one row per lane, `width` columns over [0, t_max].
    pub fn render_ascii(&self, width: usize) -> String {
        let spans = self.spans();
        if spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let t_max = spans.iter().map(|s| s.end_s).fold(0.0f64, f64::max).max(1e-9);
        let mut lanes: Vec<String> = Vec::new();
        for s in &spans {
            if !lanes.contains(&s.lane) {
                lanes.push(s.lane.clone());
            }
        }
        lanes.sort_by(|a, b| lane_sort_key(a).cmp(&lane_sort_key(b)));
        let name_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        out.push_str(&format!(
            "timeline 0.0s .. {t_max:.2}s ({} spans; █ busy, · idle)\n",
            spans.len()
        ));
        for lane in &lanes {
            let mut cells = vec!['·'; width];
            for s in spans.iter().filter(|s| &s.lane == lane) {
                let a = ((s.start_s / t_max) * width as f64).floor() as usize;
                let b = ((s.end_s / t_max) * width as f64).ceil() as usize;
                for cell in cells.iter_mut().take(b.min(width)).skip(a.min(width)) {
                    *cell = '█';
                }
            }
            let bar: String = cells.into_iter().collect();
            let notes = self
                .annotations()
                .into_iter()
                .filter(|(l, _, _)| l == lane.as_str())
                .map(|(_, k, v)| format!(" {k}={v:.2}"))
                .collect::<String>();
            out.push_str(&format!("{lane:<name_w$} |{bar}|{notes}\n"));
        }
        // Lanes that carry only scalar annotations, no spans — e.g. the
        // cross-engine store's fill/hit gauges — render as a trailing line
        // each so the gauges aren't JSON-only.
        let mut bare: Vec<String> = Vec::new();
        for (l, k, v) in self.annotations() {
            if !lanes.contains(&l) {
                match bare.iter_mut().find(|s| s.starts_with(&format!("{l}:"))) {
                    Some(s) => s.push_str(&format!(" {k}={v:.2}")),
                    None => bare.push(format!("{l}: {k}={v:.2}")),
                }
            }
        }
        for line in bare {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

/// Sort key for lane names: a trailing decimal suffix orders numerically
/// (`infer-2` before `infer-10`), so wide fleets render in engine order; the
/// full name breaks remaining ties lexicographically.
fn lane_sort_key(lane: &str) -> (&str, Option<u64>, &str) {
    let digits = lane.bytes().rev().take_while(u8::is_ascii_digit).count();
    let split = lane.len() - digits;
    let (head, tail) = lane.split_at(split);
    (head, tail.parse::<u64>().ok(), lane)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "gen", 0.0, 0.6);
        tr.record_abs("train", "micro", 0.3, 1.0);
        let busy = tr.lane_busy();
        assert_eq!(busy.len(), 2);
        let infer = busy.iter().find(|(l, _)| l == "infer-0").unwrap().1;
        assert!((infer - 0.6).abs() < 1e-9);
        let art = tr.render_ascii(20);
        assert!(art.contains("infer-0"));
        assert!(art.contains('█'));
        // json form carries spans and (empty) annotations
        let j = tr.to_json();
        assert_eq!(j.req("spans").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.req("annotations").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn thread_safe_recording() {
        let tr = Trace::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let tr2 = tr.clone();
            handles.push(crate::check::thread::spawn(move || {
                for k in 0..25 {
                    tr2.record_abs(&format!("lane-{i}"), "x", k as f64, k as f64 + 0.5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(tr.spans().len(), 100);
    }

    #[test]
    fn empty_trace_renders() {
        assert!(Trace::new().render_ascii(10).contains("empty"));
    }

    #[test]
    fn lanes_render_in_numeric_engine_order() {
        let tr = Trace::new();
        // recorded out of order, with enough engines that lexicographic
        // sorting would interleave (infer-10 < infer-2 as strings)
        for idx in [10, 2, 0, 1, 11] {
            tr.record_abs(&format!("infer-{idx}"), "step", 0.0, 1.0);
        }
        tr.record_abs("train", "micro", 0.0, 1.0);
        let art = tr.render_ascii(10);
        let order: Vec<usize> = art
            .lines()
            .filter_map(|l| l.split_whitespace().next())
            .filter_map(|lane| lane.strip_prefix("infer-"))
            .filter_map(|n| n.parse().ok())
            .collect();
        assert_eq!(order, vec![0, 1, 2, 10, 11], "{art}");
        // digit-less lanes still sort lexicographically among themselves
        assert!(art.find("infer-11").unwrap() < art.find("train").unwrap());
    }

    #[test]
    fn annotations_render_and_refresh() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "step", 0.0, 1.0);
        tr.annotate("infer-0", "kv_hit", 0.5);
        tr.annotate("infer-0", "kv_hit", 0.88); // latest value wins
        tr.annotate("store", "fetch_hits", 12.0); // span-less gauge lane
        assert_eq!(tr.annotations().len(), 2);
        let art = tr.render_ascii(20);
        assert!(art.contains("kv_hit=0.88"), "{art}");
        assert!(!art.contains("kv_hit=0.50"), "{art}");
        // Annotation-only lanes render as trailing gauge lines.
        assert!(art.contains("store: fetch_hits=12.00"), "{art}");
        // kv_hit reaches the machine-readable timeline output too
        let j = tr.to_json();
        let notes = j.req("annotations").unwrap().as_arr().unwrap();
        assert_eq!(notes.len(), 2);
        assert_eq!(notes[0].req_str("key").unwrap(), "kv_hit");
    }
}
