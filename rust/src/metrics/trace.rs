//! Wall-clock execution timeline (paper Figure 3) and causal span tree.
//!
//! Threads record named spans into lanes ("infer-0", "train", "sync"); the
//! trace renders as JSON (machine-readable) or as an ASCII timeline that
//! makes the sync-vs-async overlap visible exactly like the paper's figure:
//!
//! ```text
//! infer-0 |████████████░░░░░░░░░░░░|
//! train   |░░░░████████████████████|
//! ```
//!
//! Each span carries a trace-unique `id` and an optional `parent` id, so
//! causally-linked work (an iteration root → its dispatch / sync / train
//! children, a dispatch → the engine-side generation it triggered) forms a
//! tree across threads. [`Trace::to_chrome_json`] exports the whole tree as
//! Chrome trace-event JSON loadable in Perfetto / `chrome://tracing`: one
//! process per engine plus driver/trainer lanes, overlapping spans spread
//! over per-lane tracks so every track's B/E events nest trivially.

use crate::check::sync::atomic::{AtomicU64, Ordering};
use crate::check::sync::{lock_or_poison, Arc, Mutex};
use crate::metrics::timeline::Clock;
use crate::util::json::Json;
use std::time::Instant;

/// Parent id for a root span (span ids start at 1).
pub const NO_PARENT: u64 = 0;

/// One recorded span.
#[derive(Debug, Clone)]
pub struct Span {
    /// Trace-unique id (>= 1); [`NO_PARENT`] never names a real span.
    pub id: u64,
    /// Id of the causally-enclosing span, or [`NO_PARENT`] for roots.
    pub parent: u64,
    pub lane: String,
    pub name: String,
    pub start_s: f64,
    pub end_s: f64,
}

/// Thread-safe trace recorder anchored at a run's start instant.
#[derive(Clone)]
pub struct Trace {
    epoch: Instant,
    spans: Arc<Mutex<Vec<Span>>>,
    /// Next span id; 0 is reserved as [`NO_PARENT`].
    next_id: Arc<AtomicU64>,
    /// Per-lane scalar annotations, e.g. `("infer-0", "kv_hit", 0.88)` —
    /// latest value wins. Rendered beside the lane's timeline so throughput
    /// lines carry the prefix-cache hit rate.
    notes: Arc<Mutex<Vec<(String, String, f64)>>>,
}

impl Default for Trace {
    fn default() -> Self {
        Self::new()
    }
}

impl Trace {
    pub fn new() -> Trace {
        Trace {
            epoch: Instant::now(),
            spans: Arc::new(Mutex::new(Vec::new())),
            next_id: Arc::new(AtomicU64::new(1)),
            notes: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Attach (or refresh) a named scalar on a lane.
    pub fn annotate(&self, lane: &str, key: &str, value: f64) {
        let mut notes = lock_or_poison(&self.notes);
        match notes.iter_mut().find(|(l, k, _)| l == lane && k == key) {
            Some(entry) => entry.2 = value,
            None => notes.push((lane.to_string(), key.to_string(), value)),
        }
    }

    /// All lane annotations (lane, key, value).
    pub fn annotations(&self) -> Vec<(String, String, f64)> {
        lock_or_poison(&self.notes).clone()
    }

    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// A copyable [`Clock`] sharing this trace's epoch, so request-timeline
    /// stamps and span timestamps live on the same time axis.
    pub fn clock(&self) -> Clock {
        Clock::from_epoch(self.epoch)
    }

    /// Reserve a span id without recording yet — for roots (an iteration)
    /// whose children are recorded before the root's own end is known.
    pub fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record a span that started at `start_s` (from [`Trace::now`]) and ends
    /// now. Returns the new span's id so callers can parent further work.
    pub fn record(&self, lane: &str, name: &str, start_s: f64) -> u64 {
        self.record_child(lane, name, start_s, NO_PARENT)
    }

    /// [`Trace::record`] with an explicit causal parent.
    pub fn record_child(&self, lane: &str, name: &str, start_s: f64, parent: u64) -> u64 {
        let end_s = self.now();
        self.push(lane, name, start_s, end_s, parent)
    }

    /// Record with explicit bounds (simulator). Returns the span's id.
    pub fn record_abs(&self, lane: &str, name: &str, start_s: f64, end_s: f64) -> u64 {
        self.push(lane, name, start_s, end_s, NO_PARENT)
    }

    /// [`Trace::record_abs`] with an explicit causal parent.
    pub fn record_abs_child(
        &self,
        lane: &str,
        name: &str,
        start_s: f64,
        end_s: f64,
        parent: u64,
    ) -> u64 {
        self.push(lane, name, start_s, end_s, parent)
    }

    /// Record a span under a pre-allocated id (see [`Trace::alloc_id`]) —
    /// how an iteration root is closed after its children already recorded
    /// themselves against it.
    pub fn record_reserved(
        &self,
        id: u64,
        lane: &str,
        name: &str,
        start_s: f64,
        end_s: f64,
        parent: u64,
    ) {
        lock_or_poison(&self.spans).push(Span {
            id,
            parent,
            lane: lane.to_string(),
            name: name.to_string(),
            start_s,
            end_s,
        });
    }

    fn push(&self, lane: &str, name: &str, start_s: f64, end_s: f64, parent: u64) -> u64 {
        let id = self.alloc_id();
        self.record_reserved(id, lane, name, start_s, end_s, parent);
        id
    }

    pub fn spans(&self) -> Vec<Span> {
        lock_or_poison(&self.spans).clone()
    }

    /// The most recently *recorded* span per lane (insertion order, not end
    /// time) — the stall watchdog's "what was each lane last doing".
    pub fn last_span_per_lane(&self) -> Vec<Span> {
        let spans = lock_or_poison(&self.spans);
        let mut last: Vec<Span> = Vec::new();
        for s in spans.iter() {
            match last.iter_mut().find(|l| l.lane == s.lane) {
                Some(slot) => *slot = s.clone(),
                None => last.push(s.clone()),
            }
        }
        last.sort_by(|a, b| lane_sort_key(&a.lane).cmp(&lane_sort_key(&b.lane)));
        last
    }

    /// Total busy time per lane.
    pub fn lane_busy(&self) -> Vec<(String, f64)> {
        let spans = lock_or_poison(&self.spans);
        let mut lanes: Vec<(String, f64)> = Vec::new();
        for s in spans.iter() {
            match lanes.iter_mut().find(|(l, _)| *l == s.lane) {
                Some((_, acc)) => *acc += s.end_s - s.start_s,
                None => lanes.push((s.lane.clone(), s.end_s - s.start_s)),
            }
        }
        lanes
    }

    /// Machine-readable form: `{"spans": [...], "annotations": [...]}`. The
    /// annotations carry per-lane scalars (notably each engine lane's
    /// `kv_hit` rate and the driver's per-phase attribution) so the fig3
    /// timeline files record cache effectiveness alongside the spans.
    pub fn to_json(&self) -> Json {
        let spans = Json::arr(self.spans().into_iter().map(|s| {
            Json::obj(vec![
                ("lane", Json::str(&s.lane)),
                ("name", Json::str(&s.name)),
                ("start", Json::num(s.start_s)),
                ("end", Json::num(s.end_s)),
            ])
        }));
        let notes = Json::arr(self.annotations().into_iter().map(|(lane, key, value)| {
            Json::obj(vec![
                ("lane", Json::str(&lane)),
                ("key", Json::str(&key)),
                ("value", Json::num(value)),
            ])
        }));
        Json::obj(vec![("spans", spans), ("annotations", notes)])
    }

    /// Chrome trace-event JSON (Perfetto / `chrome://tracing`-loadable):
    /// `{"traceEvents": [...]}` with B/E duration events in microseconds.
    ///
    /// * One *process* (pid) per engine — any lane with a numeric suffix
    ///   (`infer-3`, `req-3`) maps to `pid = 100 + 3` — plus pid 2 for the
    ///   trainer (`train*` lanes) and pid 1 for everything driver-side.
    /// * One *thread* (tid) per non-overlapping track: a lane whose spans
    ///   overlap (concurrent slot generations) is spread greedily over
    ///   `lane.0`, `lane.1`, ... so each tid's B/E events nest trivially.
    /// * Every B event's `args` carries the span's causal `id`/`parent`.
    /// * Events are globally sorted by `ts` (E before B on ties), so the
    ///   stream is monotonic — the invariant the schema proptest pins.
    pub fn to_chrome_json(&self) -> Json {
        let mut spans = self.spans();
        spans.sort_by(|a, b| {
            lane_sort_key(&a.lane)
                .cmp(&lane_sort_key(&b.lane))
                .then(a.start_s.total_cmp(&b.start_s))
                .then(a.id.cmp(&b.id))
        });

        // Group into lanes (already sorted), then partition each lane's
        // spans into non-overlapping tracks.
        let mut events: Vec<(i64, u8, Json)> = Vec::new(); // (ts_us, order, event)
        let mut named_pids: Vec<i64> = Vec::new();
        let mut next_tid: Vec<(i64, i64)> = Vec::new(); // per-pid tid counter
        let mut i = 0;
        while i < spans.len() {
            let lane = spans[i].lane.clone();
            let mut j = i;
            while j < spans.len() && spans[j].lane == lane {
                j += 1;
            }
            let pid = lane_pid(&lane);
            if !named_pids.contains(&pid) {
                named_pids.push(pid);
                events.push((i64::MIN, 0, meta_event(pid, None, "process_name", pid_name(pid))));
            }
            // Greedy interval partitioning: first track whose last end fits.
            let mut tracks: Vec<f64> = Vec::new(); // last end per track
            let mut assigned: Vec<(usize, &Span)> = Vec::new();
            for s in &spans[i..j] {
                let start = s.start_s.min(s.end_s);
                let track = match tracks.iter().position(|&end| end <= start + 1e-12) {
                    Some(t) => t,
                    None => {
                        tracks.push(f64::NEG_INFINITY);
                        tracks.len() - 1
                    }
                };
                tracks[track] = s.start_s.max(s.end_s);
                assigned.push((track, s));
            }
            // Stable tids: tracks of a lane take consecutive tids within the
            // lane's pid, in lane-sorted order.
            let counter = match next_tid.iter_mut().find(|(p, _)| *p == pid) {
                Some(c) => c,
                None => {
                    next_tid.push((pid, 1));
                    // pa-lint: allow(unwrap): pushed the entry on the line above
                    next_tid.last_mut().expect("just pushed")
                }
            };
            let tid0 = counter.1;
            counter.1 += tracks.len() as i64;
            for (t, tid) in (0..tracks.len()).zip(tid0..) {
                let label = if tracks.len() == 1 {
                    lane.clone()
                } else {
                    format!("{lane}.{t}")
                };
                events.push((i64::MIN, 1, meta_event(pid, Some(tid), "thread_name", &label)));
            }
            for (track, s) in assigned {
                let tid = tid0 + track as i64;
                let ts = us(s.start_s.min(s.end_s));
                let te = us(s.start_s.max(s.end_s)).max(ts);
                events.push((
                    ts,
                    1,
                    Json::obj(vec![
                        ("name", Json::str(&s.name)),
                        ("cat", Json::str(&lane)),
                        ("ph", Json::str("B")),
                        ("ts", Json::num(ts as f64)),
                        ("pid", Json::num(pid as f64)),
                        ("tid", Json::num(tid as f64)),
                        (
                            "args",
                            Json::obj(vec![
                                ("id", Json::num(s.id as f64)),
                                ("parent", Json::num(s.parent as f64)),
                            ]),
                        ),
                    ]),
                ));
                events.push((
                    te,
                    0,
                    Json::obj(vec![
                        ("ph", Json::str("E")),
                        ("ts", Json::num(te as f64)),
                        ("pid", Json::num(pid as f64)),
                        ("tid", Json::num(tid as f64)),
                    ]),
                ));
            }
            i = j;
        }
        events.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        Json::obj(vec![
            ("traceEvents", Json::arr(events.into_iter().map(|(_, _, e)| e))),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// ASCII rendering: one row per lane, `width` columns over [0, t_max].
    pub fn render_ascii(&self, width: usize) -> String {
        let spans = self.spans();
        if spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let t_max = spans.iter().map(|s| s.end_s).fold(0.0f64, f64::max).max(1e-9);
        let mut lanes: Vec<String> = Vec::new();
        for s in &spans {
            if !lanes.contains(&s.lane) {
                lanes.push(s.lane.clone());
            }
        }
        lanes.sort_by(|a, b| lane_sort_key(a).cmp(&lane_sort_key(b)));
        let name_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        out.push_str(&format!(
            "timeline 0.0s .. {t_max:.2}s ({} spans; █ busy, · idle)\n",
            spans.len()
        ));
        for lane in &lanes {
            let mut cells = vec!['·'; width];
            for s in spans.iter().filter(|s| &s.lane == lane) {
                let a = ((s.start_s / t_max) * width as f64).floor() as usize;
                let b = ((s.end_s / t_max) * width as f64).ceil() as usize;
                for cell in cells.iter_mut().take(b.min(width)).skip(a.min(width)) {
                    *cell = '█';
                }
            }
            let bar: String = cells.into_iter().collect();
            let notes = self
                .annotations()
                .into_iter()
                .filter(|(l, _, _)| l == lane.as_str())
                .map(|(_, k, v)| format!(" {k}={v:.2}"))
                .collect::<String>();
            out.push_str(&format!("{lane:<name_w$} |{bar}|{notes}\n"));
        }
        // Lanes that carry only scalar annotations, no spans — e.g. the
        // cross-engine store's fill/hit gauges — render as a trailing line
        // each so the gauges aren't JSON-only.
        let mut bare: Vec<String> = Vec::new();
        for (l, k, v) in self.annotations() {
            if !lanes.contains(&l) {
                match bare.iter_mut().find(|s| s.starts_with(&format!("{l}:"))) {
                    Some(s) => s.push_str(&format!(" {k}={v:.2}")),
                    None => bare.push(format!("{l}: {k}={v:.2}")),
                }
            }
        }
        for line in bare {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

/// Microseconds for Chrome `ts`, clamped at zero (spans never predate the
/// epoch; tiny negative rounding artifacts must not break monotonicity).
fn us(s: f64) -> i64 {
    ((s * 1e6).round() as i64).max(0)
}

/// Chrome pid for a lane: numeric-suffixed lanes (`infer-3`) are engine
/// processes (`100 + n`), `train*` lanes are the trainer (2), everything
/// else rides the driver process (1).
fn lane_pid(lane: &str) -> i64 {
    let (head, suffix, _) = lane_sort_key(lane);
    if let Some(n) = suffix {
        if head.ends_with('-') || head.ends_with('_') {
            return 100 + n as i64;
        }
    }
    if lane.starts_with("train") {
        2
    } else {
        1
    }
}

fn pid_name(pid: i64) -> String {
    match pid {
        1 => "driver".to_string(),
        2 => "trainer".to_string(),
        n => format!("engine-{}", n - 100),
    }
}

fn meta_event(pid: i64, tid: Option<i64>, name: &str, value: &str) -> Json {
    let mut fields = vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
    ];
    if let Some(t) = tid {
        fields.push(("tid", Json::num(t as f64)));
    }
    fields.push(("args", Json::obj(vec![("name", Json::str(value))])));
    Json::obj(fields)
}

/// Sort key for lane names: a trailing decimal suffix orders numerically
/// (`infer-2` before `infer-10`), so wide fleets render in engine order; the
/// full name breaks remaining ties lexicographically.
fn lane_sort_key(lane: &str) -> (&str, Option<u64>, &str) {
    let digits = lane.bytes().rev().take_while(u8::is_ascii_digit).count();
    let split = lane.len() - digits;
    let (head, tail) = lane.split_at(split);
    (head, tail.parse::<u64>().ok(), lane)
}

/// Validate a parsed Chrome trace-event document against the invariants the
/// exporter guarantees: monotonic non-decreasing `ts`, per-tid matched and
/// alternating B/E pairs, and a stable pid/tid per (lane, track) — shared by
/// the schema proptest and `pa-report trace`.
pub fn validate_chrome_trace(doc: &Json) -> Result<(), String> {
    let events = doc
        .req("traceEvents")
        .ok()
        .and_then(Json::as_arr)
        .ok_or("missing 'traceEvents' array")?;
    let mut last_ts = f64::NEG_INFINITY;
    // (pid, tid) -> open B count; strict alternation means depth is 0 or 1.
    let mut open: Vec<((i64, i64), u32)> = Vec::new();
    // tid -> lane (cat) seen, to pin pid/tid stability per lane track.
    let mut tid_lane: Vec<((i64, i64), String)> = Vec::new();
    for (idx, e) in events.iter().enumerate() {
        let ph = e.req_str("ph").map_err(|_| format!("event {idx}: missing 'ph'"))?;
        if ph == "M" {
            continue;
        }
        if ph != "B" && ph != "E" {
            return Err(format!("event {idx}: unexpected phase '{ph}'"));
        }
        let ts = e.req_f64("ts").map_err(|_| format!("event {idx}: missing 'ts'"))?;
        if !ts.is_finite() || ts < 0.0 {
            return Err(format!("event {idx}: bad ts {ts}"));
        }
        if ts < last_ts {
            return Err(format!("event {idx}: ts {ts} < previous {last_ts} (not monotonic)"));
        }
        last_ts = ts;
        let pid = e.req_f64("pid").map_err(|_| format!("event {idx}: missing 'pid'"))? as i64;
        let tid = e.req_f64("tid").map_err(|_| format!("event {idx}: missing 'tid'"))? as i64;
        let key = (pid, tid);
        let depth = match open.iter_mut().find(|(k, _)| *k == key) {
            Some(d) => &mut d.1,
            None => {
                open.push((key, 0));
                // pa-lint: allow(unwrap): pushed the entry on the line above
                &mut open.last_mut().expect("just pushed").1
            }
        };
        if ph == "B" {
            if *depth != 0 {
                return Err(format!("event {idx}: B while pid {pid} tid {tid} already open"));
            }
            *depth = 1;
            let lane = e.req_str("cat").map_err(|_| format!("event {idx}: B without 'cat'"))?;
            match tid_lane.iter().find(|(k, _)| *k == key) {
                Some((_, seen)) if seen != lane => {
                    return Err(format!(
                        "event {idx}: pid {pid} tid {tid} hosts lanes '{seen}' and '{lane}'"
                    ));
                }
                Some(_) => {}
                None => tid_lane.push((key, lane.to_string())),
            }
        } else {
            if *depth != 1 {
                return Err(format!("event {idx}: E without open B on pid {pid} tid {tid}"));
            }
            *depth = 0;
        }
    }
    if let Some(((pid, tid), _)) = open.iter().find(|(_, d)| *d != 0) {
        return Err(format!("unclosed B event on pid {pid} tid {tid}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn records_and_renders() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "gen", 0.0, 0.6);
        tr.record_abs("train", "micro", 0.3, 1.0);
        let busy = tr.lane_busy();
        assert_eq!(busy.len(), 2);
        let infer = busy.iter().find(|(l, _)| l == "infer-0").unwrap().1;
        assert!((infer - 0.6).abs() < 1e-9);
        let art = tr.render_ascii(20);
        assert!(art.contains("infer-0"));
        assert!(art.contains('█'));
        // json form carries spans and (empty) annotations
        let j = tr.to_json();
        assert_eq!(j.req("spans").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.req("annotations").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn thread_safe_recording() {
        let tr = Trace::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let tr2 = tr.clone();
            handles.push(crate::check::thread::spawn(move || {
                for k in 0..25 {
                    tr2.record_abs(&format!("lane-{i}"), "x", k as f64, k as f64 + 0.5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(tr.spans().len(), 100);
    }

    #[test]
    fn empty_trace_renders() {
        assert!(Trace::new().render_ascii(10).contains("empty"));
    }

    #[test]
    fn lanes_render_in_numeric_engine_order() {
        let tr = Trace::new();
        // recorded out of order, with enough engines that lexicographic
        // sorting would interleave (infer-10 < infer-2 as strings)
        for idx in [10, 2, 0, 1, 11] {
            tr.record_abs(&format!("infer-{idx}"), "step", 0.0, 1.0);
        }
        tr.record_abs("train", "micro", 0.0, 1.0);
        let art = tr.render_ascii(10);
        let order: Vec<usize> = art
            .lines()
            .filter_map(|l| l.split_whitespace().next())
            .filter_map(|lane| lane.strip_prefix("infer-"))
            .filter_map(|n| n.parse().ok())
            .collect();
        assert_eq!(order, vec![0, 1, 2, 10, 11], "{art}");
        // digit-less lanes still sort lexicographically among themselves
        assert!(art.find("infer-11").unwrap() < art.find("train").unwrap());
    }

    #[test]
    fn annotations_render_and_refresh() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "step", 0.0, 1.0);
        tr.annotate("infer-0", "kv_hit", 0.5);
        tr.annotate("infer-0", "kv_hit", 0.88); // latest value wins
        tr.annotate("store", "fetch_hits", 12.0); // span-less gauge lane
        assert_eq!(tr.annotations().len(), 2);
        let art = tr.render_ascii(20);
        assert!(art.contains("kv_hit=0.88"), "{art}");
        assert!(!art.contains("kv_hit=0.50"), "{art}");
        // Annotation-only lanes render as trailing gauge lines.
        assert!(art.contains("store: fetch_hits=12.00"), "{art}");
        // kv_hit reaches the machine-readable timeline output too
        let j = tr.to_json();
        let notes = j.req("annotations").unwrap().as_arr().unwrap();
        assert_eq!(notes.len(), 2);
        assert_eq!(notes[0].req_str("key").unwrap(), "kv_hit");
    }

    #[test]
    fn span_ids_are_unique_and_parents_link() {
        let tr = Trace::new();
        let root = tr.alloc_id();
        let a = tr.record_abs_child("infer-0", "gen", 0.0, 0.5, root);
        let b = tr.record_abs_child("infer-0", "gen", 0.2, 0.9, root);
        tr.record_reserved(root, "driver", "iter", 0.0, 1.0, NO_PARENT);
        let spans = tr.spans();
        assert_eq!(spans.len(), 3);
        let mut ids: Vec<u64> = spans.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3, "ids must be unique");
        assert_ne!(a, b);
        for s in spans.iter().filter(|s| s.lane == "infer-0") {
            assert_eq!(s.parent, root);
        }
        assert_eq!(
            spans.iter().find(|s| s.id == root).unwrap().parent,
            NO_PARENT
        );
    }

    #[test]
    fn last_span_per_lane_tracks_latest() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "step", 0.0, 0.1);
        tr.record_abs("train", "micro", 0.0, 0.2);
        tr.record_abs("infer-0", "drain", 0.1, 0.3);
        let last = tr.last_span_per_lane();
        assert_eq!(last.len(), 2);
        assert_eq!(last[0].lane, "infer-0");
        assert_eq!(last[0].name, "drain");
        assert_eq!(last[1].lane, "train");
    }

    #[test]
    fn chrome_export_maps_lanes_to_processes() {
        let tr = Trace::new();
        tr.record_abs("infer-0", "step", 0.0, 0.5);
        tr.record_abs("infer-3", "step", 0.1, 0.6);
        tr.record_abs("train", "micro", 0.2, 0.7);
        tr.record_abs("sync", "weights", 0.0, 0.1);
        let doc = tr.to_chrome_json();
        validate_chrome_trace(&doc).unwrap();
        let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
        // process metadata names driver (1), trainer (2) and engines (100+n)
        let pids: Vec<f64> = events
            .iter()
            .filter(|e| e.req_str("ph").unwrap() == "M")
            .filter(|e| e.req_str("name").unwrap() == "process_name")
            .map(|e| e.req_f64("pid").unwrap())
            .collect();
        assert!(pids.contains(&1.0), "driver pid: {pids:?}");
        assert!(pids.contains(&2.0), "trainer pid: {pids:?}");
        assert!(pids.contains(&100.0) && pids.contains(&103.0), "engine pids: {pids:?}");
        // B events carry causal args and microsecond timestamps
        let b = events
            .iter()
            .find(|e| e.req_str("ph").unwrap() == "B" && e.req_str("cat").unwrap() == "infer-3")
            .unwrap();
        assert_eq!(b.req_f64("ts").unwrap(), 100_000.0);
        assert!(b.req("args").unwrap().req_f64("id").unwrap() >= 1.0);
    }

    #[test]
    fn chrome_export_spreads_overlapping_spans_over_tracks() {
        let tr = Trace::new();
        // three mutually-overlapping generations on one engine lane
        tr.record_abs("req-0", "gen", 0.0, 1.0);
        tr.record_abs("req-0", "gen", 0.2, 0.8);
        tr.record_abs("req-0", "gen", 0.4, 1.2);
        // and a sequential pair that should share one track
        tr.record_abs("infer-0", "step", 0.0, 0.5);
        tr.record_abs("infer-0", "step", 0.5, 1.0);
        let doc = tr.to_chrome_json();
        validate_chrome_trace(&doc).unwrap();
        let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
        let req_tids: std::collections::BTreeSet<i64> = events
            .iter()
            .filter(|e| e.req_str("ph").unwrap() == "B")
            .filter(|e| e.req_str("cat").unwrap() == "req-0")
            .map(|e| e.req_f64("tid").unwrap() as i64)
            .collect();
        assert_eq!(req_tids.len(), 3, "overlapping spans need distinct tracks");
        let step_tids: std::collections::BTreeSet<i64> = events
            .iter()
            .filter(|e| e.req_str("ph").unwrap() == "B")
            .filter(|e| e.req_str("cat").unwrap() == "infer-0")
            .map(|e| e.req_f64("tid").unwrap() as i64)
            .collect();
        assert_eq!(step_tids.len(), 1, "sequential spans share a track");
    }

    #[test]
    fn chrome_export_schema_holds_under_random_traces() {
        prop::quick(
            "chrome-trace-schema",
            |rng, size| {
                let tr = Trace::new();
                let n = rng.range(0, size.scaled(120) + 1);
                for _ in 0..n {
                    let lane = match rng.range(0, 5) {
                        0 => format!("infer-{}", rng.range(0, 4)),
                        1 => format!("req-{}", rng.range(0, 4)),
                        2 => "train".to_string(),
                        3 => "sync".to_string(),
                        _ => "driver".to_string(),
                    };
                    let start = rng.range(0, 5_000) as f64 / 1000.0;
                    let dur = rng.range(0, 2_000) as f64 / 1000.0;
                    let parent = rng.range(0, 3) as u64; // includes dangling parents
                    tr.record_abs_child(&lane, "s", start, start + dur, parent);
                }
                tr.to_chrome_json()
            },
            |doc| validate_chrome_trace(doc),
        );
    }

    #[test]
    fn chrome_validator_rejects_malformed_streams() {
        // non-monotonic ts
        let bad = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![
                Json::obj(vec![
                    ("name", Json::str("a")),
                    ("cat", Json::str("l")),
                    ("ph", Json::str("B")),
                    ("ts", Json::num(10.0)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(1.0)),
                ]),
                Json::obj(vec![
                    ("ph", Json::str("E")),
                    ("ts", Json::num(5.0)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(1.0)),
                ]),
            ]),
        )]);
        assert!(validate_chrome_trace(&bad).unwrap_err().contains("monotonic"));
        // unmatched B
        let open = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("a")),
                ("cat", Json::str("l")),
                ("ph", Json::str("B")),
                ("ts", Json::num(1.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(1.0)),
            ])]),
        )]);
        assert!(validate_chrome_trace(&open).unwrap_err().contains("unclosed"));
    }
}
