//! Measurement plane: wall-clock timeline traces (paper Fig. 3), TPSPD
//! throughput accounting (the paper's headline metric), and CSV curve logs
//! (paper Fig. 5).

pub mod trace;

pub use trace::{Span, Trace};

use std::time::Instant;

/// Tokens-trained-per-second-per-device — the paper's primary metric
/// ("end-to-end training throughput, measured by tokens trained per second
/// per device"). On this testbed a "device" is one engine or trainer
/// instance (each owns a PJRT client); the simulator uses real device counts.
#[derive(Debug, Clone)]
pub struct Tpspd {
    started: Instant,
    pub trained_tokens: u64,
    pub devices: usize,
}

impl Tpspd {
    pub fn start(devices: usize) -> Tpspd {
        Tpspd { started: Instant::now(), trained_tokens: 0, devices }
    }

    pub fn add_tokens(&mut self, n: usize) {
        self.trained_tokens += n as u64;
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn value(&self) -> f64 {
        self.trained_tokens as f64 / (self.elapsed() * self.devices as f64).max(1e-9)
    }

    /// TPSPD from explicit components (simulator / offline computation).
    pub fn compute(tokens: f64, seconds: f64, devices: usize) -> f64 {
        tokens / (seconds * devices as f64).max(1e-12)
    }
}

/// Append-only CSV logger for training curves (reward/loss/kl per step —
/// regenerates paper Fig. 5).
pub struct CsvLog {
    path: std::path::PathBuf,
    header: Vec<String>,
    rows: Vec<Vec<f64>>,
}

impl CsvLog {
    pub fn new(path: &std::path::Path, header: &[&str]) -> CsvLog {
        CsvLog {
            path: path.to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.header.len(), "csv row width");
        self.rows.push(row.to_vec());
    }

    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        std::fs::write(&self.path, s)
    }

    pub fn rows(&self) -> &[Vec<f64>] {
        &self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpspd_compute() {
        assert_eq!(Tpspd::compute(1000.0, 10.0, 10), 10.0);
        let mut t = Tpspd::start(2);
        t.add_tokens(100);
        assert_eq!(t.trained_tokens, 100);
        assert!(t.value() > 0.0);
    }

    #[test]
    fn csv_log_roundtrip() {
        let dir = std::env::temp_dir().join("pa_rl_csv_test");
        let path = dir.join("curve.csv");
        let mut log = CsvLog::new(&path, &["step", "reward"]);
        log.add(&[0.0, 0.1]);
        log.add(&[1.0, 0.4]);
        log.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,reward\n"));
        assert_eq!(text.lines().count(), 3);
    }
}
