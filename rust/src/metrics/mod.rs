//! Measurement plane: wall-clock timeline traces (paper Fig. 3), TPSPD
//! throughput accounting (the paper's headline metric), CSV curve logs
//! (paper Fig. 5), and the unified telemetry subsystem — a named-metric
//! [`Registry`] with counter/gauge/histogram handles, per-request lifecycle
//! [`timeline`]s aggregated into deterministic log-bucketed [`histogram`]s,
//! and JSON / Prometheus exporters (see `docs/OBSERVABILITY.md`).
//!
//! Telemetry depth is governed by [`MetricsLevel`] (`metrics.level` in the
//! config): `Basic` keeps every output surface bit-identical to the
//! pre-telemetry tree; `Full` additionally stamps request timelines and
//! emits per-iteration snapshots.

pub mod histogram;
pub mod registry;
pub mod timeline;
pub mod trace;

pub use histogram::Histogram;
pub use registry::{Counter, Gauge, HistogramHandle, Registry, RegistrySnapshot};
pub use timeline::{Clock, RequestMetrics, RequestTimeline};
pub use trace::{validate_chrome_trace, Span, Trace, NO_PARENT};

use std::io::Write;
use std::time::Instant;

/// How much telemetry a run records.
///
/// * `Basic` (default) — the seed surfaces only: trace spans, per-iteration
///   counters, train CSV. Output stays bit-identical to a build without the
///   telemetry subsystem; request timelines are never stamped.
/// * `Full` — additionally stamps per-request lifecycle timelines
///   (enqueue → dispatch → admit → first-token → finish → train-consume),
///   aggregates TTFT / queue-wait / decode-throughput / staleness
///   histograms, and writes per-iteration registry snapshots under
///   `artifacts/runs/`. Overhead is a few relaxed atomic ops per request,
///   asserted < 3% of request cost in `perf_micro`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsLevel {
    #[default]
    Basic,
    Full,
}

impl MetricsLevel {
    /// Parse a config string (`"basic"` / `"full"`).
    pub fn parse(s: &str) -> Option<MetricsLevel> {
        match s {
            "basic" => Some(MetricsLevel::Basic),
            "full" => Some(MetricsLevel::Full),
            _ => None,
        }
    }

    pub fn is_full(self) -> bool {
        self == MetricsLevel::Full
    }

    pub fn as_str(self) -> &'static str {
        match self {
            MetricsLevel::Basic => "basic",
            MetricsLevel::Full => "full",
        }
    }
}

/// Tokens-trained-per-second-per-device — the paper's primary metric
/// ("end-to-end training throughput, measured by tokens trained per second
/// per device"). On this testbed a "device" is one engine or trainer
/// instance (each owns a PJRT client); the simulator uses real device counts.
#[derive(Debug, Clone)]
pub struct Tpspd {
    started: Instant,
    pub trained_tokens: u64,
    pub devices: usize,
}

impl Tpspd {
    pub fn start(devices: usize) -> Tpspd {
        Tpspd { started: Instant::now(), trained_tokens: 0, devices }
    }

    pub fn add_tokens(&mut self, n: usize) {
        self.trained_tokens += n as u64;
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn value(&self) -> f64 {
        self.trained_tokens as f64 / (self.elapsed() * self.devices as f64).max(1e-9)
    }

    /// TPSPD from explicit components (simulator / offline computation).
    pub fn compute(tokens: f64, seconds: f64, devices: usize) -> f64 {
        tokens / (seconds * devices as f64).max(1e-12)
    }
}

/// Crash-safe CSV logger for training curves (reward/loss/kl per step —
/// regenerates paper Fig. 5).
///
/// Rows are persisted incrementally: the first [`CsvLog::add`] creates the
/// file and writes the header, every subsequent `add` appends its row
/// through a buffered writer and flushes it, so a killed run keeps its
/// partial training curve. [`CsvLog::flush`] retries anything a best-effort
/// `add` could not persist (and surfaces the I/O error); the final file is
/// byte-identical to the old whole-file-at-end writer.
pub struct CsvLog {
    path: std::path::PathBuf,
    header: Vec<String>,
    rows: Vec<Vec<f64>>,
    /// Rows already persisted to disk.
    written: usize,
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl CsvLog {
    pub fn new(path: &std::path::Path, header: &[&str]) -> CsvLog {
        CsvLog {
            path: path.to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            written: 0,
            out: None,
        }
    }

    /// Append a row and persist it (best-effort: an I/O failure here is
    /// retried and reported by the next [`CsvLog::flush`]).
    pub fn add(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.header.len(), "csv row width");
        self.rows.push(row.to_vec());
        let _ = self.persist();
    }

    /// Ensure header and all rows are on disk.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.persist()
    }

    fn persist(&mut self) -> std::io::Result<()> {
        if self.out.is_none() {
            if let Some(parent) = self.path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let file = std::fs::File::create(&self.path)?;
            let mut w = std::io::BufWriter::new(file);
            let mut head = self.header.join(",");
            head.push('\n');
            w.write_all(head.as_bytes())?;
            self.out = Some(w);
        }
        let mut pending = String::new();
        for row in &self.rows[self.written..] {
            let cells: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
            pending.push_str(&cells.join(","));
            pending.push('\n');
        }
        let w = self.out.as_mut().expect("csv writer opened above");
        w.write_all(pending.as_bytes())?;
        w.flush()?;
        self.written = self.rows.len();
        Ok(())
    }

    pub fn rows(&self) -> &[Vec<f64>] {
        &self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpspd_compute() {
        assert_eq!(Tpspd::compute(1000.0, 10.0, 10), 10.0);
        let mut t = Tpspd::start(2);
        t.add_tokens(100);
        assert_eq!(t.trained_tokens, 100);
        assert!(t.value() > 0.0);
    }

    #[test]
    fn csv_log_roundtrip() {
        let dir = std::env::temp_dir().join("pa_rl_csv_test");
        let path = dir.join("curve.csv");
        let mut log = CsvLog::new(&path, &["step", "reward"]);
        log.add(&[0.0, 0.1]);
        log.add(&[1.0, 0.4]);
        log.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,reward\n"));
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn csv_log_persists_each_add_without_flush() {
        // crash-safety: rows must reach disk even if flush() is never
        // called and the log is dropped mid-run.
        let dir = std::env::temp_dir().join("pa_rl_csv_crash_test");
        let path = dir.join("curve.csv");
        let _ = std::fs::remove_file(&path);
        {
            let mut log = CsvLog::new(&path, &["step", "reward"]);
            log.add(&[0.0, 0.5]);
            log.add(&[1.0, 0.25]);
            // read back while the writer is still alive: both rows flushed
            let text = std::fs::read_to_string(&path).unwrap();
            assert_eq!(text, "step,reward\n0,0.5\n1,0.25\n");
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,reward\n0,0.5\n1,0.25\n");
    }

    #[test]
    fn csv_flush_with_no_rows_writes_header() {
        let dir = std::env::temp_dir().join("pa_rl_csv_header_test");
        let path = dir.join("curve.csv");
        let mut log = CsvLog::new(&path, &["a", "b"]);
        log.flush().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n");
    }

    #[test]
    fn metrics_level_parses() {
        assert_eq!(MetricsLevel::parse("basic"), Some(MetricsLevel::Basic));
        assert_eq!(MetricsLevel::parse("full"), Some(MetricsLevel::Full));
        assert_eq!(MetricsLevel::parse("verbose"), None);
        assert_eq!(MetricsLevel::default(), MetricsLevel::Basic);
        assert!(!MetricsLevel::Basic.is_full());
        assert_eq!(MetricsLevel::Full.as_str(), "full");
    }
}
