//! Synthetic arithmetic task generator (GSM8K-mini).
//!
//! The paper's training data (DeepScaleR, GSM8K) and its verifier are
//! substituted by a deterministic arithmetic-word-problem generator with a
//! rule-based exact-match reward — the same reward *mechanism* the paper uses
//! ("the predicted answer is considered correct if it can be accurately
//! extracted and matches the ground-truth answer").
//!
//! The generator controls the prompt/response length ratio through few-shot
//! prefixes, which lets experiments sit in either of the paper's regimes:
//! long-response/short-prompt (DeepScaleR-like, SPA off) or
//! long-prompt/short-response (GSM8K 1K-context-like, where SPA shines).

use super::tokenizer::{Tokenizer, BOS};
use crate::config::DataConfig;
use crate::util::rng::Pcg64;

/// One training prompt with its ground truth.
#[derive(Debug, Clone)]
pub struct Prompt {
    /// Monotonic id assigned by the loader.
    pub id: u64,
    /// Token ids, starting with BOS. Length <= prompt_max enforced by caller.
    pub tokens: Vec<u32>,
    /// Human-readable form.
    pub text: String,
    /// Ground-truth integer answer.
    pub answer: i64,
}

/// Deterministic generator of arithmetic problems.
#[derive(Debug, Clone)]
pub struct TaskGen {
    cfg: DataConfig,
    tokenizer: Tokenizer,
}

impl TaskGen {
    pub fn new(cfg: DataConfig) -> TaskGen {
        TaskGen { cfg, tokenizer: Tokenizer::new() }
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// One problem: operands, operator, answer. Subtraction is ordered so
    /// answers are non-negative (keeps responses short and parseable).
    fn problem(&self, rng: &mut Pcg64) -> (u64, char, u64, i64) {
        let a = rng.range_u64(0, self.cfg.max_operand + 1);
        let b = rng.range_u64(0, self.cfg.max_operand + 1);
        match rng.range(0, 3) {
            0 => (a, '+', b, (a + b) as i64),
            1 => {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                (hi, '-', lo, (hi - lo) as i64)
            }
            _ => {
                // keep products small: scale operands down
                let a = a % 13;
                let b = b % 13;
                (a, '*', b, (a * b) as i64)
            }
        }
    }

    fn render(a: u64, op: char, b: u64) -> String {
        format!("Q:{a}{op}{b}=?A:")
    }

    /// Few-shot prefix: complete worked examples, '#'-separated. With
    /// `shared_few_shot` the examples come from one fixed stream independent
    /// of the prompt index, so every prompt shares a byte-identical template
    /// — the template-sharing workload where chunked prefill and cross-engine
    /// KV sharing collapse the per-prompt prefill to the question suffix.
    fn push_few_shot(&self, text: &mut String, rng: &mut Pcg64) {
        let mut shared;
        let rng = if self.cfg.shared_few_shot {
            shared = Pcg64::new(self.cfg.seed ^ 0x7E41, 1);
            &mut shared
        } else {
            rng
        };
        for _ in 0..self.cfg.few_shot {
            let (a, op, b, ans) = self.problem(rng);
            text.push_str(&Self::render(a, op, b));
            text.push_str(&ans.to_string());
            text.push('#');
        }
    }

    /// Generate the `idx`-th prompt deterministically (same seed + idx ⇒ same
    /// prompt, independent of iteration order).
    pub fn prompt(&self, idx: u64) -> Prompt {
        let mut rng = Pcg64::new(self.cfg.seed ^ 0xDA7A, idx + 1);
        let mut text = String::new();
        self.push_few_shot(&mut text, &mut rng);
        let (a, op, b, answer) = self.problem(&mut rng);
        text.push_str(&Self::render(a, op, b));
        let mut tokens = vec![BOS];
        tokens.extend(self.tokenizer.encode(&text).expect("generator emits only vocab chars"));
        Prompt { id: idx, tokens, text, answer }
    }

    /// A held-out evaluation prompt (disjoint stream from training prompts).
    pub fn eval_prompt(&self, idx: u64) -> Prompt {
        let mut rng = Pcg64::new(self.cfg.seed ^ 0xE7A1, (idx + 1) << 20);
        let mut text = String::new();
        self.push_few_shot(&mut text, &mut rng);
        let (a, op, b, answer) = self.problem(&mut rng);
        text.push_str(&Self::render(a, op, b));
        let mut tokens = vec![BOS];
        tokens.extend(self.tokenizer.encode(&text).expect("generator emits only vocab chars"));
        Prompt { id: idx, tokens, text, answer }
    }

    /// The ideal (SFT target) response text for a prompt.
    pub fn target_response(answer: i64) -> String {
        answer.to_string()
    }

    /// Upper bound on prompt token length for a given config (BOS + few-shot
    /// worked examples + the question). Used to validate `prompt_max`.
    pub fn max_prompt_len(cfg: &DataConfig) -> usize {
        let digits = (cfg.max_operand.max(1) as f64).log10().floor() as usize + 1;
        // "Q:" a op b "=?A:" -> 2 + d + 1 + d + 4
        let q = 2 + digits + 1 + digits + 4;
        // answers: up to 2*max (sum) -> digits+1; products of %13 operands fit too
        let ans = digits + 1;
        1 + cfg.few_shot * (q + ans + 1) + q
    }
}

/// Streaming data loader over the infinite synthetic task distribution —
/// stands in for the paper's "data source that loads and provides training
/// prompts in batches".
#[derive(Debug)]
pub struct DataLoader {
    gen: TaskGen,
    next_idx: u64,
}

impl DataLoader {
    pub fn new(cfg: DataConfig) -> DataLoader {
        DataLoader { gen: TaskGen::new(cfg), next_idx: 0 }
    }

    pub fn taskgen(&self) -> &TaskGen {
        &self.gen
    }

    /// Next batch of N prompts (Algorithm 1 line 4).
    pub fn next_batch(&mut self, n: usize) -> Vec<Prompt> {
        let batch = (0..n).map(|i| self.gen.prompt(self.next_idx + i as u64)).collect();
        self.next_idx += n as u64;
        batch
    }

    /// Held-out evaluation set.
    pub fn eval_set(&self, n: usize) -> Vec<Prompt> {
        (0..n as u64).map(|i| self.gen.eval_prompt(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig { few_shot: 2, shared_few_shot: false, max_operand: 99, seed: 11 }
    }

    #[test]
    fn deterministic_by_index() {
        let g = TaskGen::new(cfg());
        let a = g.prompt(5);
        let b = g.prompt(5);
        assert_eq!(a.text, b.text);
        assert_eq!(a.answer, b.answer);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn prompts_differ_across_indices() {
        let g = TaskGen::new(cfg());
        let texts: std::collections::HashSet<String> =
            (0..50).map(|i| g.prompt(i).text).collect();
        assert!(texts.len() > 40, "prompts should be diverse, got {}", texts.len());
    }

    #[test]
    fn answers_are_correct() {
        let g = TaskGen::new(cfg());
        for i in 0..200 {
            let p = g.prompt(i);
            // last question is after the final '#'
            let q = p.text.rsplit('#').next().unwrap();
            let body = q.strip_prefix("Q:").unwrap().strip_suffix("=?A:").unwrap();
            let (a, op, b) = if let Some((a, b)) = body.split_once('+') {
                (a.parse::<i64>().unwrap(), '+', b.parse::<i64>().unwrap())
            } else if let Some((a, b)) = body.split_once('-') {
                (a.parse::<i64>().unwrap(), '-', b.parse::<i64>().unwrap())
            } else {
                let (a, b) = body.split_once('*').unwrap();
                (a.parse::<i64>().unwrap(), '*', b.parse::<i64>().unwrap())
            };
            let expect = match op {
                '+' => a + b,
                '-' => a - b,
                _ => a * b,
            };
            assert_eq!(p.answer, expect, "prompt {}", p.text);
            assert!(p.answer >= 0);
        }
    }

    #[test]
    fn lengths_bounded() {
        let c = cfg();
        let g = TaskGen::new(c.clone());
        let bound = TaskGen::max_prompt_len(&c);
        for i in 0..200 {
            let p = g.prompt(i);
            assert!(p.tokens.len() <= bound, "len {} > bound {}", p.tokens.len(), bound);
            assert_eq!(p.tokens[0], BOS);
        }
    }

    #[test]
    fn loader_advances_and_eval_disjoint() {
        let mut dl = DataLoader::new(cfg());
        let b1 = dl.next_batch(4);
        let b2 = dl.next_batch(4);
        assert_eq!(b1[0].id, 0);
        assert_eq!(b2[0].id, 4);
        assert_ne!(b1[0].text, b2[0].text);
        let ev = dl.eval_set(8);
        let train_texts: std::collections::HashSet<&str> =
            b1.iter().chain(b2.iter()).map(|p| p.text.as_str()).collect();
        // eval prompts come from a different stream; overwhelmingly disjoint
        let overlap = ev.iter().filter(|p| train_texts.contains(p.text.as_str())).count();
        assert!(overlap <= 1);
    }

    #[test]
    fn shared_few_shot_yields_one_template() {
        let mut c = cfg();
        c.shared_few_shot = true;
        let g = TaskGen::new(c);
        let prompts: Vec<Prompt> = (0..20).map(|i| g.prompt(i)).collect();
        // Every prompt carries the same worked-example template...
        let tpl_of = |p: &Prompt| p.text.rsplit_once('#').unwrap().0.to_string();
        let templates: std::collections::HashSet<String> = prompts.iter().map(tpl_of).collect();
        assert_eq!(templates.len(), 1, "shared template must be identical across prompts");
        // ...while the questions (and answers) still vary per index.
        let questions: std::collections::HashSet<String> =
            prompts.iter().map(|p| p.text.rsplit('#').next().unwrap().to_string()).collect();
        assert!(questions.len() > 15, "questions should stay diverse: {}", questions.len());
        // Token-level: the common prefix spans the whole template.
        let tpl_tokens = {
            let a = &prompts[0].tokens;
            let b = &prompts[1].tokens;
            a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
        };
        assert!(
            tpl_tokens > tpl_of(&prompts[0]).len(),
            "shared token prefix {tpl_tokens} shorter than the template text"
        );
    }

    #[test]
    fn few_shot_zero_is_single_question() {
        let g = TaskGen::new(DataConfig { few_shot: 0, shared_few_shot: false, max_operand: 9, seed: 0 });
        let p = g.prompt(0);
        assert!(!p.text.contains('#'));
        assert!(p.text.starts_with("Q:"));
        assert!(p.text.ends_with("A:"));
    }
}
