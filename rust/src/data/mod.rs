//! Data plane: tokenizer, synthetic task generation, streaming loader.
//!
//! Substitutes the paper's GSM8K / DeepScaleR pipelines (see DESIGN.md §6):
//! a deterministic arithmetic-problem generator with controllable
//! prompt/response length ratio and a rule-based exact-match verifier.

pub mod taskgen;
pub mod tokenizer;

pub use taskgen::{DataLoader, Prompt, TaskGen};
pub use tokenizer::{Tokenizer, BOS, EOS, PAD};
