//! Character-level tokenizer for the synthetic arithmetic corpus.
//!
//! The paper trains on GSM8K / DeepScaleR with Qwen tokenizers; in this
//! reproduction the data substrate is a synthetic arithmetic task (see
//! [`super::taskgen`]) so a small fixed character vocabulary suffices. The
//! vocabulary is stable across runs — token ids are baked into the AOT
//! artifacts' embedding shapes via `vocab_size` in the config.

/// Reserved special tokens.
pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;

/// Characters mapped to ids `3..3+len`.
const CHARS: &str = "0123456789+-*/=?QA:.# ";

/// Character-level tokenizer with PAD/BOS/EOS specials.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// byte -> token id (dense table over u8 space).
    encode_table: [Option<u32>; 256],
    /// token id -> char.
    decode_table: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut encode_table = [None; 256];
        let mut decode_table = vec!['\0', '\0', '\0']; // PAD, BOS, EOS placeholders
        for (i, c) in CHARS.chars().enumerate() {
            let id = 3 + i as u32;
            encode_table[c as usize] = Some(id);
            decode_table.push(c);
        }
        Tokenizer { encode_table, decode_table }
    }

    /// Number of distinct token ids (specials + chars).
    pub fn vocab_used(&self) -> usize {
        self.decode_table.len()
    }

    /// Encode text. Unknown characters are an error in this closed domain.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>, String> {
        text.chars()
            .map(|c| {
                if (c as usize) < 256 {
                    self.encode_table[c as usize].ok_or_else(|| format!("unknown char {c:?}"))
                } else {
                    Err(format!("unknown char {c:?}"))
                }
            })
            .collect()
    }

    /// Decode ids, stopping at EOS, skipping PAD/BOS.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id == PAD || id == BOS {
                continue;
            }
            if let Some(&c) = self.decode_table.get(id as usize) {
                s.push(c);
            }
        }
        s
    }

    /// Decode including everything after EOS (debugging).
    pub fn decode_raw(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&id| match id {
                PAD => '_',
                BOS => '^',
                EOS => '$',
                id => *self.decode_table.get(id as usize).unwrap_or(&'?'),
            })
            .collect()
    }

    pub fn id_of(&self, c: char) -> Option<u32> {
        if (c as usize) < 256 {
            self.encode_table[c as usize]
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let text = "Q:17+25=?A:42";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn specials_are_reserved() {
        let t = Tokenizer::new();
        let ids = t.encode("0").unwrap();
        assert!(ids[0] >= 3);
        assert_eq!(t.decode(&[BOS, ids[0], EOS, ids[0]]), "0");
    }

    #[test]
    fn unknown_char_rejected() {
        let t = Tokenizer::new();
        assert!(t.encode("hello").is_err()); // lowercase not in vocab
        assert!(t.encode("Q:1+1=?A:").is_ok());
    }

    #[test]
    fn vocab_fits_default_config() {
        let t = Tokenizer::new();
        assert!(t.vocab_used() <= 64, "vocab {} exceeds default 64", t.vocab_used());
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = Tokenizer::new();
        let a = t.id_of('1').unwrap();
        let b = t.id_of('2').unwrap();
        assert_eq!(t.decode(&[a, EOS, b]), "1");
        assert_eq!(t.decode_raw(&[a, EOS, b]), "1$2");
    }

    #[test]
    fn all_chars_unique_ids() {
        let t = Tokenizer::new();
        let mut seen = std::collections::HashSet::new();
        for c in CHARS.chars() {
            assert!(seen.insert(t.id_of(c).unwrap()), "duplicate id for {c:?}");
        }
    }
}
