//! Discrete-event cluster simulator.
//!
//! The paper's evaluation runs on 16–64 Ascend-910B NPUs and 8×A100 GPUs
//! with 1.5B–32B models — hardware this reproduction does not have. The
//! simulator substitutes it (DESIGN.md §6): device/cluster/model/workload
//! specs carry the paper's published constants, five framework executors
//! model the compared systems' scheduling structure (wave-batched colocated,
//! continuous-batched colocated, decoupled sync, periodic async, fully
//! async), and `experiments` wires up each paper table. The real mini-cluster
//! (coordinator module) validates the same scheduling logic end-to-end at
//! small scale; the simulator extends the comparison to paper scale.
//!
//! [`fleet`] is the third rung between those two: the *real* coordinator
//! protocol loops (`coordinator::ctrl`) driven over the deterministic
//! executor (`coordinator::exec`) with mock engines and virtual time, so
//! 1000-engine join/drain/straggler schedules run — and replay — in
//! milliseconds.

pub mod experiments;
pub mod fleet;
pub mod frameworks;
pub mod queue;
pub mod specs;

pub use fleet::{replay as replay_fleet, FleetOp, FleetScript, SimFleetCfg, SimFleetReport};
pub use frameworks::{Framework, SimResult, SimSetup};
pub use specs::{ClusterSpec, DeviceSpec, EfficiencySpec, ModelSpec, WorkloadSpec};
