//! Multi-server FIFO queue primitives for the discrete-event simulator.

/// Simulate `jobs` (service times, FIFO order) on `n_servers` identical
/// servers, all available from `t0`. Returns per-job completion times.
/// This models a continuous-batching inference cluster where each KV slot is
/// a server and per-token step time is occupancy-independent
/// (bandwidth-bound decode), and equally a single trainer consuming groups.
pub fn multi_server_fifo(t0: f64, service: &[f64], n_servers: usize) -> Vec<f64> {
    assert!(n_servers > 0);
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<F64Ord>> =
        (0..n_servers).map(|_| std::cmp::Reverse(F64Ord(t0))).collect();
    let mut out = Vec::with_capacity(service.len());
    for &s in service {
        let std::cmp::Reverse(F64Ord(free)) = heap.pop().unwrap();
        let done = free.max(t0) + s;
        out.push(done);
        heap.push(std::cmp::Reverse(F64Ord(done)));
    }
    out
}

/// Static (wave) batching: jobs are grouped into waves of `n_servers`; each
/// wave completes when its slowest job does (no slot refill mid-wave) — the
/// paper's "without continuous batching, synchronous training is gated by the
/// slowest rollout in each inference batch".
pub fn wave_batching(t0: f64, service: &[f64], n_servers: usize) -> Vec<f64> {
    assert!(n_servers > 0);
    let mut out = vec![0.0; service.len()];
    let mut t = t0;
    for (w, wave) in service.chunks(n_servers).enumerate() {
        let wave_time = wave.iter().cloned().fold(0.0f64, f64::max);
        t += wave_time;
        for i in 0..wave.len() {
            out[w * n_servers + i] = t;
        }
    }
    out
}

/// Serve jobs sequentially on one server, each available no earlier than its
/// ready time; service begins at max(server_free, ready). Returns (per-job
/// completion times, total idle time waiting for work). This is the
/// asynchronous trainer consuming groups in completion order.
pub fn sequential_with_ready(t0: f64, ready: &[f64], service: &[f64]) -> (Vec<f64>, f64) {
    assert_eq!(ready.len(), service.len());
    let mut free = t0;
    let mut idle = 0.0;
    let mut out = Vec::with_capacity(ready.len());
    for (&r, &s) in ready.iter().zip(service) {
        let start = free.max(r);
        idle += start - free;
        free = start + s;
        out.push(free);
    }
    (out, idle)
}

/// Total-order float wrapper (service/completion times are finite).
#[derive(PartialEq, PartialOrd)]
struct F64Ord(f64);

impl Eq for F64Ord {}

#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_single_server_is_cumulative() {
        let done = multi_server_fifo(10.0, &[1.0, 2.0, 3.0], 1);
        assert_eq!(done, vec![11.0, 13.0, 16.0]);
    }

    #[test]
    fn fifo_many_servers_parallel() {
        let done = multi_server_fifo(0.0, &[5.0, 1.0, 1.0], 3);
        assert_eq!(done, vec![5.0, 1.0, 1.0]);
    }

    #[test]
    fn fifo_refills_freed_servers() {
        // 2 servers: jobs 4,1,1,1 -> server2 takes three short jobs
        let done = multi_server_fifo(0.0, &[4.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(done, vec![4.0, 1.0, 2.0, 3.0]);
        // continuous batching beats wave batching on makespan
        let waves = wave_batching(0.0, &[4.0, 1.0, 1.0, 1.0], 2);
        assert!(waves.iter().cloned().fold(0.0f64, f64::max) > 4.0);
    }

    #[test]
    fn wave_batching_gated_by_slowest() {
        let done = wave_batching(0.0, &[1.0, 9.0, 2.0, 2.0], 2);
        assert_eq!(done, vec![9.0, 9.0, 11.0, 11.0]);
    }

    #[test]
    fn sequential_ready_accounts_idle() {
        let (done, idle) = sequential_with_ready(0.0, &[0.0, 10.0], &[2.0, 1.0]);
        assert_eq!(done, vec![2.0, 11.0]);
        assert!((idle - 8.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_decreases_with_servers() {
        let jobs: Vec<f64> = (0..100).map(|i| 1.0 + (i % 7) as f64).collect();
        let m1 = multi_server_fifo(0.0, &jobs, 1).last().cloned().unwrap();
        let m4 = multi_server_fifo(0.0, &jobs, 4)
            .into_iter()
            .fold(0.0f64, f64::max);
        let m16 = multi_server_fifo(0.0, &jobs, 16)
            .into_iter()
            .fold(0.0f64, f64::max);
        assert!(m4 < m1 && m16 < m4);
    }
}
