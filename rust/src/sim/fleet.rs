//! Simulated N-engine fleets on the deterministic coordinator executor.
//!
//! This is the discrete-event half of the coordinator refactor: the *same*
//! protocol loops the real driver runs over worker threads —
//! [`ctrl::recv_step`] for the liveness-checked queue receive,
//! [`ctrl::pump_drain_ack`] for the drain handshake, [`FleetCtrl`] for
//! routing and job accounting — driven here over [`exec::Executor`] tasks,
//! virtual time and mock engines. One engine is one executor task; the
//! rollout queue is a bounded deterministic channel (so backpressure and the
//! drain pump are exercised for real); generation latency is a seeded
//! virtual-time sleep, placement-independent like the per-request RNG
//! streams.
//!
//! A run is fully described by a **schedule string**, e.g.
//!
//! ```text
//! simfleet/v1 e=4 i=3 b=8 g=2 tpl=4 seed=42 aff=1 ttl=0 sv=1 cap=64 ops=j@1,d@2,s@0:1x8
//! ```
//!
//! (`e` engines, `i` iterations, `b` prompts per batch, `g` rollouts per
//! group, `tpl` shared prompt templates, `aff` affinity routing, `ttl`
//! warmth TTL, `sv` sync-every-K-iterations, `cap` queue bound; ops are
//! `j@I` join, `d@I` tail drain, `s@I:ExF` straggle engine E by F,
//! `k@I:E` crash instead of acking the next drain, `x@I:E` crash now.)
//! Same schedule ⇒ same event trace, verbatim — failing property schedules
//! replay with [`replay`]. Grammar and design: `docs/CONCURRENCY.md`,
//! "Deterministic coordinator".

use std::cell::Cell;
use std::collections::{HashSet, VecDeque};
use std::fmt;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::check::sync::mpsc;
use crate::coordinator::assembler::Assembler;
use crate::coordinator::ctrl::{
    self, AckPoll, FleetCtrl, QueuePoll, RecvStep, RolloutSource, StallWatchdog,
};
use crate::coordinator::exec::{self, Executor, TryRecv};
use crate::coordinator::messages::{DrainAck, GenJob, ScoredRollout, WeightSyncAck, WorkerStats};
use crate::coordinator::route;
use crate::data::taskgen::Prompt;
use crate::engine::{EngineStats, GenRequest};
use crate::util::rng::{splitmix64, Pcg64};

/// Affinity-key granularity for simulated prompts (tokens per cache block).
const CACHE_BLOCK: usize = 16;
/// Unique suffix tokens appended to each prompt after its template prefix.
const TAIL_TOKENS: usize = 8;
/// Response length every mock engine produces.
const RESPONSE_TOKENS: usize = 4;
/// Virtual seconds of queue silence after which the harness declares the
/// run wedged ("drains always terminate" violations surface as this error,
/// not as a hung test). Far beyond any legitimate batch: a job takes at
/// most ~2 s × the largest straggle factor.
const SILENCE_CAP_S: f64 = 3600.0;

// ---------------------------------------------------------------------------
// Schedules
// ---------------------------------------------------------------------------

/// Fleet shape and workload knobs — the `key=value` half of a schedule
/// string.
#[derive(Debug, Clone)]
pub struct SimFleetCfg {
    /// Engines at start (`e=`).
    pub engines: usize,
    /// Training iterations (`i=`).
    pub iters: u32,
    /// Prompts per batch (`b=`).
    pub batch_prompts: usize,
    /// Rollouts per group (`g=`).
    pub group_size: usize,
    /// Shared prompt templates — the affinity/warmth workload (`tpl=`).
    pub templates: usize,
    /// Seed for prompt content and generation latency (`seed=`).
    pub seed: u64,
    /// Residency-aware affinity routing (`aff=`, gated off below 2 engines).
    pub affinity: bool,
    /// Warmth-belief TTL in router epochs, 0 = never expire (`ttl=`).
    pub warmth_ttl: u64,
    /// Sync weights every K iterations; 0 = only the initial sync (`sv=`).
    pub sync_every: u32,
    /// Rollout queue bound (`cap=`) — small values exercise backpressure.
    pub queue_cap: usize,
}

impl Default for SimFleetCfg {
    fn default() -> Self {
        SimFleetCfg {
            engines: 4,
            iters: 3,
            batch_prompts: 8,
            group_size: 2,
            templates: 4,
            seed: 0,
            affinity: true,
            warmth_ttl: 0,
            sync_every: 1,
            queue_cap: 64,
        }
    }
}

/// One scheduled fleet event. Events land mid-iteration, after dispatch:
/// drains hand back queued jobs and joiners can receive re-routes — the
/// corner cases the real `rl.fleet_schedule` exists to exercise.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetOp {
    /// `j@I`: spawn and weight-sync one engine during iteration `I`.
    Join { iter: u32 },
    /// `d@I`: drain the tail engine during iteration `I`, re-routing its
    /// returned jobs over the survivors.
    Drain { iter: u32 },
    /// `s@I:ExF`: engine `E` multiplies its generation latency by `F` from
    /// iteration `I` on.
    Straggle { iter: u32, engine: usize, factor: f64 },
    /// `k@I:E`: engine `E` crashes instead of acking its next drain (the
    /// `pump_drain_ack` liveness regression).
    KillOnDrain { iter: u32, engine: usize },
    /// `x@I:E`: engine `E` crashes immediately, losing its queued jobs.
    Die { iter: u32, engine: usize },
}

impl FleetOp {
    /// The iteration this event fires in.
    pub fn fires_at(&self) -> u32 {
        match *self {
            FleetOp::Join { iter }
            | FleetOp::Drain { iter }
            | FleetOp::Straggle { iter, .. }
            | FleetOp::KillOnDrain { iter, .. }
            | FleetOp::Die { iter, .. } => iter,
        }
    }
}

/// A complete, replayable run description: config plus ordered fleet events.
/// `Display` renders the canonical schedule string; [`FleetScript::parse`]
/// round-trips it.
#[derive(Debug, Clone)]
pub struct FleetScript {
    pub cfg: SimFleetCfg,
    pub ops: Vec<FleetOp>,
}

impl fmt::Display for FleetScript {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let c = &self.cfg;
        write!(
            f,
            "simfleet/v1 e={} i={} b={} g={} tpl={} seed={} aff={} ttl={} sv={} cap={} ops=",
            c.engines,
            c.iters,
            c.batch_prompts,
            c.group_size,
            c.templates,
            c.seed,
            u8::from(c.affinity),
            c.warmth_ttl,
            c.sync_every,
            c.queue_cap
        )?;
        if self.ops.is_empty() {
            return write!(f, "-");
        }
        for (i, op) in self.ops.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            match *op {
                FleetOp::Join { iter } => write!(f, "j@{iter}")?,
                FleetOp::Drain { iter } => write!(f, "d@{iter}")?,
                FleetOp::Straggle { iter, engine, factor } => {
                    write!(f, "s@{iter}:{engine}x{factor}")?
                }
                FleetOp::KillOnDrain { iter, engine } => write!(f, "k@{iter}:{engine}")?,
                FleetOp::Die { iter, engine } => write!(f, "x@{iter}:{engine}")?,
            }
        }
        Ok(())
    }
}

fn parse_op(o: &str) -> Result<FleetOp> {
    let bad = || anyhow!("bad schedule op {o:?}");
    let mut halves = o.splitn(2, '@');
    let kind = halves.next().unwrap_or("");
    let body = halves.next().ok_or_else(bad)?;
    match kind {
        "j" => Ok(FleetOp::Join { iter: body.parse().map_err(|_| bad())? }),
        "d" => Ok(FleetOp::Drain { iter: body.parse().map_err(|_| bad())? }),
        "s" => {
            let (iter, rest) = body.split_once(':').ok_or_else(bad)?;
            let (engine, factor) = rest.split_once('x').ok_or_else(bad)?;
            Ok(FleetOp::Straggle {
                iter: iter.parse().map_err(|_| bad())?,
                engine: engine.parse().map_err(|_| bad())?,
                factor: factor.parse().map_err(|_| bad())?,
            })
        }
        "k" | "x" => {
            let (iter, engine) = body.split_once(':').ok_or_else(bad)?;
            let iter = iter.parse().map_err(|_| bad())?;
            let engine = engine.parse().map_err(|_| bad())?;
            Ok(if kind == "k" {
                FleetOp::KillOnDrain { iter, engine }
            } else {
                FleetOp::Die { iter, engine }
            })
        }
        _ => Err(bad()),
    }
}

impl FleetScript {
    /// Parse a schedule string (the inverse of `Display`). Unlisted keys
    /// keep their [`SimFleetCfg::default`] values.
    pub fn parse(s: &str) -> Result<FleetScript> {
        let mut toks = s.split_whitespace();
        let magic = toks.next().unwrap_or("");
        if magic != "simfleet/v1" {
            bail!("schedule must start with `simfleet/v1`, got {magic:?}");
        }
        let mut cfg = SimFleetCfg::default();
        let mut ops = Vec::new();
        for tok in toks {
            let (k, v) =
                tok.split_once('=').ok_or_else(|| anyhow!("bad token {tok:?} (want key=value)"))?;
            match k {
                "e" => cfg.engines = v.parse().context("e=")?,
                "i" => cfg.iters = v.parse().context("i=")?,
                "b" => cfg.batch_prompts = v.parse().context("b=")?,
                "g" => cfg.group_size = v.parse().context("g=")?,
                "tpl" => cfg.templates = v.parse().context("tpl=")?,
                "seed" => cfg.seed = v.parse().context("seed=")?,
                "aff" => cfg.affinity = v.parse::<u8>().context("aff=")? != 0,
                "ttl" => cfg.warmth_ttl = v.parse().context("ttl=")?,
                "sv" => cfg.sync_every = v.parse().context("sv=")?,
                "cap" => cfg.queue_cap = v.parse().context("cap=")?,
                "ops" => {
                    if v != "-" {
                        for o in v.split(',') {
                            ops.push(parse_op(o)?);
                        }
                    }
                }
                _ => bail!("unknown schedule key {k:?} in {tok:?}"),
            }
        }
        Ok(FleetScript { cfg, ops })
    }

    /// A seeded random schedule over `cfg`: per iteration, maybe a join, a
    /// tail drain (never shrinking below 2 engines) and/or a straggler.
    /// Fault injection (`k@`/`x@`) is never generated — those ops model
    /// crashes whose whole point is to *fail* the run, and belong to the
    /// error-path tests.
    pub fn random(cfg: SimFleetCfg, ops_seed: u64) -> FleetScript {
        let mut rng = Pcg64::from_stream(ops_seed, 0x51AF_F1EE);
        let mut n = cfg.engines;
        let mut ops = Vec::new();
        for iter in 0..cfg.iters {
            if rng.chance(0.35) {
                ops.push(FleetOp::Join { iter });
                n += 1;
            }
            if n > 2 && rng.chance(0.35) {
                ops.push(FleetOp::Drain { iter });
                n -= 1;
            }
            if rng.chance(0.4) {
                let engine = rng.range(0, n);
                let factor = [2.0, 4.0, 8.0, 16.0][rng.range(0, 4)];
                ops.push(FleetOp::Straggle { iter, engine, factor });
            }
        }
        FleetScript { cfg, ops }
    }
}

// ---------------------------------------------------------------------------
// Mock engines (one executor task each)
// ---------------------------------------------------------------------------

/// Control messages into a simulated engine — the executor-task analogue of
/// [`crate::coordinator::messages::EngineMsg`], with reply channels over the
/// shim `mpsc` (replies are non-blocking sends; the harness root polls them
/// between executor steps, exactly as the real driver polls its worker
/// handshakes).
enum SimMsg {
    Gen(Vec<GenJob>),
    SetWeights { version: u64, ack: mpsc::Sender<WeightSyncAck> },
    QueryStats(mpsc::Sender<WorkerStats>),
    Drain(mpsc::Sender<DrainAck>),
    Straggle(f64),
    KillOnDrain,
    Die,
}

/// Flips the shared liveness flag when the engine task ends — normally or
/// by crash — which is what [`RolloutSource::workers_dead`] reads.
struct AliveGuard(Rc<Cell<bool>>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.set(false);
    }
}

/// Seeded per-request virtual generation latency in `[1, 2)` seconds:
/// a pure function of `(fleet seed, request id)`, so latency follows the
/// request wherever routing places it (placement-independent, like the
/// per-request sampling streams).
fn gen_latency_s(seed: u64, request_id: u64) -> f64 {
    1.0 + (splitmix64(seed ^ splitmix64(request_id ^ 0x00C0_FFEE)) % 1024) as f64 / 1024.0
}

/// Mutable state of one mock engine.
struct EngineSim {
    idx: usize,
    seed: u64,
    version: u64,
    straggle: f64,
    kill_on_drain: bool,
    stats: EngineStats,
    /// Warm-template advertisement, `(affinity key, resident tokens)` in
    /// first-completion order — cleared on weight upload like a real KV
    /// cache.
    warm: Vec<(u64, usize)>,
    queue: exec::SimSender<ScoredRollout>,
}

impl EngineSim {
    /// Run one job: a seeded virtual-latency sleep, then publish the scored
    /// rollout (parking on the bounded queue while the consumer lags —
    /// that park is what the drain pump exists to unblock). Returns false
    /// when the consumer is gone and the task should exit quietly.
    async fn complete(&mut self, job: GenJob) -> bool {
        let rid = job.request.request_id;
        let dt = gen_latency_s(self.seed, rid) * self.straggle;
        exec::sleep(dt).await;
        let (key, alen) = route::affinity_key(&job.request.prompt, CACHE_BLOCK);
        if !self.warm.iter().any(|&(k, _)| k == key) {
            self.warm.push((key, alen));
        }
        self.stats.prefills += 1;
        self.stats.tokens_generated += RESPONSE_TOKENS as u64;
        self.stats.busy_seconds += dt;
        let rollout = ScoredRollout {
            request_id: rid,
            prompt_id: job.prompt_id,
            sample_idx: job.sample_idx,
            weight_version: self.version,
            tokens: (0..RESPONSE_TOKENS as u32).map(|i| 2 + i).collect(),
            logprobs: vec![-0.5; RESPONSE_TOKENS],
            reward: (splitmix64(self.seed ^ rid ^ 0x5EED) % 2) as f32,
            gen_seconds: dt,
            engine_idx: self.idx,
            timeline: Default::default(),
        };
        self.queue.send(rollout).await.is_ok()
    }
}

/// The engine task body — a mirror of `worker::worker_main`'s two-phase
/// receive: park on the inbox when idle, drain control messages without
/// blocking when busy, then run one job.
async fn engine_task(
    idx: usize,
    seed: u64,
    inbox: exec::SimReceiver<SimMsg>,
    queue: exec::SimSender<ScoredRollout>,
    alive: Rc<Cell<bool>>,
) {
    let _guard = AliveGuard(alive);
    let mut eng = EngineSim {
        idx,
        seed,
        version: 0,
        straggle: 1.0,
        kill_on_drain: false,
        stats: EngineStats::default(),
        warm: Vec::new(),
        queue,
    };
    let mut pending: VecDeque<GenJob> = VecDeque::new();
    loop {
        let mut msgs: Vec<SimMsg> = Vec::new();
        if pending.is_empty() {
            match inbox.recv().await {
                Some(m) => msgs.push(m),
                None => return, // coordinator gone: clean shutdown
            }
        }
        loop {
            match inbox.try_recv() {
                TryRecv::Item(m) => msgs.push(m),
                TryRecv::Empty => break,
                TryRecv::Closed => {
                    if msgs.is_empty() && pending.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        for msg in msgs {
            match msg {
                SimMsg::Gen(jobs) => pending.extend(jobs),
                SimMsg::SetWeights { version, ack } => {
                    let uploaded = version != eng.version;
                    if uploaded {
                        eng.version = version;
                        // A weight upload evicts the KV cache: warm
                        // templates are gone, like the real engine.
                        eng.warm.clear();
                        eng.stats.weight_syncs += 1;
                    } else {
                        eng.stats.weight_syncs_skipped += 1;
                    }
                    let _ = ack.send(WeightSyncAck { uploaded });
                }
                SimMsg::QueryStats(reply) => {
                    let _ = reply.send(WorkerStats {
                        engine_idx: eng.idx,
                        engine: eng.stats.clone(),
                        cache: None,
                        warm: eng.warm.clone(),
                        pending: pending.len(),
                        active: 0,
                    });
                }
                SimMsg::Straggle(factor) => eng.straggle = factor,
                SimMsg::KillOnDrain => eng.kill_on_drain = true,
                SimMsg::Die => return, // crash: queued jobs are lost
                SimMsg::Drain(ack) => {
                    if eng.kill_on_drain {
                        // Crash mid-drain: the ack sender drops unsent and
                        // the pump surfaces it as `AckPoll::Gone`.
                        return;
                    }
                    // The head job models work already admitted when the
                    // drain landed: it runs to completion *through the
                    // queue*, which is what exercises `pump_drain_ack`'s
                    // rollout pump; the rest return in the ack.
                    if let Some(job) = pending.pop_front() {
                        if !eng.complete(job).await {
                            return;
                        }
                    }
                    let _ = ack.send(DrainAck {
                        pending: pending.drain(..).collect(),
                        stats: eng.stats.clone(),
                        cache: None,
                    });
                    return;
                }
            }
        }
        if let Some(job) = pending.pop_front() {
            if !eng.complete(job).await {
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The harness
// ---------------------------------------------------------------------------

struct EngineSlot {
    inbox: exec::SimSender<SimMsg>,
    alive: Rc<Cell<bool>>,
}

/// [`RolloutSource`] over the simulated substrate: poll the deterministic
/// queue, driving the executor and advancing virtual time to cover the
/// window. A run that goes [`SILENCE_CAP_S`] virtual seconds with no
/// rollout is declared wedged — "drains always terminate" violations
/// surface as that error instead of a hung test.
struct SimPlane<'a> {
    ex: &'a mut Executor,
    rx: &'a exec::SimReceiver<ScoredRollout>,
    engines: &'a [EngineSlot],
    silence_s: &'a mut f64,
}

impl RolloutSource for SimPlane<'_> {
    fn poll(&mut self, timeout_s: f64) -> Result<QueuePoll> {
        let deadline = self.ex.clock().now() + timeout_s;
        loop {
            if let TryRecv::Item(r) = self.rx.try_recv() {
                *self.silence_s = 0.0;
                return Ok(QueuePoll::Rollout(r));
            }
            if self.ex.step(deadline) {
                continue;
            }
            // Quiescent through the window: no runnable task, no timer due
            // before the deadline. Cover the remaining virtual time, then
            // probe once more (the quiescing step may have published).
            let clock = self.ex.clock();
            if clock.now() < deadline {
                clock.advance_to(deadline);
            }
            if let TryRecv::Item(r) = self.rx.try_recv() {
                *self.silence_s = 0.0;
                return Ok(QueuePoll::Rollout(r));
            }
            *self.silence_s += timeout_s;
            if *self.silence_s > SILENCE_CAP_S {
                bail!(
                    "simulated fleet made no progress for {:.0} virtual seconds \
                     (a drain or batch failed to terminate)",
                    *self.silence_s
                );
            }
            return Ok(QueuePoll::TimedOut { waited_s: timeout_s });
        }
    }

    fn workers_dead(&mut self) -> bool {
        self.engines.iter().all(|e| !e.alive.get())
    }
}

/// What a completed run reports — enough for tests to assert the paper's
/// invariants and to diff event traces across runs.
#[derive(Debug, Clone)]
pub struct SimFleetReport {
    /// Canonical schedule string; feed to [`replay`] to reproduce.
    pub schedule: String,
    /// Deterministic event trace: same schedule ⇒ same lines, verbatim.
    pub trace: Vec<String>,
    /// Jobs dispatched across the run.
    pub minted: u64,
    /// Rollouts consumed across the run (conservation: equals `minted`).
    pub consumed: u64,
    /// Max observed `synced version − rollout version` (Prop. 1 ⇒ 0).
    pub max_staleness: u64,
    /// Fleet size after the last iteration.
    pub engines: usize,
    /// Router warmth beliefs alive at the end.
    pub warm_beliefs: usize,
    /// Virtual seconds the whole run took.
    pub virtual_s: f64,
    /// Total executor polls — a cheap determinism fingerprint.
    pub polls: u64,
}

struct SimFleet {
    cfg: SimFleetCfg,
    ex: Executor,
    ctrl: FleetCtrl,
    assembler: Assembler,
    engines: Vec<EngineSlot>,
    queue_tx: exec::SimSender<ScoredRollout>,
    queue_rx: exec::SimReceiver<ScoredRollout>,
    watchdog: Option<StallWatchdog>,
    silence_s: f64,
    version: u64,
    minted: u64,
    consumed: u64,
    seen_ids: HashSet<u64>,
    max_staleness: u64,
    iter_groups_done: usize,
    next_prompt_id: u64,
    trace: Vec<String>,
}

impl SimFleet {
    fn new(cfg: &SimFleetCfg) -> Result<SimFleet> {
        if cfg.engines == 0 || cfg.batch_prompts == 0 || cfg.group_size == 0 {
            bail!("schedule needs engines, batch and group size >= 1");
        }
        let (queue_tx, queue_rx) = exec::channel(Some(cfg.queue_cap.max(1)));
        let mut fleet = SimFleet {
            cfg: cfg.clone(),
            ex: Executor::new(),
            ctrl: FleetCtrl::new(
                cfg.engines,
                cfg.affinity && cfg.engines >= 2,
                cfg.warmth_ttl,
                2 * cfg.group_size,
                CACHE_BLOCK,
            ),
            assembler: Assembler::new(),
            engines: Vec::new(),
            queue_tx,
            queue_rx,
            watchdog: None,
            silence_s: 0.0,
            version: 0,
            minted: 0,
            consumed: 0,
            seen_ids: HashSet::new(),
            max_staleness: 0,
            iter_groups_done: 0,
            next_prompt_id: 0,
            trace: Vec::new(),
        };
        for _ in 0..cfg.engines {
            fleet.spawn_engine();
        }
        Ok(fleet)
    }

    fn now(&self) -> f64 {
        self.ex.clock().now()
    }

    fn spawn_engine(&mut self) -> usize {
        let idx = self.engines.len();
        let (inbox_tx, inbox_rx) = exec::channel(None);
        let alive = Rc::new(Cell::new(true));
        self.ex.spawn(engine_task(
            idx,
            self.cfg.seed,
            inbox_rx,
            self.queue_tx.clone(),
            alive.clone(),
        ));
        self.engines.push(EngineSlot { inbox: inbox_tx, alive });
        idx
    }

    /// Non-blocking control send (engine inboxes are unbounded). A closed
    /// inbox means the task already exited — schedules that message a
    /// crashed engine fail here, loudly.
    fn send_to(&self, idx: usize, msg: SimMsg) -> Result<()> {
        match self.engines[idx].inbox.try_send(msg) {
            Ok(()) => Ok(()),
            Err(_) => bail!("engine-{idx} inbox is closed (worker exited)"),
        }
    }

    fn check_engine(&self, engine: usize) -> Result<()> {
        if engine >= self.engines.len() {
            bail!("op targets engine {engine} but the fleet has {}", self.engines.len());
        }
        Ok(())
    }

    /// Drive the executor until `want` replies arrive on `rx` or
    /// `timeout_s` of virtual time elapses — the virtual-time analogue of
    /// the driver's bounded `recv_timeout` handshake loops, using the same
    /// seconds-based constants (the satellite-6 parity fix).
    fn await_replies<T>(&mut self, rx: &mpsc::Receiver<T>, want: usize, timeout_s: f64) -> Vec<T> {
        let mut got = Vec::new();
        let deadline = self.now() + timeout_s;
        loop {
            while let Ok(v) = rx.try_recv() {
                got.push(v);
            }
            if got.len() >= want {
                return got;
            }
            if !self.ex.step(deadline) {
                let clock = self.ex.clock();
                if clock.now() < deadline {
                    clock.advance_to(deadline);
                }
                while let Ok(v) = rx.try_recv() {
                    got.push(v);
                }
                return got;
            }
        }
    }

    fn sync_weights(&mut self, iter: u32) -> Result<()> {
        self.version += 1;
        let v = self.version;
        let n = self.engines.len();
        let (ack_tx, ack_rx) = mpsc::channel();
        for (idx, slot) in self.engines.iter().enumerate() {
            if slot.inbox.try_send(SimMsg::SetWeights { version: v, ack: ack_tx.clone() }).is_err()
            {
                bail!("engine-{idx} inbox is closed (worker exited)");
            }
        }
        drop(ack_tx);
        let acks = self.await_replies(&ack_rx, n, ctrl::STATS_REPLY_TIMEOUT_S);
        if acks.len() != n {
            bail!("weight sync v{v}: {}/{} engines acked within the timeout", acks.len(), n);
        }
        if acks.iter().any(|a| a.uploaded) {
            // Uploads evicted engine KV caches; the router's warmth
            // beliefs are stale, exactly as in the real driver.
            self.ctrl.warmth.flush();
        }
        self.trace.push(format!("i{iter} sync v{v} -> {n} engines @{:.3}", self.now()));
        Ok(())
    }

    fn next_prompt(&mut self) -> Prompt {
        let id = self.next_prompt_id;
        self.next_prompt_id += 1;
        let tid = (splitmix64(self.cfg.seed ^ splitmix64(id)) % self.cfg.templates.max(1) as u64)
            as u32;
        let mut tokens: Vec<u32> = (0..CACHE_BLOCK as u32).map(|i| tid * 131 + i + 1).collect();
        tokens.extend((0..TAIL_TOKENS as u32).map(|i| 100_000 + (id as u32) * TAIL_TOKENS as u32 + i));
        Prompt { id, tokens, text: format!("sim-{id}"), answer: 0 }
    }

    fn dispatch_batch(&mut self, iter: u32) -> Result<()> {
        let g = self.cfg.group_size;
        for _ in 0..self.cfg.batch_prompts {
            let prompt = self.next_prompt();
            self.assembler.register(prompt.clone(), g);
            let mut jobs = Vec::with_capacity(g);
            for sample_idx in 0..g {
                jobs.push(GenJob {
                    prompt_id: prompt.id,
                    sample_idx,
                    request: GenRequest {
                        request_id: self.ctrl.mint_request_id(),
                        prompt: prompt.tokens.clone(),
                        ..Default::default()
                    },
                    answer: prompt.answer,
                });
            }
            self.minted += g as u64;
            let idx = self.ctrl.pick_engine(&prompt.tokens, true, || 0);
            self.ctrl.note_dispatch(idx, g);
            self.trace.push(format!("i{iter} dispatch p{} -> e{idx}", prompt.id));
            self.send_to(idx, SimMsg::Gen(jobs))?;
        }
        Ok(())
    }

    fn join_engine(&mut self, iter: u32) -> Result<()> {
        let idx = self.spawn_engine();
        // Weight-sync the joiner before it enters the routing pool — a
        // joiner generating at a stale version would break Prop. 1, and
        // the staleness tally below would catch it.
        let (ack_tx, ack_rx) = mpsc::channel();
        self.send_to(idx, SimMsg::SetWeights { version: self.version, ack: ack_tx })?;
        let acks = self.await_replies(&ack_rx, 1, ctrl::STATS_REPLY_TIMEOUT_S);
        if acks.len() != 1 {
            bail!("engine-{idx} never acked its join weight sync");
        }
        self.ctrl.add_engine();
        self.ctrl.set_affinity(self.cfg.affinity && self.engines.len() >= 2);
        self.trace
            .push(format!("i{iter} join e{idx} -> {} engines @{:.3}", self.engines.len(), self.now()));
        Ok(())
    }

    fn drain_tail(&mut self, iter: u32) -> Result<()> {
        if self.engines.len() <= 1 {
            bail!("schedule drains the last engine");
        }
        let idx = self.engines.len() - 1;
        let (ack_tx, ack_rx) = mpsc::channel();
        self.send_to(idx, SimMsg::Drain(ack_tx))?;
        let (ack, pumped) = {
            let mut plane = SimPlane {
                ex: &mut self.ex,
                rx: &self.queue_rx,
                engines: &self.engines,
                silence_s: &mut self.silence_s,
            };
            ctrl::pump_drain_ack(&mut plane, idx, || match ack_rx.try_recv() {
                Ok(a) => AckPoll::Ready(Box::new(a)),
                Err(mpsc::TryRecvError::Empty) => AckPoll::Pending,
                Err(mpsc::TryRecvError::Disconnected) => AckPoll::Gone,
            })?
        };
        // Pumped rollouts are ingested before the pool shrinks, so their
        // engine index still resolves for load accounting (the same order
        // the real driver uses).
        for r in pumped {
            self.ingest(iter, r)?;
        }
        let pend = ack.pending.len();
        let departed = self.ctrl.remove_tail_engine();
        debug_assert_eq!(departed, idx);
        self.engines.pop();
        self.ctrl.set_affinity(self.cfg.affinity && self.engines.len() >= 2);
        for (target, jobs) in self.ctrl.reroute_drained(ack.pending, |_| 0) {
            self.send_to(target, SimMsg::Gen(jobs))?;
        }
        self.trace.push(format!(
            "i{iter} drain e{idx} pend={pend} -> {} engines @{:.3}",
            self.engines.len(),
            self.now()
        ));
        Ok(())
    }

    fn apply_op(&mut self, iter: u32, op: &FleetOp) -> Result<()> {
        match *op {
            FleetOp::Join { .. } => self.join_engine(iter),
            FleetOp::Drain { .. } => self.drain_tail(iter),
            FleetOp::Straggle { engine, factor, .. } => {
                self.check_engine(engine)?;
                self.trace.push(format!("i{iter} straggle e{engine} x{factor}"));
                self.send_to(engine, SimMsg::Straggle(factor))
            }
            FleetOp::KillOnDrain { engine, .. } => {
                self.check_engine(engine)?;
                self.trace.push(format!("i{iter} kill-on-drain e{engine}"));
                self.send_to(engine, SimMsg::KillOnDrain)
            }
            FleetOp::Die { engine, .. } => {
                self.check_engine(engine)?;
                self.trace.push(format!("i{iter} die e{engine}"));
                self.send_to(engine, SimMsg::Die)
            }
        }
    }

    fn ingest(&mut self, iter: u32, r: ScoredRollout) -> Result<()> {
        if !self.seen_ids.insert(r.request_id) {
            bail!("request {} consumed twice (job duplicated)", r.request_id);
        }
        self.max_staleness = self.max_staleness.max(self.version.saturating_sub(r.weight_version));
        self.consumed += 1;
        self.ctrl.note_ingest(r.engine_idx);
        let pid = r.prompt_id;
        if self.assembler.ingest(r)?.is_some() {
            self.iter_groups_done += 1;
            self.trace.push(format!("i{iter} group p{pid} done @{:.3}", self.now()));
        }
        Ok(())
    }

    fn consume_batch(&mut self, iter: u32) -> Result<()> {
        while self.iter_groups_done < self.cfg.batch_prompts {
            let step = {
                let mut plane = SimPlane {
                    ex: &mut self.ex,
                    rx: &self.queue_rx,
                    engines: &self.engines,
                    silence_s: &mut self.silence_s,
                };
                ctrl::recv_step(&mut plane, &mut self.watchdog, ctrl::RECV_POLL_S)?
            };
            match step {
                RecvStep::Got(r) => self.ingest(iter, r)?,
                RecvStep::Waiting { .. } => {}
            }
        }
        Ok(())
    }

    /// End-of-iteration stats sweep: fold each engine's warm-template
    /// advertisement into the router's beliefs and tick the warmth epoch —
    /// the TTL-decay clock the ≥64-engine tests drive.
    fn refresh_warmth(&mut self, iter: u32) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        let mut asked = 0;
        for slot in &self.engines {
            if slot.alive.get() && slot.inbox.try_send(SimMsg::QueryStats(tx.clone())).is_ok() {
                asked += 1;
            }
        }
        drop(tx);
        let mut stats = self.await_replies(&rx, asked, ctrl::STATS_REPLY_TIMEOUT_S);
        stats.sort_by_key(|s| s.engine_idx);
        for s in &stats {
            self.ctrl.warmth.refresh_engine(s.engine_idx, &s.warm);
        }
        self.ctrl.warmth.advance();
        self.trace.push(format!(
            "i{iter} end warm={} out={} @{:.3}",
            self.ctrl.warmth.len(),
            self.ctrl.outstanding(),
            self.now()
        ));
        Ok(())
    }

    fn run_inner(&mut self, ops: &[FleetOp]) -> Result<()> {
        for iter in 0..self.cfg.iters {
            if iter == 0 || (self.cfg.sync_every > 0 && iter % self.cfg.sync_every == 0) {
                self.sync_weights(iter)?;
            }
            self.iter_groups_done = 0;
            self.dispatch_batch(iter)?;
            // Fleet events land mid-iteration, after dispatch: drains hand
            // back queued jobs and joiners can receive re-routes — the
            // corner cases the real fleet schedule exists to exercise.
            for op in ops.iter().filter(|o| o.fires_at() == iter) {
                self.apply_op(iter, op)?;
            }
            self.consume_batch(iter)?;
            self.refresh_warmth(iter)?;
            if self.ctrl.outstanding() != 0 {
                bail!("iteration {iter} ended with {} jobs outstanding", self.ctrl.outstanding());
            }
            if self.assembler.pending_prompts() != 0 {
                bail!(
                    "iteration {iter} ended with {} groups incomplete",
                    self.assembler.pending_prompts()
                );
            }
        }
        if self.consumed != self.minted {
            bail!("job conservation broken: minted {} consumed {}", self.minted, self.consumed);
        }
        Ok(())
    }
}

/// Execute `script` and return its report. Every fleet invariant — job
/// conservation, drain termination, zero Sync-mode staleness, group
/// completion — is enforced inside; violations come back as `Err` with the
/// schedule string attached for [`replay`].
pub fn run(script: &FleetScript) -> Result<SimFleetReport> {
    let schedule = script.to_string();
    let mut fleet = SimFleet::new(&script.cfg).with_context(|| format!("schedule: {schedule}"))?;
    match fleet.run_inner(&script.ops) {
        Ok(()) => Ok(SimFleetReport {
            schedule,
            trace: std::mem::take(&mut fleet.trace),
            minted: fleet.minted,
            consumed: fleet.consumed,
            max_staleness: fleet.max_staleness,
            engines: fleet.engines.len(),
            warm_beliefs: fleet.ctrl.warmth.len(),
            virtual_s: fleet.now(),
            polls: fleet.ex.polls(),
        }),
        Err(e) => Err(e.context(format!("schedule: {schedule}"))),
    }
}

/// Parse and run a schedule string — the replay entry point printed by
/// failing property tests (`docs/CONCURRENCY.md`, "Deterministic
/// coordinator").
pub fn replay(schedule: &str) -> Result<SimFleetReport> {
    run(&FleetScript::parse(schedule)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_string_round_trips() {
        let s = "simfleet/v1 e=4 i=3 b=8 g=2 tpl=4 seed=42 aff=1 ttl=2 sv=1 cap=64 \
                 ops=j@1,d@2,s@0:1x8,k@1:3,x@2:0";
        let script = FleetScript::parse(s).unwrap();
        assert_eq!(script.to_string(), s);
        assert_eq!(script.ops.len(), 5);
        assert_eq!(script.ops[2], FleetOp::Straggle { iter: 0, engine: 1, factor: 8.0 });
    }

    #[test]
    fn schedule_rejects_garbage() {
        assert!(FleetScript::parse("fleet/v0 e=2").is_err());
        assert!(FleetScript::parse("simfleet/v1 bogus").is_err());
        assert!(FleetScript::parse("simfleet/v1 z=3").is_err());
        assert!(FleetScript::parse("simfleet/v1 ops=q@1").is_err());
        assert!(FleetScript::parse("simfleet/v1 ops=s@1:2").is_err());
    }

    #[test]
    fn random_schedules_round_trip() {
        for seed in 0..32 {
            let script = FleetScript::random(SimFleetCfg::default(), seed);
            let reparsed = FleetScript::parse(&script.to_string()).unwrap();
            assert_eq!(script.ops, reparsed.ops, "seed {seed}");
            assert_eq!(script.to_string(), reparsed.to_string(), "seed {seed}");
        }
    }

    #[test]
    fn smoke_run_conserves_jobs_and_stays_on_policy() {
        let script = FleetScript {
            cfg: SimFleetCfg { engines: 2, iters: 2, ..Default::default() },
            ops: vec![],
        };
        let r = run(&script).unwrap();
        assert_eq!(r.minted, r.consumed);
        assert_eq!(r.minted, 2 * 8 * 2); // iters * batch * group
        assert_eq!(r.max_staleness, 0);
        assert!(r.virtual_s > 0.0);
    }

    #[test]
    fn drain_and_join_mid_iteration_lose_nothing() {
        let script = FleetScript {
            cfg: SimFleetCfg { engines: 3, iters: 3, seed: 7, ..Default::default() },
            ops: vec![
                FleetOp::Drain { iter: 0 },
                FleetOp::Join { iter: 1 },
                FleetOp::Straggle { iter: 1, engine: 0, factor: 8.0 },
            ],
        };
        let r = run(&script).unwrap();
        assert_eq!(r.minted, r.consumed);
        assert_eq!(r.max_staleness, 0);
        assert_eq!(r.engines, 3); // 3 - 1 + 1
    }

    #[test]
    fn small_queue_cap_exercises_backpressure() {
        let script = FleetScript {
            cfg: SimFleetCfg { engines: 2, iters: 2, queue_cap: 1, ..Default::default() },
            ops: vec![FleetOp::Drain { iter: 1 }],
        };
        let r = run(&script).unwrap();
        assert_eq!(r.minted, r.consumed);
    }

    #[test]
    fn kill_on_drain_surfaces_the_pump_error() {
        let script = FleetScript {
            cfg: SimFleetCfg { engines: 2, iters: 1, ..Default::default() },
            ops: vec![FleetOp::KillOnDrain { iter: 0, engine: 1 }, FleetOp::Drain { iter: 0 }],
        };
        let err = run(&script).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("exited without acking the drain"), "got: {msg}");
        assert!(msg.contains("simfleet/v1"), "error should carry the schedule: {msg}");
    }

    #[test]
    fn dead_fleet_surfaces_liveness_error_not_a_hang() {
        let script = FleetScript {
            cfg: SimFleetCfg { engines: 2, iters: 1, ..Default::default() },
            ops: vec![FleetOp::Die { iter: 0, engine: 0 }, FleetOp::Die { iter: 0, engine: 1 }],
        };
        let err = run(&script).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("all engine workers exited"), "got: {msg}");
    }

    #[test]
    fn same_schedule_same_trace() {
        let script = FleetScript::random(
            SimFleetCfg { engines: 6, iters: 3, seed: 9, ..Default::default() },
            17,
        );
        let a = run(&script).unwrap();
        let b = replay(&a.schedule).unwrap();
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.polls, b.polls);
        assert_eq!(a.virtual_s, b.virtual_s);
    }
}
