//! Specifications for the cluster-scale simulator: devices, clusters, models
//! and workloads, parameterised with the paper's testbeds (air-cooled
//! Ascend-910B nodes, A100-40G nodes) and datasets (DeepScaleR long-response,
//! GSM8K short-response).

use crate::util::rng::Pcg64;

/// An accelerator device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s (decode is bandwidth-bound).
    pub hbm_bw: f64,
    /// Device memory, bytes.
    pub mem_bytes: f64,
}

impl DeviceSpec {
    /// Air-cooled Ascend-910B (paper §6.1: 64 GB, ~376 TFLOPs bf16 nominal;
    /// air-cooled parts clock lower, ~280 TF effective peak).
    pub fn ascend_910b() -> DeviceSpec {
        DeviceSpec { peak_flops: 280e12, hbm_bw: 1.2e12, mem_bytes: 64e9 }
    }

    /// NVIDIA A100-40G (312 TFLOPs bf16, 1.55 TB/s).
    pub fn a100_40g() -> DeviceSpec {
        DeviceSpec { peak_flops: 312e12, hbm_bw: 1.555e12, mem_bytes: 40e9 }
    }
}

/// A cluster of identical devices.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    pub device: DeviceSpec,
    pub n_devices: usize,
    /// Devices per node.
    pub node_size: usize,
    /// Intra-node interconnect, bytes/s per device (910B: 196 GB/s; A100
    /// paper setup: 64 GB/s).
    pub intra_bw: f64,
    /// Inter-node network, bytes/s per device (100 Gb/s = 12.5 GB/s).
    pub inter_bw: f64,
}

impl ClusterSpec {
    pub fn npu(n_devices: usize) -> ClusterSpec {
        ClusterSpec {
            device: DeviceSpec::ascend_910b(),
            n_devices,
            node_size: 8,
            intra_bw: 196e9,
            inter_bw: 12.5e9,
        }
    }

    pub fn gpu(n_devices: usize) -> ClusterSpec {
        ClusterSpec {
            device: DeviceSpec::a100_40g(),
            n_devices,
            node_size: 8,
            intra_bw: 64e9,
            inter_bw: 12.5e9,
        }
    }

    /// Effective per-device bandwidth for cluster-wide weight movement:
    /// bottlenecked by the inter-node link once the cluster spans nodes.
    pub fn sync_bw(&self) -> f64 {
        if self.n_devices > self.node_size {
            self.inter_bw
        } else {
            self.intra_bw
        }
    }
}

/// Model size class.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    /// Parameter count.
    pub params: f64,
    /// Bytes per parameter in inference/weight-sync (bf16).
    pub param_bytes: f64,
    /// KV-cache bytes per token (2 · layers · kv_heads · head_dim · 2B) —
    /// decode at long context is dominated by streaming this from HBM.
    pub kv_bytes_per_token: f64,
}

impl ModelSpec {
    /// Qwen-family sizes used in the paper (KV geometry from the model cards:
    /// 1.5B: 28L × 2kv × 128; 7B: 28L × 4×128; Qwen3-8B: 36L × 8×128;
    /// R1-Distill-32B: 64L × 8×128).
    pub fn qwen(params_b: f64) -> ModelSpec {
        let (layers, kv_dim) = if params_b <= 2.0 {
            (28.0, 256.0)
        } else if params_b <= 7.5 {
            (28.0, 512.0)
        } else if params_b <= 14.0 {
            (36.0, 1024.0)
        } else {
            (64.0, 1024.0)
        };
        ModelSpec {
            params: params_b * 1e9,
            param_bytes: 2.0,
            kv_bytes_per_token: 2.0 * layers * kv_dim * 2.0,
        }
    }

    /// Training FLOPs per token. The paper's unified tri-model computes
    /// policy fwd+bwd (6P) and the old/reference logits (2P each) in a single
    /// fused pass: 10P effective. Baseline frameworks schedule old-logprob
    /// and ref-logprob as *separate phases* (extra weight gathers, launches,
    /// memory traffic), which we charge as the two forwards running at half
    /// efficiency: 6P + 2·(2P·2) = 14P effective.
    pub fn train_flops_per_token(&self, unified_tri_model: bool) -> f64 {
        if unified_tri_model {
            10.0 * self.params
        } else {
            14.0 * self.params
        }
    }

    pub fn infer_flops_per_token(&self) -> f64 {
        2.0 * self.params
    }

    pub fn weight_bytes(&self) -> f64 {
        self.params * self.param_bytes
    }
}

/// Workload: prompt/response length distributions and GRPO shape.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Prompts per iteration (global batch).
    pub batch_prompts: usize,
    /// Rollouts per prompt (G = 32 in all paper experiments).
    pub group_size: usize,
    pub prompt_mean: f64,
    pub prompt_std: f64,
    /// Lognormal response lengths (heavy right tail, like CoT rollouts).
    pub response_median: f64,
    pub response_sigma: f64,
    /// Context limit: prompt + response truncated here.
    pub context: usize,
}

impl WorkloadSpec {
    /// DeepScaleR-like: short prompts, long chain-of-thought responses.
    pub fn deepscaler(batch_prompts: usize, context: usize) -> WorkloadSpec {
        WorkloadSpec {
            batch_prompts,
            group_size: 32,
            prompt_mean: 110.0,
            prompt_std: 30.0,
            response_median: 6500.0,
            response_sigma: 0.75,
            context,
        }
    }

    /// GSM8K-like at 1K context: medium prompts, short answers — the
    /// training-dominated, SPA-friendly regime of paper Table 3.
    pub fn gsm8k(batch_prompts: usize) -> WorkloadSpec {
        WorkloadSpec {
            batch_prompts,
            group_size: 32,
            prompt_mean: 180.0,
            prompt_std: 60.0,
            response_median: 320.0,
            response_sigma: 0.70,
            context: 1024,
        }
    }

    /// Mean response length (lognormal mean = median · exp(σ²/2), truncated).
    pub fn response_mean(&self) -> f64 {
        (self.response_median * (self.response_sigma * self.response_sigma / 2.0).exp())
            .min(self.context as f64 - self.prompt_mean)
    }

    /// Average attention context during decode (prompt + half the response) —
    /// what a decode step's KV read amortises to over a sequence's lifetime.
    pub fn avg_decode_context(&self) -> f64 {
        (self.prompt_mean + self.response_mean() / 2.0).min(self.context as f64)
    }

    /// Sample one (prompt_len, response_len) pair.
    pub fn sample(&self, rng: &mut Pcg64) -> (usize, usize) {
        let p = (self.prompt_mean + self.prompt_std * rng.normal())
            .clamp(8.0, self.context as f64 * 0.8);
        let mu = self.response_median.ln();
        let r = rng.lognormal(mu, self.response_sigma);
        let p = p.round() as usize;
        let r = (r.round() as usize).clamp(1, self.context - p);
        (p, r)
    }
}

/// Parallelism/efficiency knobs per framework (paper Table 9 analog).
#[derive(Debug, Clone, Copy)]
pub struct EfficiencySpec {
    /// Training MFU (model FLOPs utilisation).
    pub train_mfu: f64,
    /// Prefill MFU.
    pub prefill_mfu: f64,
    /// Decode bandwidth utilisation.
    pub decode_bw_util: f64,
    /// Fixed per-iteration overhead (scheduling, python, dataloader), s.
    pub iter_overhead: f64,
    /// Extra per-iteration cost for colocated designs: weight resharding /
    /// engine switch between train and rollout phases, s per GB of weights.
    pub reshard_s_per_gb: f64,
    /// Padding inflation for frameworks that pad micro-batches to the max
    /// sample length (1.0 = native dynamic lengths, no padding).
    pub padding_factor: f64,
    /// Whether policy/old/reference logits are computed in one fused pass
    /// (the paper's unified tri-model) or as separate scheduled phases.
    pub unified_tri_model: bool,
}

impl EfficiencySpec {
    /// pa-rl / EasyLLM-like: dynamic-length training, no padding.
    pub fn ours() -> EfficiencySpec {
        EfficiencySpec {
            train_mfu: 0.42,
            prefill_mfu: 0.50,
            decode_bw_util: 0.55,
            iter_overhead: 2.0,
            reshard_s_per_gb: 0.0,
            padding_factor: 1.0,
            unified_tri_model: true,
        }
    }

    /// MindSpeed-RL-like: Megatron backend, shared-accelerator (colocated)
    /// design — pays resharding on every phase switch and pads micro-batches.
    pub fn mindspeed() -> EfficiencySpec {
        EfficiencySpec {
            train_mfu: 0.38,
            prefill_mfu: 0.45,
            decode_bw_util: 0.45,
            iter_overhead: 8.0,
            reshard_s_per_gb: 1.4,
            padding_factor: 1.35,
            unified_tri_model: false,
        }
    }

    /// VERL-like: FSDP colocated, vLLM rollouts (continuous batching),
    /// lighter resharding than full Megatron reshard.
    pub fn verl() -> EfficiencySpec {
        EfficiencySpec {
            train_mfu: 0.32,
            prefill_mfu: 0.50,
            decode_bw_util: 0.40,
            iter_overhead: 20.0,
            reshard_s_per_gb: 0.5,
            padding_factor: 1.15,
            unified_tri_model: false,
        }
    }

    /// AReaL-like: fully asynchronous decoupled system. The cross-iteration
    /// pipeline removes every barrier, but the machinery that makes it safe
    /// (interruptible rollouts, KV migration on weight update, staleness
    /// bookkeeping) taxes both stages — the reason the paper's periodic
    /// design still wins end-to-end (Table 4) despite synchronising more.
    pub fn areal() -> EfficiencySpec {
        EfficiencySpec {
            train_mfu: 0.36,
            prefill_mfu: 0.47,
            decode_bw_util: 0.48,
            iter_overhead: 3.0,
            reshard_s_per_gb: 0.0,
            padding_factor: 1.05,
            unified_tri_model: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_samples_within_context() {
        let mut rng = Pcg64::seeded(1);
        let w = WorkloadSpec::deepscaler(32, 16384);
        for _ in 0..2000 {
            let (p, r) = w.sample(&mut rng);
            assert!(p + r <= 16384);
            assert!(r >= 1);
        }
    }

    #[test]
    fn gsm8k_shorter_than_deepscaler() {
        let mut rng = Pcg64::seeded(2);
        let d = WorkloadSpec::deepscaler(8, 16384);
        let g = WorkloadSpec::gsm8k(8);
        let mean = |w: &WorkloadSpec, rng: &mut Pcg64| {
            (0..500).map(|_| w.sample(rng).1).sum::<usize>() as f64 / 500.0
        };
        assert!(mean(&d, &mut rng) > 5.0 * mean(&g, &mut rng));
    }

    #[test]
    fn sync_bw_depends_on_span() {
        assert_eq!(ClusterSpec::npu(8).sync_bw(), 196e9);
        assert_eq!(ClusterSpec::npu(16).sync_bw(), 12.5e9);
    }

    #[test]
    fn model_flops() {
        let m = ModelSpec::qwen(8.0);
        assert_eq!(m.params, 8e9);
        assert_eq!(m.infer_flops_per_token(), 16e9);
        assert_eq!(m.weight_bytes(), 16e9);
    }
}
