//! Framework executors: analytic + discrete-event models of the five RL
//! systems the paper compares (MindSpeed-RL, VERL, AReaL, and our
//! synchronous / periodically-asynchronous designs), over the cluster and
//! workload specs. Regenerates the *shape* of Tables 1–5 (who wins, by what
//! factor, where crossovers fall); absolute TPSPD depends on testbed
//! constants we only approximate.

use super::queue::{multi_server_fifo, sequential_with_ready, wave_batching};
use super::specs::{ClusterSpec, EfficiencySpec, ModelSpec, WorkloadSpec};
use crate::metrics::{RequestMetrics, RequestTimeline, Trace};
use crate::util::rng::RequestRng;

/// Which of the five system designs to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    /// MindSpeed-RL-like: colocated Megatron, wave-batched rollouts,
    /// reshard between phases.
    ColocatedSync,
    /// VERL-like: colocated FSDP + vLLM continuous batching, sequential
    /// phases.
    ColocatedContinuous,
    /// Ours, synchronous: decoupled instances, continuous batching, training
    /// waits for the full batch.
    DecoupledSync,
    /// Ours, periodic asynchrony: training consumes rollouts in
    /// completion-time order within the iteration.
    PeriodicAsync,
    /// AReaL-like: fully asynchronous across iterations (off-policy,
    /// staleness-controlled).
    FullyAsync,
}

impl Framework {
    pub fn label(&self) -> &'static str {
        match self {
            Framework::ColocatedSync => "MindSpeed-RL (sim)",
            Framework::ColocatedContinuous => "VERL (sim)",
            Framework::DecoupledSync => "Sync (ours, sim)",
            Framework::PeriodicAsync => "Async (ours, sim)",
            Framework::FullyAsync => "AReaL (sim)",
        }
    }
}

/// Full experiment setup.
#[derive(Debug, Clone)]
pub struct SimSetup {
    pub cluster: ClusterSpec,
    pub model: ModelSpec,
    pub workload: WorkloadSpec,
    pub eff: EfficiencySpec,
    pub framework: Framework,
    /// Decoupled designs: fraction of devices serving inference (paper: the
    /// training:rollout ratio, typically 1:4; tuned per platform — see
    /// [`SimSetup::run_tuned`]).
    pub infer_fraction: f64,
    /// Tensor-parallel degree of one inference instance.
    pub infer_tp: usize,
    /// Shared-prompt attention in the trainer.
    pub spa: bool,
    /// Shared-prefix KV cache in the inference engines (the rust engine's
    /// `engine::kvcache`): with group-affine dispatch, members 1..G of each
    /// group skip prefill compute and pay only a KV-copy (HBM-bound) cost.
    pub prefix_cache: bool,
    /// Chunked partial-prefix reuse: the fraction of each *group-leader*
    /// prompt covered by a warm cached template (few-shot prefixes shared
    /// across prompts). Leaders prefill only the uncached remainder and pay
    /// the KV-copy cost for the rest; 0.0 = leaders prefill from scratch
    /// (full-prompt hits only, the pre-chunked engine). Requires
    /// `prefix_cache`.
    pub template_frac: f64,
    /// Cross-engine KV sharing (the host-side shared segment store): the
    /// template is warm *fleet-wide* after one cold leader, instead of per
    /// inference instance — with it off, the first leader on each instance
    /// pays the cold template (the PR-2, per-engine-cache reality).
    /// Meaningful only with `prefix_cache` and a nonzero `template_frac`.
    pub cross_engine: bool,
    /// Hash-range shards of the host-side store (`engine.store_shards`).
    /// Every group-leader admission does a store round-trip (fetch +
    /// publish) that serializes on its shard's lock; with one shard the
    /// whole fleet contends on a single mutex, with `min(shards,
    /// instances)` lanes the aggregate store time divides accordingly.
    /// Only meaningful with `cross_engine`; >= 1.
    pub store_shards: usize,
    /// Elastic fleet (the driver's `spawn_engine` path, modeled): this
    /// fraction of the run's iterations, from the start, is served with only
    /// half the inference instances — the other half join at the warmup
    /// boundary, weight-synced, so nothing about the trained tokens changes
    /// (periodic asynchrony keeps joins on-policy by construction). 0.0 =
    /// static fleet, bit-identical to the pre-elastic simulator. With a
    /// nonzero warmup, TPSPD divides by *device-seconds actually deployed*
    /// instead of peak devices × wall: the elasticity dividend is not paying
    /// for engines before they join. Decoupled frameworks only (colocated
    /// designs have no separate inference fleet to resize).
    pub elastic_warmup_frac: f64,
    /// Samples per training micro-batch (paper's Micro-BS column; SPA packs
    /// the whole group into one launch regardless). Determines kernel-launch
    /// overhead, which is what makes micro-bs 1 at short sequence lengths so
    /// expensive (Table 3's "Async w/o SPA" row).
    pub train_micro_bs: usize,
    /// Per-micro-batch launch/dispatch cost, seconds. Platform-dependent:
    /// ~0.5s on the NPU stack (graph launch + host sync), ~0.1s on GPU.
    pub micro_launch_s: f64,
    pub iters: usize,
    pub seed: u64,
}

/// Result of a simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub framework: Framework,
    pub wall_seconds: f64,
    pub trained_tokens: f64,
    pub tpspd: f64,
    /// Mean per-iteration inference / training phase durations (for the
    /// speedup-bound analysis, Eq. 2–4).
    pub t_infer_mean: f64,
    pub t_train_mean: f64,
    pub consumer_idle_mean: f64,
    pub staleness_mean: f64,
    /// Inference device fraction actually used (after tuning).
    pub infer_fraction: f64,
}

struct IterOutcome {
    wall: f64,
    t_infer: f64,
    t_train: f64,
    idle: f64,
    tokens: f64,
}

impl SimSetup {
    fn infer_devices(&self) -> usize {
        match self.framework {
            Framework::ColocatedSync | Framework::ColocatedContinuous => self.cluster.n_devices,
            _ => ((self.cluster.n_devices as f64 * self.infer_fraction).round() as usize)
                .clamp(self.infer_tp, self.cluster.n_devices - 1),
        }
    }

    fn train_devices(&self) -> usize {
        match self.framework {
            Framework::ColocatedSync | Framework::ColocatedContinuous => self.cluster.n_devices,
            _ => self.cluster.n_devices - self.infer_devices(),
        }
    }

    /// KV slots per inference instance, from the memory budget. Decoupled
    /// instances devote everything beyond the weight shard to KV; colocated
    /// designs reserve most memory for training state (the vLLM
    /// `gpu_memory_utilization` knob), which is a key structural handicap.
    pub fn slots_per_instance(&self) -> usize {
        let per_dev_weights = self.model.weight_bytes() / self.infer_tp as f64;
        let per_dev_kv = match self.framework {
            // MindSpeed-RL offloads training state during rollout -> more KV
            Framework::ColocatedSync => {
                (self.cluster.device.mem_bytes * 0.45 - per_dev_weights).max(self.cluster.device.mem_bytes * 0.02)
            }
            // VERL-style gpu_memory_utilization carve-out beside live FSDP state
            Framework::ColocatedContinuous => {
                (self.cluster.device.mem_bytes * 0.25 - per_dev_weights).max(self.cluster.device.mem_bytes * 0.02)
            }
            _ => (self.cluster.device.mem_bytes - per_dev_weights - 6e9).max(self.cluster.device.mem_bytes * 0.02),
        };
        let kv_per_seq = self.model.kv_bytes_per_token * self.workload.context as f64;
        // capped by the scheduler's max concurrent sequences (vLLM
        // max_num_seqs-style) — at short contexts memory would otherwise
        // admit thousands of sequences the batcher never schedules.
        ((self.infer_tp as f64 * per_dev_kv / kv_per_seq) as usize).clamp(1, 64)
    }

    /// Per-token decode step time for one instance at full occupancy:
    /// every step streams the weight shard plus all active sequences' KV
    /// (amortised to the mean decode context) through HBM.
    fn decode_step_s(&self, slots: usize) -> f64 {
        let weights = self.model.weight_bytes();
        let kv = slots as f64 * self.model.kv_bytes_per_token * self.workload.avg_decode_context();
        let bw_bound = (weights + kv)
            / (self.infer_tp as f64 * self.cluster.device.hbm_bw * self.eff.decode_bw_util);
        // at large batch the step becomes compute-bound
        let compute_bound = slots as f64 * self.model.infer_flops_per_token()
            / (self.infer_tp as f64 * self.cluster.device.peak_flops * 0.35);
        bw_bound.max(compute_bound)
    }

    /// Prefill time for `tokens` prompt tokens on one instance.
    fn prefill_s(&self, tokens: f64) -> f64 {
        let flops = tokens * self.model.infer_flops_per_token();
        let inst_flops =
            self.infer_tp as f64 * self.cluster.device.peak_flops * self.eff.prefill_mfu;
        flops / inst_flops
    }

    /// Admission cost for `tokens` prompt tokens whose KV is already cached:
    /// no prefill FLOPs, just streaming the KV rows into the slot
    /// (HBM-bandwidth bound). Orders of magnitude below [`Self::prefill_s`].
    fn shared_prefill_s(&self, tokens: f64) -> f64 {
        tokens * self.model.kv_bytes_per_token
            / (self.infer_tp as f64 * self.cluster.device.hbm_bw * self.eff.decode_bw_util)
    }

    /// Host-side DRAM copy bandwidth for store round-trips (publish/fetch
    /// copy template KV under a shard lock). Conservative single-socket
    /// memcpy figure; the point is contention structure, not absolutes.
    const HOST_COPY_BW: f64 = 25e9;

    /// Excess lock-serialization time the fleet spends queueing on the
    /// shared store this iteration. Each of the `groups` leaders fetches and
    /// publishes its template once (two copies of the template's KV bytes at
    /// host bandwidth); unrelated templates spread over
    /// `min(store_shards, instances)` independent locks. The copy itself
    /// overlaps admission compute when every instance has its own lane — so
    /// the model charges only the queueing *beyond* that parallel floor:
    /// zero for a fully sharded store (or a single instance), and the full
    /// fleet-minus-one serialization for the pre-shard single mutex.
    fn store_serial_s(&self, groups: usize, mean_lp: f64) -> f64 {
        if !self.cross_engine || !self.prefix_cache {
            return 0.0;
        }
        let n_instances = (self.infer_devices() / self.infer_tp).max(1) as f64;
        let lanes = (self.store_shards.max(1) as f64).min(n_instances);
        let tpl_bytes = mean_lp * self.template_frac.clamp(0.0, 1.0) * self.model.kv_bytes_per_token;
        let op_s = 2.0 * tpl_bytes / Self::HOST_COPY_BW; // fetch + publish
        groups as f64 * op_s * (1.0 / lanes - 1.0 / n_instances)
    }

    /// Rollout service time (prefill + decode). `matched_frac` is the
    /// fraction of the prompt restored from the prefix cache (1.0 = full
    /// hit, the in-group case; between 0 and 1 = chunked partial-prefix
    /// resume from a warm template): the discount scales with it.
    fn rollout_service(&self, lp: usize, lr: usize, step_s: f64, matched_frac: f64) -> f64 {
        let cached = lp as f64 * matched_frac.clamp(0.0, 1.0);
        let fresh = lp as f64 - cached;
        self.prefill_s(fresh) + self.shared_prefill_s(cached) + lr as f64 * step_s
    }

    /// Tokens entering training compute for one group.
    fn group_train_tokens(&self, group: &[(usize, usize)]) -> f64 {
        if self.spa {
            let lp = group[0].0 as f64;
            let lr: f64 = group.iter().map(|&(_, r)| r as f64).sum();
            lp + lr + group.len() as f64 // + duplicated prompt-last tokens
        } else {
            group.iter().map(|&(p, r)| (p + r) as f64).sum()
        }
    }

    /// Training time for one group on the training sub-cluster.
    fn group_train_s(&self, group: &[(usize, usize)]) -> f64 {
        let tokens = self.group_train_tokens(group) * self.eff.padding_factor;
        let flops = tokens * self.model.train_flops_per_token(self.eff.unified_tri_model);
        let cluster_flops =
            self.train_devices() as f64 * self.cluster.device.peak_flops * self.eff.train_mfu;
        let launches = if self.spa {
            1
        } else {
            group.len().div_ceil(self.train_micro_bs.max(1))
        };
        // data-parallel gradient reduction spans nodes at larger scale,
        // eroding training MFU (the paper's Table 5 TPSPD decline)
        let train_nodes = self.train_devices().div_ceil(self.cluster.node_size).max(1);
        let node_decay = 1.0 + 0.03 * (train_nodes as f64 - 1.0);
        flops * node_decay / cluster_flops + self.micro_launch_s * launches as f64
    }

    /// Optimizer update at iteration end (reads/writes weights + moments).
    fn update_s(&self) -> f64 {
        6.0 * self.model.weight_bytes()
            / (self.train_devices() as f64 * self.cluster.device.hbm_bw * 0.5)
    }

    /// Weight-sync (decoupled) or reshard (colocated) cost per iteration.
    fn weight_move_s(&self) -> f64 {
        match self.framework {
            Framework::ColocatedSync | Framework::ColocatedContinuous => {
                // reshard to rollout engine and back
                2.0 * self.eff.reshard_s_per_gb * self.model.weight_bytes() / 1e9
            }
            _ => {
                // pipelined broadcast bottlenecked at per-device link
                self.model.weight_bytes() / self.cluster.sync_bw() + 0.5
            }
        }
    }

    /// Simulate the run.
    pub fn run(&self) -> SimResult {
        self.run_traced(None)
    }

    /// Simulate with the training:rollout ratio tuned for best TPSPD —
    /// the paper tunes this knob per platform (§5: "deployed as separate
    /// instances with a configurable ratio, tuned per platform to balance
    /// throughput"). No-op for colocated designs.
    pub fn run_tuned(&self) -> SimResult {
        match self.framework {
            Framework::ColocatedSync | Framework::ColocatedContinuous => self.run(),
            _ => {
                let mut best: Option<SimResult> = None;
                for pct in [40, 50, 60, 70, 75, 80, 85, 90] {
                    let mut s = self.clone();
                    s.infer_fraction = pct as f64 / 100.0;
                    // keep at least one device on each side
                    let r = s.run();
                    if best.as_ref().map(|b| r.tpspd > b.tpspd).unwrap_or(true) {
                        best = Some(r);
                    }
                }
                best.unwrap()
            }
        }
    }

    /// The pre-join fleet for the elastic-warmup ablation: the same training
    /// sub-cluster, half the inference instances. `None` for static runs and
    /// for colocated frameworks (no separate fleet to resize).
    fn elastic_reduced_setup(&self) -> Option<SimSetup> {
        if self.elastic_warmup_frac <= 0.0
            || matches!(
                self.framework,
                Framework::ColocatedSync | Framework::ColocatedContinuous
            )
        {
            return None;
        }
        let train = self.train_devices();
        let half_infer = (self.infer_devices() / 2).max(self.infer_tp);
        let mut s = self.clone();
        s.elastic_warmup_frac = 0.0;
        // Shrink the *cluster* to the deployed devices: the not-yet-joined
        // engines do not exist during warmup (they are not reassigned to
        // training), and the fraction is set so the instance math lands on
        // exactly `half_infer` inference devices.
        s.cluster.n_devices = train + half_infer;
        s.infer_fraction = half_infer as f64 / (train + half_infer) as f64;
        Some(s)
    }

    /// Simulate, optionally recording one iteration's timeline (Fig. 3).
    pub fn run_traced(&self, trace: Option<&Trace>) -> SimResult {
        self.run_traced_metrics(trace, None)
    }

    /// Like [`Self::run_traced`], additionally synthesizing per-request
    /// lifecycle timelines for the first iteration into `requests` — the
    /// same [`RequestMetrics`] schema the real driver aggregates in
    /// full-telemetry mode, so fig3 sim and real outputs are comparable.
    pub fn run_traced_metrics(
        &self,
        mut trace: Option<&Trace>,
        mut requests: Option<&mut RequestMetrics>,
    ) -> SimResult {
        // Workload draws are keyed per dispatch-order id — the sim mirror of
        // the engine's per-request sampling streams. Ids are minted in a
        // fixed order (prompt, then its G members), so every drawn length is
        // a pure function of `(seed, id)`: no shared sequential generator
        // whose consumption order could couple draws to fleet composition.
        let draw_rng = |id: u64| RequestRng::new(self.seed ^ 0x51A7, id).at_step(0);
        let mut next_id = 0u64;
        let reduced = self.elastic_reduced_setup();
        let warmup_iters =
            (self.iters as f64 * self.elastic_warmup_frac.clamp(0.0, 1.0)).round() as usize;
        let mut wall = 0.0;
        let mut tokens = 0.0;
        let mut device_seconds = 0.0;
        let mut t_inf_sum = 0.0;
        let mut t_train_sum = 0.0;
        let mut idle_sum = 0.0;
        for it in 0..self.iters {
            // Pre-join iterations run on the reduced fleet; the full fleet
            // takes over at the warmup boundary (the joiners' weight sync is
            // part of the ordinary iteration-boundary sync, so it costs
            // nothing extra here).
            let setup: &SimSetup = match &reduced {
                Some(r) if it < warmup_iters => r,
                _ => self,
            };
            // Sample the batch: N groups of G rollouts. Always drawn from
            // `self`'s workload spec so the stream is identical whether or
            // not the fleet is elastic — joins must not change what is
            // trained.
            let groups: Vec<Vec<(usize, usize)>> = (0..self.workload.batch_prompts)
                .map(|_| {
                    let mut prompt_rng = draw_rng(next_id);
                    next_id += 1;
                    let (lp, _) = self.workload.sample(&mut prompt_rng);
                    (0..self.workload.group_size)
                        .map(|_| {
                            let mut member_rng = draw_rng(next_id);
                            next_id += 1;
                            let (_, lr) = self.workload.sample(&mut member_rng);
                            (lp, lr.min(self.workload.context - lp))
                        })
                        .collect()
                })
                .collect();
            let out = setup.run_iteration(
                &groups,
                trace.take().filter(|_| it == 0),
                if it == 0 { requests.take() } else { None },
            );
            wall += out.wall;
            tokens += out.tokens;
            device_seconds += out.wall * (setup.train_devices() + setup.infer_devices()) as f64;
            t_inf_sum += out.t_infer;
            t_train_sum += out.t_train;
            idle_sum += out.idle;
        }
        let n = self.iters as f64;
        let staleness_mean = match self.framework {
            Framework::FullyAsync => 1.0,
            _ => 0.0,
        };
        // Elastic runs bill the devices actually deployed per iteration;
        // static runs keep the exact peak-devices formula (bit-identical to
        // the pre-elastic simulator).
        let tpspd = if reduced.is_some() {
            tokens / device_seconds
        } else {
            tokens / (wall * self.cluster.n_devices as f64)
        };
        SimResult {
            framework: self.framework,
            wall_seconds: wall,
            trained_tokens: tokens,
            tpspd,
            t_infer_mean: t_inf_sum / n,
            t_train_mean: t_train_sum / n,
            consumer_idle_mean: idle_sum / n,
            staleness_mean,
            infer_fraction: self.infer_fraction,
        }
    }

    fn run_iteration(
        &self,
        groups: &[Vec<(usize, usize)>],
        trace: Option<&Trace>,
        requests: Option<&mut RequestMetrics>,
    ) -> IterOutcome {
        let slots = self.slots_per_instance();
        let servers = (self.infer_devices() / self.infer_tp).max(1) * slots;
        let step_s = self.decode_step_s(slots);
        // Group-major dispatch order: a prompt's G rollouts enter the batch
        // together (vLLM n=G semantics), so early groups complete early and
        // the consumer's overlap window opens immediately.
        let g = self.workload.group_size;
        let mut order: Vec<(usize, usize)> = Vec::new(); // (group, member)
        for (gi, _) in groups.iter().enumerate() {
            for m in 0..g {
                order.push((gi, m));
            }
        }
        // Template warm-up horizon: with cross-engine sharing one cold
        // leader warms the whole fleet; without it, the first leader landing
        // on each inference instance pays the cold template.
        let n_instances = (self.infer_devices() / self.infer_tp).max(1);
        let cold_leaders = if self.cross_engine { 1 } else { n_instances };
        let service: Vec<f64> = order
            .iter()
            .map(|&(gi, m)| {
                let (lp, lr) = groups[gi][m];
                // Group-affine dispatch: member 0 prefills and populates the
                // prefix cache; members 1.. reuse its whole prompt KV. With
                // chunked partial-prefix reuse, the leader resumes from the
                // warm template fraction of its prompt once the template is
                // warm on (or importable by) its engine.
                let matched_frac = if !self.prefix_cache {
                    0.0
                } else if m > 0 {
                    1.0
                } else if gi < cold_leaders {
                    0.0
                } else {
                    self.template_frac
                };
                self.rollout_service(lp, lr, step_s, matched_frac)
            })
            .collect();

        let tokens: f64 = groups.iter().map(|grp| self.group_train_tokens(grp)).sum();
        let train_each: Vec<f64> = groups.iter().map(|grp| self.group_train_s(grp)).collect();
        let t_update = self.update_s();
        let t_move = self.weight_move_s();
        let overhead = self.eff.iter_overhead;

        let completions = match self.framework {
            Framework::ColocatedSync => wave_batching(0.0, &service, servers),
            _ => multi_server_fifo(0.0, &service, servers),
        };
        // Group ready time = completion of its slowest member.
        let mut ready = vec![0.0f64; groups.len()];
        for (idx, &(gi, _)) in order.iter().enumerate() {
            ready[gi] = ready[gi].max(completions[idx]);
        }
        // Host-store lock serialization: leaders' fetch+publish round-trips
        // stall admission; the stall shrinks with the shard lane count.
        let mean_lp = groups.iter().map(|grp| grp[0].0 as f64).sum::<f64>()
            / groups.len().max(1) as f64;
        let t_store = self.store_serial_s(groups.len(), mean_lp);
        let t_infer = completions.iter().cloned().fold(0.0f64, f64::max) + t_store;
        let t_train: f64 = train_each.iter().sum();

        if let Some(tr) = trace {
            for (idx, &(gi, m)) in order.iter().enumerate() {
                let lane = format!("slot-{:02}", idx % servers.min(16));
                tr.record_abs(&lane, &format!("rollout g{gi}.{m}"), completions[idx] - service[idx], completions[idx]);
            }
        }

        // Synthesized request timelines (same schema the real driver stamps
        // in full-telemetry mode): every request enqueues and dispatches at
        // the iteration start, is admitted when its server picks it up, and
        // samples its first token once the prefill portion of service is
        // done — the remaining lr-1 tokens are decode-phase.
        if let Some(req) = requests {
            let staleness = u64::from(matches!(self.framework, Framework::FullyAsync));
            for (idx, &(gi, m)) in order.iter().enumerate() {
                let (_, lr) = groups[gi][m];
                let decode = lr.saturating_sub(1) as f64 * step_s;
                let tl = RequestTimeline {
                    enqueue_s: 0.0,
                    dispatch_s: 0.0,
                    admit_s: completions[idx] - service[idx],
                    first_token_s: (completions[idx] - decode).max(0.0),
                    finish_s: completions[idx],
                    decode_tokens: lr.saturating_sub(1) as u32,
                    ..Default::default()
                };
                req.observe(&tl, staleness);
            }
        }

        let (wall, idle) = match self.framework {
            Framework::ColocatedSync | Framework::ColocatedContinuous => {
                // sequential phases + reshard both ways
                (t_infer + t_move + t_train + t_update + overhead, t_infer)
            }
            Framework::DecoupledSync => {
                // training waits for the whole batch (Fig. 3a)
                if let Some(tr) = trace {
                    let mut t = t_infer;
                    for (gi, s) in train_each.iter().enumerate() {
                        tr.record_abs("train", &format!("group {gi}"), t, t + s);
                        t += s;
                    }
                }
                (t_move + t_infer + t_train + t_update + overhead, t_infer)
            }
            Framework::PeriodicAsync => {
                // consume groups in completion order while inference runs
                let mut idx: Vec<usize> = (0..groups.len()).collect();
                idx.sort_by(|&a, &b| ready[a].partial_cmp(&ready[b]).unwrap());
                let ready_sorted: Vec<f64> = idx.iter().map(|&i| ready[i]).collect();
                let service_sorted: Vec<f64> = idx.iter().map(|&i| train_each[i]).collect();
                let (done, idle) = sequential_with_ready(0.0, &ready_sorted, &service_sorted);
                if let Some(tr) = trace {
                    for (k, &i) in idx.iter().enumerate() {
                        tr.record_abs("train", &format!("group {i}"), done[k] - service_sorted[k], done[k]);
                    }
                }
                let last = done.last().cloned().unwrap_or(0.0);
                (t_move + last.max(t_infer) + t_update + overhead, idle)
            }
            Framework::FullyAsync => {
                // steady-state pipeline across iterations: the slower stage
                // dominates; no drain barrier, no ready-lag, async weight push.
                (t_infer.max(t_train + t_update) + overhead, (t_infer - t_train).max(0.0) * 0.0)
            }
        };
        IterOutcome { wall, t_infer, t_train, idle, tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(framework: Framework) -> SimSetup {
        SimSetup {
            cluster: ClusterSpec::npu(16),
            model: ModelSpec::qwen(8.0),
            workload: WorkloadSpec::deepscaler(32, 16384),
            eff: EfficiencySpec::ours(),
            framework,
            infer_fraction: 0.8,
            infer_tp: 2,
            spa: false,
            prefix_cache: false,
            template_frac: 0.0,
            cross_engine: false,
            store_shards: 1,
            elastic_warmup_frac: 0.0,
            train_micro_bs: 16,
            micro_launch_s: 0.5,
            iters: 5,
            seed: 7,
        }
    }

    #[test]
    fn async_beats_sync_and_bounded_by_2x() {
        let sync = base(Framework::DecoupledSync).run_tuned();
        let asyn = base(Framework::PeriodicAsync).run_tuned();
        let speedup = asyn.tpspd / sync.tpspd;
        assert!(speedup > 1.2, "async should clearly beat sync, got {speedup:.2}");
        // Eq. 4: the overlap bound (small slack for the removed drain wait).
        assert!(speedup < 2.15, "async speedup cannot exceed ~2x, got {speedup:.2}");
    }

    #[test]
    fn decoupled_async_beats_colocated_wavebatch() {
        let mind = {
            let mut s = base(Framework::ColocatedSync);
            s.eff = EfficiencySpec::mindspeed();
            s.run()
        };
        let ours = base(Framework::PeriodicAsync).run_tuned();
        assert!(
            ours.tpspd > mind.tpspd,
            "periodic async {:.1} should beat colocated wave-batched {:.1}",
            ours.tpspd,
            mind.tpspd
        );
    }

    #[test]
    fn fully_async_at_least_as_fast_as_periodic() {
        let p = base(Framework::PeriodicAsync).run_tuned();
        let f = base(Framework::FullyAsync).run_tuned();
        assert!(f.tpspd >= p.tpspd * 0.95, "{} vs {}", f.tpspd, p.tpspd);
        assert!(f.staleness_mean > 0.0);
        assert_eq!(p.staleness_mean, 0.0);
    }

    #[test]
    fn spa_reduces_trained_tokens_and_time_in_training_bound_regime() {
        let mut no_spa = base(Framework::PeriodicAsync);
        no_spa.workload = WorkloadSpec::gsm8k(32);
        let mut spa = no_spa.clone();
        spa.spa = true;
        let a = no_spa.run_tuned();
        let b = spa.run_tuned();
        // SPA removes (K-1) prompt recomputations per group
        assert!(
            b.trained_tokens < a.trained_tokens * 0.75,
            "spa tokens {} vs standard {}",
            b.trained_tokens,
            a.trained_tokens
        );
        assert!(b.tpspd > a.tpspd, "spa {:.1} should beat no-spa {:.1}", b.tpspd, a.tpspd);
    }

    #[test]
    fn prefix_cache_cuts_infer_time_not_trained_tokens() {
        // Prompt-heavy short-response regime (GSM8K-like): prefill is a real
        // fraction of rollout time, so sharing it across the group bites.
        let mut off = base(Framework::PeriodicAsync);
        off.workload = WorkloadSpec::gsm8k(32);
        let mut on = off.clone();
        on.prefix_cache = true;
        let a = off.run();
        let b = on.run();
        assert!(
            b.t_infer_mean < a.t_infer_mean,
            "prefix cache should shorten inference: {} vs {}",
            b.t_infer_mean,
            a.t_infer_mean
        );
        assert_eq!(
            a.trained_tokens, b.trained_tokens,
            "prefill sharing must not change what gets trained"
        );
        assert!(b.tpspd >= a.tpspd, "cache cannot hurt TPSPD: {} vs {}", b.tpspd, a.tpspd);
        // The saving is bounded by the prefill share of (G-1)/G members.
        assert!(b.t_infer_mean > a.t_infer_mean * 0.2, "discount implausibly large");
    }

    #[test]
    fn partial_prefix_discount_scales_with_matched_fraction() {
        // Prompt-heavy regime; leaders dominate the remaining prefill cost
        // once members 1..G share the cache, so the template fraction's
        // discount must be visible and monotone.
        let mut base = base(Framework::PeriodicAsync);
        base.workload = WorkloadSpec::gsm8k(32);
        base.prefix_cache = true;
        let t_infer = |frac: f64| {
            let mut s = base.clone();
            s.template_frac = frac;
            s.run().t_infer_mean
        };
        let full = t_infer(0.0);
        let half = t_infer(0.5);
        let most = t_infer(0.9);
        assert!(half < full, "warm template must cut leader prefill: {half} vs {full}");
        assert!(most < half, "discount must grow with the matched fraction");
        // Trained tokens are untouched by inference-side reuse.
        let mut a = base.clone();
        a.template_frac = 0.9;
        assert_eq!(base.run().trained_tokens, a.run().trained_tokens);
    }

    #[test]
    fn cross_engine_store_discount_never_hurts_and_is_wired() {
        // Prompt-heavy template workload across several instances: sharing
        // the template fleet-wide can only reduce inference time (fewer cold
        // leaders), never change what is trained.
        let mut per_engine = base(Framework::PeriodicAsync);
        per_engine.workload = WorkloadSpec::gsm8k(32);
        per_engine.prefix_cache = true;
        per_engine.template_frac = 0.6;
        // Fully sharded store: this test isolates the *warmth* benefit. The
        // single-mutex contention cost is covered (and must be strictly
        // positive) in `store_shards_cut_host_serialization_and_nothing_else`.
        per_engine.store_shards = 8;
        let mut cross = per_engine.clone();
        cross.cross_engine = true;
        let a = per_engine.run();
        let b = cross.run();
        assert!(
            b.t_infer_mean <= a.t_infer_mean,
            "cross-engine sharing must not lengthen inference: {} vs {}",
            b.t_infer_mean,
            a.t_infer_mean
        );
        assert_eq!(a.trained_tokens, b.trained_tokens);
        assert!(b.tpspd >= a.tpspd, "cross-engine {} vs per-engine {}", b.tpspd, a.tpspd);
        // The knob is actually wired: with several instances and G=1 (every
        // job is a leader) the per-engine model pays n_instances cold
        // templates per iteration vs one fleet-wide, so inference must be
        // strictly slower without the store.
        assert!(
            per_engine.infer_devices() / per_engine.infer_tp > 1,
            "setup must have >1 instance for the distinction to exist"
        );
        per_engine.workload.group_size = 1;
        let mut cross = per_engine.clone();
        cross.cross_engine = true;
        let a = per_engine.run();
        let b = cross.run();
        assert!(
            b.t_infer_mean < a.t_infer_mean,
            "cross_engine knob had no effect on a leaders-only template workload: {} vs {}",
            b.t_infer_mean,
            a.t_infer_mean
        );
    }

    #[test]
    fn store_shards_cut_host_serialization_and_nothing_else() {
        // Cross-engine sharing on a template workload pays a host-store
        // serialization term per leader; sharding the lock divides it by the
        // lane count and must touch nothing else (trained tokens identical).
        let mut single = base(Framework::PeriodicAsync);
        single.workload = WorkloadSpec::gsm8k(32);
        single.prefix_cache = true;
        single.template_frac = 0.6;
        single.cross_engine = true;
        single.store_shards = 1;
        assert!(
            single.infer_devices() / single.infer_tp > 1,
            "setup must have >1 instance for lock lanes to matter"
        );
        let mut sharded = single.clone();
        sharded.store_shards = 8;
        let a = single.run();
        let b = sharded.run();
        assert!(
            b.t_infer_mean < a.t_infer_mean,
            "sharding must strictly cut the store serialization: {} vs {}",
            b.t_infer_mean,
            a.t_infer_mean
        );
        assert_eq!(a.trained_tokens, b.trained_tokens);
        assert!(b.tpspd >= a.tpspd);
        // Without the store there is nothing to serialize on: the knob is
        // inert (guards against the term leaking into store-less configs).
        let mut no_store = single.clone();
        no_store.cross_engine = false;
        let mut no_store_sharded = no_store.clone();
        no_store_sharded.store_shards = 8;
        assert_eq!(no_store.run().t_infer_mean, no_store_sharded.run().t_infer_mean);
    }

    #[test]
    fn elastic_fleet_bills_deployed_device_seconds_only() {
        // Training-bound regime (micro-bs 1 inflates launch overhead): the
        // consumer is always behind, so serving the warmup half of the run
        // with half the inference fleet barely moves the wall clock while
        // billing strictly fewer device-seconds — the elasticity dividend.
        let mut s = base(Framework::PeriodicAsync);
        s.workload = WorkloadSpec::gsm8k(32);
        s.train_micro_bs = 1;
        let static_run = s.run();
        let mut e = s.clone();
        e.elastic_warmup_frac = 0.5;
        let elastic = e.run();
        assert_eq!(
            static_run.trained_tokens, elastic.trained_tokens,
            "joining engines must not change what is trained"
        );
        assert!(
            elastic.wall_seconds >= static_run.wall_seconds,
            "a smaller warmup fleet cannot be faster: {} vs {}",
            elastic.wall_seconds,
            static_run.wall_seconds
        );
        // Billing deployed devices can only raise TPSPD relative to billing
        // the peak fleet for the same walls...
        let peak_billed = elastic.trained_tokens / (elastic.wall_seconds * 16.0);
        assert!(
            elastic.tpspd >= peak_billed * 0.999,
            "device-second billing must not undercut peak billing: {} vs {peak_billed}",
            elastic.tpspd
        );
        // ...and in the training-bound regime the elastic run beats the
        // static fleet outright: same wall (training dominates), fewer
        // device-seconds.
        assert!(
            elastic.tpspd > static_run.tpspd,
            "elastic {} should beat static {} when training-bound",
            elastic.tpspd,
            static_run.tpspd
        );
        // frac 0 takes the static path, bit-identically.
        let mut z = s.clone();
        z.elastic_warmup_frac = 0.0;
        assert_eq!(z.run().tpspd, static_run.tpspd);
        // Colocated designs have no separate fleet: the knob is inert there.
        let mut c = base(Framework::ColocatedSync);
        c.workload = WorkloadSpec::gsm8k(32);
        let colo = c.run();
        c.elastic_warmup_frac = 0.5;
        assert_eq!(c.run().tpspd, colo.tpspd);
    }

    #[test]
    fn synthesizes_request_metrics_in_driver_schema() {
        let s = base(Framework::PeriodicAsync);
        let mut req = RequestMetrics::default();
        let r = s.run_traced_metrics(None, Some(&mut req));
        assert!(r.wall_seconds > 0.0);
        // first iteration only: every member of every group lands once
        let expected = (s.workload.batch_prompts * s.workload.group_size) as u64;
        assert_eq!(req.completed, expected);
        assert_eq!(req.ttft.count(), expected, "all synthetic timelines carry a first token");
        assert_eq!(req.queue_wait.count(), expected);
        // strictly on-policy design: staleness is identically zero
        assert_eq!(req.staleness.max(), 0.0);
        // the JSON schema matches the driver's RequestMetrics export
        let j = req.to_json();
        for key in ["completed", "ttft_s", "queue_wait_s", "decode_tok_per_s", "staleness"] {
            assert!(j.req(key).is_ok(), "missing {key}");
        }
        // fully-async synthesizes one-iteration-stale consumption
        let mut req = RequestMetrics::default();
        base(Framework::FullyAsync).run_traced_metrics(None, Some(&mut req));
        assert_eq!(req.staleness.max(), 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = base(Framework::PeriodicAsync).run();
        let b = base(Framework::PeriodicAsync).run();
        assert_eq!(a.wall_seconds, b.wall_seconds);
        assert_eq!(a.trained_tokens, b.trained_tokens);
    }

    #[test]
    fn colocated_has_fewer_slots_than_decoupled() {
        let colo = base(Framework::ColocatedContinuous).slots_per_instance();
        let dec = base(Framework::DecoupledSync).slots_per_instance();
        assert!(
            dec > colo,
            "decoupled instances ({dec} slots) should out-batch colocated ({colo})"
        );
    }

    #[test]
    fn scaling_near_linear_total_throughput() {
        // Fig. 6: total tokens/s grows near-linearly 16 -> 32 -> 64 when the
        // batch scales with data-parallel width.
        let tput = |n: usize| {
            let mut s = base(Framework::PeriodicAsync);
            s.cluster = ClusterSpec::npu(n);
            s.workload.batch_prompts = 32 * n / 16;
            let r = s.run_tuned();
            r.tpspd * n as f64
        };
        let t16 = tput(16);
        let t32 = tput(32);
        let t64 = tput(64);
        assert!(t32 / t16 > 1.4 && t32 / t16 < 2.1, "16->32 scaling {}", t32 / t16);
        assert!(t64 / t32 > 1.25 && t64 / t32 < 2.1, "32->64 scaling {}", t64 / t32);
    }
}
