//! Paper experiment configurations (Tables 1–5, Fig. 6) and their reference
//! numbers, wired to the simulator. Each `tableN()` returns rows pairing the
//! paper's reported TPSPD with the simulated value so the bench binaries can
//! print side-by-side comparisons and win-factor checks.

use super::frameworks::{Framework, SimResult, SimSetup};
use super::specs::{ClusterSpec, EfficiencySpec, ModelSpec, WorkloadSpec};

/// One table row: the paper's setting name + reported TPSPD, and ours.
#[derive(Debug, Clone)]
pub struct Row {
    pub setting: String,
    pub paper_tpspd: Option<f64>,
    pub sim: SimResult,
}

/// Render rows paper-vs-sim with win-factors relative to the last row (the
/// paper's tables put "Async (ours)" last). Used by every bench binary.
pub fn render_rows(title: &str, rows: &[Row]) -> String {
    use crate::util::bench::{f3, fx, Table};
    let base = rows.last().expect("non-empty rows");
    let mut t = Table::new(
        title,
        &["Setting", "Paper TPSPD", "Sim TPSPD", "Paper win", "Sim win", "T_inf(s)", "T_train(s)"],
    );
    for r in rows {
        let paper_win = match (base.paper_tpspd, r.paper_tpspd) {
            (Some(a), Some(x)) if x > 0.0 => fx(a / x),
            _ => "-".into(),
        };
        t.row(&[
            r.setting.clone(),
            r.paper_tpspd.map(f3).unwrap_or_else(|| "-".into()),
            f3(r.sim.tpspd),
            paper_win,
            fx(base.sim.tpspd / r.sim.tpspd),
            format!("{:.0}", r.sim.t_infer_mean),
            format!("{:.0}", r.sim.t_train_mean),
        ]);
    }
    t.note("'win' = last row (Async ours) over this row; shape, not absolute TPSPD, is the target");
    t.render()
}

fn setup(
    framework: Framework,
    cluster: ClusterSpec,
    model: ModelSpec,
    workload: WorkloadSpec,
    eff: EfficiencySpec,
    infer_tp: usize,
    spa: bool,
    micro_bs: usize,
    iters: usize,
) -> SimSetup {
    SimSetup {
        cluster,
        model,
        workload,
        eff,
        framework,
        infer_fraction: 0.8, // paper's typical training:rollout = 1:4
        infer_tp,
        spa,
        // Off in the paper tables (the testbeds predate prefix caching);
        // `prefix_cache_ablation` quantifies the engine-side saving.
        prefix_cache: false,
        template_frac: 0.0,
        cross_engine: false,
        store_shards: 1,
        elastic_warmup_frac: 0.0,
        train_micro_bs: micro_bs,
        micro_launch_s: 0.5, // NPU-stack launch cost; table4 overrides for GPU
        iters,
        seed: 0xEA5,
    }
}

/// Engine prefix-cache ablation (no paper analog): periodic async on the
/// prompt-heavy GSM8K workload, shared-prefix KV cache off / full-prompt
/// hits / chunked partial-prefix reuse. With group-affine dispatch, members
/// 1..G of every group skip prefill (inference time drops by ~the (G-1)/G
/// prefill share); chunked admission additionally lets group *leaders*
/// resume from the warm few-shot template (60% of a GSM8K-style prompt
/// here), so the remaining leader prefill shrinks with the matched-prefix
/// fraction. The fourth row adds cross-engine KV sharing (the host-side
/// shared segment store + affinity routing): the template is cold once
/// fleet-wide instead of once per inference instance. The fifth row shards
/// the host store's lock (`engine.store_shards`), removing the fleet-wide
/// serialization every leader's fetch+publish round-trip pays on the single
/// mutex. The sixth row runs the *elastic fleet* (the driver's
/// `spawn_engine`/`drain_engine` path, modeled): the first half of the run
/// is served with half the inference instances, the rest join at the
/// boundary weight-synced, and TPSPD bills device-seconds actually deployed
/// — elasticity earns its keep when the small fleet suffices. Trained
/// tokens are untouched throughout.
pub fn prefix_cache_ablation(iters: usize) -> Vec<Row> {
    let cluster = ClusterSpec::npu(16);
    let model = ModelSpec::qwen(7.0);
    let w = WorkloadSpec::gsm8k(32);
    let mk = |prefix_cache: bool,
              template_frac: f64,
              cross_engine: bool,
              shards: usize,
              elastic: f64,
              label: &str| {
        let mut s = setup(
            Framework::PeriodicAsync,
            cluster,
            model,
            w.clone(),
            EfficiencySpec::ours(),
            2,
            true,
            16,
            iters,
        );
        s.prefix_cache = prefix_cache;
        s.template_frac = template_frac;
        s.cross_engine = cross_engine;
        s.store_shards = shards;
        s.elastic_warmup_frac = elastic;
        Row { setting: label.into(), paper_tpspd: None, sim: s.run_tuned() }
    };
    vec![
        mk(false, 0.0, false, 1, 0.0, "Async ours, full prefill"),
        mk(true, 0.0, false, 1, 0.0, "Async ours, prefix-cached prefill"),
        mk(true, 0.6, false, 1, 0.0, "Async ours, chunked partial-prefix prefill"),
        mk(true, 0.6, true, 1, 0.0, "Async ours, + cross-engine shared store"),
        mk(true, 0.6, true, 8, 0.0, "Async ours, + sharded store (S=8)"),
        mk(true, 0.6, true, 8, 0.5, "Async ours, + elastic fleet (half joins mid-run)"),
    ]
}

/// Table 1: Qwen3-8B on DeepScaleR, 16 NPUs, batch 32, G=32, 16K context.
pub fn table1(iters: usize) -> Vec<Row> {
    let cluster = ClusterSpec::npu(16);
    let model = ModelSpec::qwen(8.0);
    let w = WorkloadSpec::deepscaler(32, 16384);
    // Rollout TP per paper Table 9 (Exp 1): MindSpeed/ours TP2, VERL TP8.
    let async_row =
        setup(Framework::PeriodicAsync, cluster, model, w.clone(), EfficiencySpec::ours(), 2, false, 1, iters)
            .run_tuned();
    // paper deploys sync at the same training:rollout ratio as async
    let mut sync_setup =
        setup(Framework::DecoupledSync, cluster, model, w.clone(), EfficiencySpec::ours(), 2, false, 1, iters);
    sync_setup.infer_fraction = async_row.infer_fraction;
    vec![
        Row {
            setting: Framework::ColocatedSync.label().into(),
            paper_tpspd: Some(61.641),
            sim: setup(Framework::ColocatedSync, cluster, model, w.clone(), EfficiencySpec::mindspeed(), 2, false, 1, iters).run(),
        },
        Row {
            setting: Framework::ColocatedContinuous.label().into(),
            paper_tpspd: Some(155.521),
            sim: setup(Framework::ColocatedContinuous, cluster, model, w.clone(), EfficiencySpec::verl(), 8, false, 16, iters).run(),
        },
        Row { setting: Framework::DecoupledSync.label().into(), paper_tpspd: Some(99.966), sim: sync_setup.run() },
        Row { setting: Framework::PeriodicAsync.label().into(), paper_tpspd: Some(192.259), sim: async_row },
    ]
}

/// Table 2: DeepSeek-R1-Distill-Qwen-32B on DeepScaleR.
/// Group 1: GBS 32 @ 16K — MindSpeed on 64 NPUs vs ours on 48.
/// Group 2: GBS 64 @ 8K on 64 NPUs (VERL OOMs at 16K).
pub fn table2(iters: usize) -> (Vec<Row>, Vec<Row>) {
    let model = ModelSpec::qwen(32.0);
    let w16 = WorkloadSpec::deepscaler(32, 16384);
    let g1 = vec![
        Row {
            setting: "MindSpeed-RL (64 NPU)".into(),
            paper_tpspd: Some(6.627),
            sim: setup(
                Framework::ColocatedSync,
                ClusterSpec::npu(64),
                model,
                w16.clone(),
                EfficiencySpec::mindspeed(),
                4,
                false,
                1,
                iters,
            )
            .run(),
        },
        Row {
            setting: "Sync ours (48 NPU)".into(),
            paper_tpspd: Some(26.219),
            sim: setup(
                Framework::DecoupledSync,
                ClusterSpec::npu(48),
                model,
                w16.clone(),
                EfficiencySpec::ours(),
                4,
                false,
                1,
                iters,
            )
            .run_tuned(),
        },
        Row {
            setting: "Async ours (48 NPU)".into(),
            paper_tpspd: Some(33.449),
            sim: setup(
                Framework::PeriodicAsync,
                ClusterSpec::npu(48),
                model,
                w16,
                EfficiencySpec::ours(),
                4,
                false,
                1,
                iters,
            )
            .run_tuned(),
        },
    ];
    let w8 = WorkloadSpec::deepscaler(64, 8192);
    let g2 = vec![
        Row {
            setting: "VERL (64 NPU, 8K)".into(),
            paper_tpspd: Some(44.016),
            sim: setup(
                Framework::ColocatedContinuous,
                ClusterSpec::npu(64),
                model,
                w8.clone(),
                EfficiencySpec::verl(),
                8,
                false,
                64,
                iters,
            )
            .run(),
        },
        Row {
            setting: "Sync ours (64 NPU, 8K)".into(),
            paper_tpspd: Some(46.519),
            sim: setup(
                Framework::DecoupledSync,
                ClusterSpec::npu(64),
                model,
                w8.clone(),
                EfficiencySpec::ours(),
                4,
                false,
                1,
                iters,
            )
            .run_tuned(),
        },
        Row {
            setting: "Async ours (64 NPU, 8K)".into(),
            paper_tpspd: Some(77.342),
            sim: setup(
                Framework::PeriodicAsync,
                ClusterSpec::npu(64),
                model,
                w8,
                EfficiencySpec::ours(),
                4,
                false,
                1,
                iters,
            )
            .run_tuned(),
        },
    ];
    (g1, g2)
}

/// Table 3: Qwen2.5-7B on GSM8K, 16 NPUs, 1K context — the
/// training-dominated regime where Shared-Prompt Attention bites.
pub fn table3(iters: usize) -> Vec<Row> {
    let cluster = ClusterSpec::npu(16);
    let model = ModelSpec::qwen(7.0);
    let w = WorkloadSpec::gsm8k(32);
    let mk = |fw: Framework, eff: EfficiencySpec, spa: bool, micro: usize, label: &str, paper: f64| Row {
        setting: label.into(),
        paper_tpspd: Some(paper),
        sim: setup(fw, cluster, model, w.clone(), eff, 2, spa, micro, iters).run_tuned(),
    };
    let mk2 = |fw: Framework, eff: EfficiencySpec, tp: usize, spa: bool, micro: usize, label: &str, paper: f64| Row {
        setting: label.into(),
        paper_tpspd: Some(paper),
        sim: setup(fw, cluster, model, w.clone(), eff, tp, spa, micro, iters).run(),
    };
    vec![
        mk2(Framework::ColocatedSync, EfficiencySpec::mindspeed(), 2, false, 16, "MindSpeed-RL", 199.142),
        mk2(Framework::ColocatedContinuous, EfficiencySpec::verl(), 4, false, 16, "VERL", 167.297),
        mk(Framework::PeriodicAsync, EfficiencySpec::ours(), false, 1, "Async ours, w/o SPA", 52.400),
        mk(Framework::DecoupledSync, EfficiencySpec::ours(), true, 16, "Sync ours, w/ SPA", 218.396),
        mk(Framework::PeriodicAsync, EfficiencySpec::ours(), true, 16, "Async ours, w/ SPA", 437.530),
    ]
}

/// Table 4: Qwen2.5-1.5B on GSM8K, 8×A100-40G, data-parallel only.
pub fn table4(iters: usize) -> Vec<Row> {
    let cluster = ClusterSpec::gpu(8);
    let model = ModelSpec::qwen(1.5);
    let w = WorkloadSpec::gsm8k(32);
    let mk = |fw: Framework, eff: EfficiencySpec, frac: f64, label: &str, paper: f64| {
        let mut s = setup(fw, cluster, model, w.clone(), eff, 1, false, 4, iters);
        s.micro_launch_s = 0.1; // GPU launches are much cheaper than NPU
        s.infer_fraction = frac;
        Row { setting: label.into(), paper_tpspd: Some(paper), sim: s.run_tuned() }
    };
    vec![
        mk(Framework::ColocatedContinuous, EfficiencySpec::verl(), 0.5, "VERL", 488.919),
        mk(Framework::FullyAsync, EfficiencySpec::areal(), 0.5, "AReaL", 1067.582),
        // paper: ours uses training:rollout 3:1 on GPU... ratio 1:1 for sync
        mk(Framework::DecoupledSync, EfficiencySpec::ours(), 0.5, "Sync ours", 628.503),
        mk(Framework::PeriodicAsync, EfficiencySpec::ours(), 0.25, "Async ours", 1510.418),
    ]
}

/// Table 5 / Fig. 6: Qwen3-8B scalability over 16/32/64 NPUs.
pub fn table5(iters: usize) -> Vec<(usize, Option<f64>, SimResult)> {
    let model = ModelSpec::qwen(8.0);
    [(16usize, Some(188.162)), (32, Some(171.824)), (64, Some(163.208))]
        .iter()
        .map(|&(n, paper)| {
            // batch scales with data-parallel width, per the paper's setup
            let w = WorkloadSpec::deepscaler(32 * n / 16, 16384);
            let s = setup(
                Framework::PeriodicAsync,
                ClusterSpec::npu(n),
                model,
                w,
                EfficiencySpec::ours(),
                2,
                false,
                1,
                iters,
            );
            (n, paper, s.run_tuned())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's qualitative claims that must survive simulation.
    #[test]
    fn table1_ordering_holds() {
        let rows = table1(3);
        let t = |i: usize| rows[i].sim.tpspd;
        // async >= verl > sync(ours) > mindspeed — the paper's ordering
        // (async vs VERL is the tightest margin in the paper too: 1.24x)
        assert!(t(3) > t(1) * 0.95, "async {} should match/beat VERL {}", t(3), t(1));
        assert!(t(1) > t(2), "VERL {} should beat sync ours {} at 16K", t(1), t(2));
        assert!(t(2) > t(0), "sync ours {} should beat MindSpeed {}", t(2), t(0));
        let async_vs_sync = t(3) / t(2);
        assert!(
            (1.3..=2.1).contains(&async_vs_sync),
            "async/sync {async_vs_sync:.2} should approach the 2x bound"
        );
    }

    #[test]
    fn table2_resource_economy() {
        let (g1, _) = table2(2);
        // ours on 48 NPUs beats MindSpeed on 64 by a large factor
        let speedup = g1[2].sim.tpspd / g1[0].sim.tpspd;
        assert!(speedup > 2.5, "32B async-vs-MindSpeed speedup {speedup:.2} too small");
    }

    #[test]
    fn table3_spa_ablation_shape() {
        let rows = table3(3);
        let by = |label: &str| {
            rows.iter().find(|r| r.setting.contains(label)).unwrap().sim.tpspd
        };
        // SPA alone (sync) already competitive with baselines
        assert!(by("Sync ours, w/ SPA") > by("VERL"));
        // async+SPA is the fastest and approaches 2x sync+SPA
        let ratio = by("Async ours, w/ SPA") / by("Sync ours, w/ SPA");
        assert!((1.2..=2.1).contains(&ratio), "async/sync w/ SPA = {ratio:.2}");
        // w/o SPA at micro-bs 1 collapses (launch-overhead bound)
        assert!(by("Async ours, w/o SPA") < by("MindSpeed-RL"));
        // large SPA win, in the spirit of the paper's 8x
        let spa_win = by("Async ours, w/ SPA") / by("Async ours, w/o SPA");
        assert!(spa_win > 3.0, "SPA win {spa_win:.2} too small");
    }

    #[test]
    fn prefix_cache_ablation_never_hurts() {
        let rows = prefix_cache_ablation(2);
        assert_eq!(rows.len(), 6);
        let (off, on, chunked, cross, sharded) =
            (&rows[0].sim, &rows[1].sim, &rows[2].sim, &rows[3].sim, &rows[4].sim);
        // Tuned independently: at any fixed ratio cache-on dominates
        // cache-off, chunked partial-prefix reuse dominates full-prompt
        // hits, and fleet-wide template sharing dominates per-engine warmth
        // (leaders only get cheaper at each step), so each tuned optimum can
        // only be at least as good as the previous row's. (t_infer itself
        // may differ — the tuner is free to shift freed devices to
        // training.)
        assert!(on.tpspd >= off.tpspd, "cache on {} vs off {}", on.tpspd, off.tpspd);
        assert!(
            chunked.tpspd >= on.tpspd,
            "chunked {} vs full-prompt hits {}",
            chunked.tpspd,
            on.tpspd
        );
        // The single-mutex store (row 4) trades fleet-wide template warmth
        // against lock serialization on every leader round-trip — it may
        // land marginally on either side of row 3. Sharding removes the
        // serialization while keeping the warmth, so the S=8 row must
        // dominate both the per-engine row and the single-mutex row.
        assert!(
            sharded.tpspd >= chunked.tpspd,
            "sharded store {} vs per-engine {}",
            sharded.tpspd,
            chunked.tpspd
        );
        assert!(
            sharded.tpspd >= cross.tpspd,
            "sharding the store lock cannot hurt: {} vs {}",
            sharded.tpspd,
            cross.tpspd
        );
        // Elastic fleet: identical training outcome (joins are on-policy and
        // the workload stream is shared), billed by deployed device-seconds.
        let elastic = &rows[5].sim;
        assert_eq!(
            elastic.trained_tokens, sharded.trained_tokens,
            "an elastic fleet must not change what is trained"
        );
        assert!(elastic.tpspd > 0.0);
    }

    #[test]
    fn table4_gpu_ordering() {
        let rows = table4(3);
        let t = |i: usize| rows[i].sim.tpspd;
        assert!(t(3) > t(1), "async ours should beat AReaL-like");
        assert!(t(1) > t(0), "AReaL-like should beat VERL-like");
        assert!(t(3) > t(2), "async should beat sync");
        assert!(t(1) > t(2) * 0.95, "AReaL-like should be at least on par with sync ours");
    }

    #[test]
    fn table5_tpspd_decreases_moderately_with_scale() {
        let rows = table5(2);
        assert!(rows[0].2.tpspd > rows[1].2.tpspd);
        assert!(rows[1].2.tpspd > rows[2].2.tpspd);
        // but total throughput still scales near-linearly (Fig. 6)
        let total16 = rows[0].2.tpspd * 16.0;
        let total64 = rows[2].2.tpspd * 64.0;
        // near-linear in the paper (3.5x over 4x devices); inter-node comm
        // bites harder in our model — still clearly super-2x
        assert!(total64 / total16 > 2.2, "total throughput should scale >2.2x over 4x devices, got {}", total64 / total16);
    }
}
