//! Training engine: tri-model GRPO trainer (micro-batch accumulation +
//! AdamW), and checkpointing.

pub mod checkpoint;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use trainer::{IterStats, Trainer};
