//! Checkpoint (de)serialisation — a small self-describing binary format
//! (no serde/bincode in the offline environment).
//!
//! Layout (little-endian):
//! ```text
//! magic "PARL" | u32 version | u64 step | u64 policy_version |
//! u32 n_sections | sections...
//! section: u32 name_len | name bytes | u32 n_tensors | tensors...
//! tensor:  u32 name_len | name | u32 ndims | u64 dims... | f32 data...
//! ```

use crate::runtime::{HostParams, Tensor};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PARL";
const VERSION: u32 = 1;

/// Full trainer state: policy weights + Adam moments + counters.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub step: u64,
    pub policy_version: u64,
    pub policy: Vec<Tensor>,
    pub adam_m: Vec<Tensor>,
    pub adam_v: Vec<Tensor>,
}

impl Checkpoint {
    pub fn from_params(policy: &HostParams, m: &[Tensor], v: &[Tensor], step: u64) -> Checkpoint {
        Checkpoint {
            step,
            policy_version: policy.version,
            policy: policy.tensors.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&self.policy_version.to_le_bytes())?;
        w.write_all(&3u32.to_le_bytes())?;
        for (name, tensors) in
            [("policy", &self.policy), ("adam_m", &self.adam_m), ("adam_v", &self.adam_v)]
        {
            write_section(&mut w, name, tensors)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a pa-rl checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut r)?;
        let policy_version = read_u64(&mut r)?;
        let n_sections = read_u32(&mut r)?;
        let mut policy = Vec::new();
        let mut adam_m = Vec::new();
        let mut adam_v = Vec::new();
        for _ in 0..n_sections {
            let (name, tensors) = read_section(&mut r)?;
            match name.as_str() {
                "policy" => policy = tensors,
                "adam_m" => adam_m = tensors,
                "adam_v" => adam_v = tensors,
                other => bail!("unknown checkpoint section '{other}'"),
            }
        }
        if policy.is_empty() {
            bail!("checkpoint has no policy section");
        }
        Ok(Checkpoint { step, policy_version, policy, adam_m, adam_v })
    }

    pub fn to_host_params(&self) -> HostParams {
        HostParams { tensors: self.policy.clone(), version: self.policy_version }
    }
}

fn write_section<W: Write>(w: &mut W, name: &str, tensors: &[Tensor]) -> Result<()> {
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (i, t) in tensors.iter().enumerate() {
        let tname = format!("t{i}");
        w.write_all(&(tname.len() as u32).to_le_bytes())?;
        w.write_all(tname.as_bytes())?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        let data = t.as_f32().context("checkpoint tensors must be f32")?;
        for &x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_section<R: Read>(r: &mut R) -> Result<(String, Vec<Tensor>)> {
    let name = read_string(r)?;
    let n = read_u32(r)? as usize;
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let _tname = read_string(r)?;
        let ndims = read_u32(r)? as usize;
        if ndims > 16 {
            bail!("implausible tensor rank {ndims}");
        }
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(read_u64(r)? as usize);
        }
        let count: usize = shape.iter().product();
        let mut bytes = vec![0u8; count * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(Tensor::f32(data, &shape));
    }
    Ok((name, tensors))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_string<R: Read>(r: &mut R) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 1 << 20 {
        bail!("implausible string length {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("checkpoint string not utf-8")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Checkpoint {
        Checkpoint {
            step: 42,
            policy_version: 7,
            policy: vec![
                Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]),
                Tensor::f32(vec![5.0], &[1]),
            ],
            adam_m: vec![Tensor::zeros_f32(&[2, 2]), Tensor::zeros_f32(&[1])],
            adam_v: vec![Tensor::zeros_f32(&[2, 2]), Tensor::zeros_f32(&[1])],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join("pa_rl_ckpt_test").join("a.ckpt");
        let ck = demo();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.policy_version, 7);
        assert_eq!(back.policy, ck.policy);
        assert_eq!(back.adam_v.len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("pa_rl_ckpt_test").join("bad.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn host_params_conversion() {
        let hp = demo().to_host_params();
        assert_eq!(hp.version, 7);
        assert_eq!(hp.tensors.len(), 2);
    }
}
