//! The training engine: tri-model GRPO steps with micro-batch gradient
//! accumulation and a single AdamW update per iteration (Algorithm 1 lines
//! 6–11).
//!
//! Consumes [`Group`]s in whatever order the queue delivers them — Remark 1
//! (gradient permutation invariance) guarantees the accumulated gradient is
//! order-independent, which the proptests verify numerically. The old-policy
//! snapshot is moved *before* the Adam update is applied (Algorithm 1's line
//! 10/11 ordering: "the old policy always retains the weights from the
//! previous iteration", i.e. a one-step-delayed copy of the policy).

use crate::config::Config;
use crate::grpo::{build_spa, build_standard, Group, Sample, TrainBatch};
use crate::runtime::{Arg, DeviceParams, HostParams, Runtime, Tensor};
use anyhow::{bail, Context, Result};

/// Metrics aggregated over one iteration's micro-steps.
#[derive(Debug, Clone, Default)]
pub struct IterStats {
    pub micro_steps: usize,
    pub samples: usize,
    pub loss_tokens: usize,
    pub input_tokens: usize,
    pub loss: f64,
    pub kl: f64,
    pub clip_frac: f64,
    pub entropy: f64,
    pub ratio_mean: f64,
    pub grad_norm: f64,
    pub train_seconds: f64,
    pub update_seconds: f64,
}

impl IterStats {
    fn add_micro(&mut self, metrics: &[f32], batch: &TrainBatch, seconds: f64) {
        self.micro_steps += 1;
        self.samples += batch.n_samples;
        self.loss_tokens += batch.n_loss_tokens;
        self.input_tokens += batch.n_input_tokens;
        self.loss += metrics[0] as f64;
        self.kl += metrics[1] as f64;
        self.clip_frac += metrics[2] as f64;
        self.entropy += metrics[3] as f64;
        self.ratio_mean += metrics[4] as f64;
        self.train_seconds += seconds;
    }

    /// Mean-per-micro view of the accumulated metrics.
    pub fn finalize(&mut self) {
        let m = self.micro_steps.max(1) as f64;
        self.loss /= m;
        self.kl /= m;
        self.clip_frac /= m;
        self.entropy /= m;
        self.ratio_mean /= m;
    }
}

/// The trainer. Owns its PJRT runtime and the three parameter sets of the
/// unified tri-model (policy / old-policy / reference).
pub struct Trainer {
    cfg: Config,
    rt: Runtime,
    policy: HostParams,
    old: HostParams,
    reference: HostParams,
    adam_m: Vec<Tensor>,
    adam_v: Vec<Tensor>,
    step: u64,
    // per-iteration state
    dev_policy: Option<DeviceParams>,
    dev_old: Option<DeviceParams>,
    dev_ref: Option<DeviceParams>,
    accum: Option<Vec<Vec<f32>>>,
    micro_count: usize,
    in_iteration: bool,
}

impl Trainer {
    /// Initialise from seed via the `init` artifact. Old and reference start
    /// as copies of the policy (the reference keeps the original weights for
    /// the whole run, per the paper's tri-model).
    pub fn new(cfg: Config, rt: Runtime, seed: i32) -> Result<Trainer> {
        let policy = rt.init_params(seed)?;
        let old = HostParams { tensors: policy.tensors.clone(), version: 0 };
        let reference = HostParams { tensors: policy.tensors.clone(), version: 0 };
        let adam_m: Vec<Tensor> =
            policy.tensors.iter().map(|t| Tensor::zeros_f32(&t.shape)).collect();
        let adam_v = adam_m.clone();
        Ok(Trainer {
            cfg,
            rt,
            policy,
            old,
            reference,
            adam_m,
            adam_v,
            step: 0,
            dev_policy: None,
            dev_old: None,
            dev_ref: None,
            accum: None,
            micro_count: 0,
            in_iteration: false,
        })
    }

    /// Resume from pre-trained weights (e.g. the SFT warmup checkpoint).
    pub fn with_params(cfg: Config, rt: Runtime, policy: HostParams) -> Result<Trainer> {
        policy.validate(&rt)?;
        let old = HostParams { tensors: policy.tensors.clone(), version: policy.version };
        let reference = HostParams { tensors: policy.tensors.clone(), version: policy.version };
        let adam_m: Vec<Tensor> =
            policy.tensors.iter().map(|t| Tensor::zeros_f32(&t.shape)).collect();
        let adam_v = adam_m.clone();
        Ok(Trainer {
            cfg,
            rt,
            policy,
            old,
            reference,
            adam_m,
            adam_v,
            step: 0,
            dev_policy: None,
            dev_old: None,
            dev_ref: None,
            accum: None,
            micro_count: 0,
            in_iteration: false,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Current policy snapshot (what gets published to engines at iteration
    /// boundaries).
    pub fn policy(&self) -> &HostParams {
        &self.policy
    }

    pub fn policy_version(&self) -> u64 {
        self.policy.version
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    pub fn adam_state(&self) -> (&[Tensor], &[Tensor]) {
        (&self.adam_m, &self.adam_v)
    }

    /// Replace the policy (and reset old/reference/optimizer state) with
    /// externally-provided weights, e.g. after SFT warmup.
    pub fn set_policy_params(&mut self, policy: HostParams) -> Result<()> {
        if self.in_iteration {
            bail!("set_policy_params during an open iteration");
        }
        policy.validate(&self.rt)?;
        self.old = HostParams { tensors: policy.tensors.clone(), version: policy.version };
        self.reference = HostParams { tensors: policy.tensors.clone(), version: policy.version };
        self.adam_m = policy.tensors.iter().map(|t| Tensor::zeros_f32(&t.shape)).collect();
        self.adam_v = self.adam_m.clone();
        self.policy = policy;
        self.step = 0;
        // Invalidate cached device buffers (weights changed wholesale).
        self.dev_policy = None;
        self.dev_old = None;
        self.dev_ref = None;
        Ok(())
    }

    /// Upload the tri-model parameter sets and reset the gradient
    /// accumulator (Algorithm 1 line 6).
    ///
    /// Upload traffic is minimised (§Perf): the reference set never changes
    /// after construction so its device buffers are uploaded once and
    /// cached; the old-policy set is exactly the previous iteration's
    /// policy, whose device buffers are recycled at `end_iteration` — so
    /// steady-state iterations upload only ONE param set instead of three.
    pub fn begin_iteration(&mut self) -> Result<()> {
        if self.in_iteration {
            bail!("begin_iteration called twice");
        }
        self.dev_policy = Some(self.policy.upload(&self.rt)?);
        if self.dev_old.as_ref().map(|d| d.version) != Some(self.old.version) {
            self.dev_old = Some(self.old.upload(&self.rt)?);
        }
        if self.dev_ref.is_none() {
            self.dev_ref = Some(self.reference.upload(&self.rt)?);
        }
        self.accum = Some(
            self.policy
                .tensors
                .iter()
                .map(|t| vec![0.0f32; t.len()])
                .collect(),
        );
        self.micro_count = 0;
        self.in_iteration = true;
        Ok(())
    }

    /// Train on one group (Algorithm 1 line 8). With SPA the group becomes a
    /// single packed micro-batch; otherwise its samples are chunked into
    /// standard micro-batches of `micro_bs` rows. Falls back to the standard
    /// layout when a group doesn't fit the SPA pack (documented in DESIGN.md).
    pub fn train_group(&mut self, group: &Group, spa: bool, stats: &mut IterStats) -> Result<()> {
        if !self.in_iteration {
            bail!("train_group outside an iteration");
        }
        let samples = Sample::from_group(group);
        if spa {
            if let Some(batch) = build_spa(&samples, self.cfg.train.spa.pack_len) {
                return self.train_micro(&batch, true, group.prompt.tokens.len(), stats);
            }
        }
        for chunk in samples.chunks(self.cfg.train.micro_bs) {
            let batch = build_standard(chunk, self.cfg.train.micro_bs, self.cfg.train.seq_len);
            self.train_micro(&batch, false, 0, stats)?;
        }
        Ok(())
    }

    /// One compiled tri-model micro-step; grads accumulate on the host
    /// (enables the Remark-1 permutation-invariance property tests).
    pub fn train_micro(
        &mut self,
        batch: &TrainBatch,
        spa: bool,
        prompt_len: usize,
        stats: &mut IterStats,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let artifact = if spa { "train_step_spa" } else { "train_step" };
        let shape = [batch.rows, batch.seq];
        let tokens = Tensor::i32(batch.tokens.clone(), &shape);
        let labels = Tensor::i32(batch.labels.clone(), &shape);
        let pos = Tensor::i32(batch.pos.clone(), &shape);
        let seg = Tensor::i32(batch.seg.clone(), &shape);
        let adv = Tensor::f32(batch.adv.clone(), &shape);
        let weight = Tensor::f32(batch.weight.clone(), &shape);
        let plen = Tensor::scalar_i32(prompt_len as i32);

        let dev_policy = self.dev_policy.as_ref().context("no iteration open")?;
        let dev_old = self.dev_old.as_ref().unwrap();
        let dev_ref = self.dev_ref.as_ref().unwrap();
        let mut args: Vec<Arg> = Vec::with_capacity(3 * dev_policy.bufs.len() + 7);
        args.extend(dev_policy.bufs.iter().map(Arg::Buf));
        args.extend(dev_old.bufs.iter().map(Arg::Buf));
        args.extend(dev_ref.bufs.iter().map(Arg::Buf));
        for t in [&tokens, &labels, &pos, &seg, &adv, &weight, &plen] {
            args.push(Arg::Host(t));
        }
        let out = self.rt.run(artifact, &args)?;
        let n = self.policy.tensors.len();
        let accum = self.accum.as_mut().unwrap();
        for (acc, g) in accum.iter_mut().zip(&out[..n]) {
            let gv = g.as_f32()?;
            for (a, &x) in acc.iter_mut().zip(gv) {
                *a += x;
            }
        }
        let metrics: Vec<f32> = out[n..].iter().map(|t| t.scalar().unwrap_or(0.0)).collect();
        self.micro_count += 1;
        stats.add_micro(&metrics, batch, t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Apply the accumulated update (Algorithm 1 lines 10–11):
    /// old ← policy (pre-update), then policy ← AdamW(policy, mean grads).
    /// Returns the global gradient norm.
    pub fn end_iteration(&mut self, stats: &mut IterStats) -> Result<f64> {
        if !self.in_iteration {
            bail!("end_iteration without begin_iteration");
        }
        let t0 = std::time::Instant::now();
        let mut accum = self.accum.take().context("no accumulator")?;
        let m = self.micro_count.max(1) as f32;
        for g in accum.iter_mut() {
            for x in g.iter_mut() {
                *x /= m;
            }
        }

        // Line 10: move current policy weights to old policy BEFORE updating.
        self.old = HostParams {
            tensors: self.policy.tensors.clone(),
            version: self.policy.version,
        };

        // Line 11: apply the accumulated gradient.
        let grads: Vec<Tensor> = accum
            .into_iter()
            .zip(&self.policy.tensors)
            .map(|(g, t)| Tensor::f32(g, &t.shape))
            .collect();
        let step_t = Tensor::scalar_i32(self.step as i32);
        let mut args: Vec<Arg> = Vec::new();
        args.extend(self.policy.tensors.iter().map(Arg::Host));
        args.extend(grads.iter().map(Arg::Host));
        args.extend(self.adam_m.iter().map(Arg::Host));
        args.extend(self.adam_v.iter().map(Arg::Host));
        args.push(Arg::Host(&step_t));
        let mut out = self.rt.run("adam_update", &args)?;
        let n = self.policy.tensors.len();
        let grad_norm = out.pop().context("adam outputs")?.scalar()? as f64;
        let vs: Vec<Tensor> = out.split_off(2 * n);
        let ms: Vec<Tensor> = out.split_off(n);
        self.policy = HostParams { tensors: out, version: self.policy.version + 1 };
        self.adam_m = ms;
        self.adam_v = vs;
        self.step += 1;
        // Recycle the pre-update policy's device buffers as the next
        // iteration's old-policy set (they are byte-identical to self.old).
        self.dev_old = self.dev_policy.take().map(|mut d| {
            d.version = self.old.version;
            d
        });
        self.in_iteration = false;
        stats.grad_norm = grad_norm;
        stats.update_seconds = t0.elapsed().as_secs_f64();
        stats.finalize();
        Ok(grad_norm)
    }

    /// Number of micro-steps accumulated so far this iteration.
    pub fn micro_count(&self) -> usize {
        self.micro_count
    }

    /// Supervised warmup micro-step (SFT on target responses).
    pub fn sft_micro(&mut self, batch: &TrainBatch) -> Result<f32> {
        if !self.in_iteration {
            bail!("sft_micro outside an iteration");
        }
        let shape = [batch.rows, batch.seq];
        let tokens = Tensor::i32(batch.tokens.clone(), &shape);
        let labels = Tensor::i32(batch.labels.clone(), &shape);
        let pos = Tensor::i32(batch.pos.clone(), &shape);
        let seg = Tensor::i32(batch.seg.clone(), &shape);
        let weight = Tensor::f32(batch.weight.clone(), &shape);
        let dev_policy = self.dev_policy.as_ref().context("no iteration open")?;
        let mut args: Vec<Arg> = Vec::new();
        args.extend(dev_policy.bufs.iter().map(Arg::Buf));
        for t in [&tokens, &labels, &pos, &seg, &weight] {
            args.push(Arg::Host(t));
        }
        let out = self.rt.run("sft_step", &args)?;
        let n = self.policy.tensors.len();
        let accum = self.accum.as_mut().unwrap();
        for (acc, g) in accum.iter_mut().zip(&out[..n]) {
            let gv = g.as_f32()?;
            for (a, &x) in acc.iter_mut().zip(gv) {
                *a += x;
            }
        }
        self.micro_count += 1;
        out[n].scalar().map_err(Into::into)
    }
}
