//! The cooperative schedule-exploring scheduler behind `check::model`.
//!
//! Only compiled under the `pa_modelcheck` feature. One real OS thread per
//! modeled thread, but exactly one runs at a time: every shim operation calls
//! [`Execution::sched_op`], which parks the caller on a shared condvar until
//! the scheduler picks it. The explorer ([`Checker::check`]) replays prefixes
//! of earlier executions and diverges at the deepest frame with an untried,
//! non-slept, preemption-budget-feasible choice — a bounded DFS over the
//! schedule tree with Godefroid-style sleep sets.
//!
//! Failure classes (all carry a replayable schedule string — the
//! comma-joined list of chosen thread ids, feed it to [`replay`]):
//!
//! * **Deadlock** — every live thread blocked on a disabled op and no timed
//!   receive to fire.
//! * **LockOrderInversion** — acquiring mutex `B` while holding `A` after
//!   some earlier point in the *same execution* acquired `A` while holding
//!   `B` (a cycle in the accumulated lock-order graph): a latent deadlock
//!   even when this particular interleaving got lucky.
//! * **Assertion** — a panic in any controlled thread (assert!, unwrap, ...),
//!   or exceeding [`Checker::max_steps`] (livelock guard), or a replay
//!   diverging from its recorded schedule.

use std::collections::{HashMap, HashSet};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, Once};

// ---------------------------------------------------------------------------
// Public result types
// ---------------------------------------------------------------------------

/// What kind of concurrency bug a model run uncovered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// All live threads blocked with no timed wait left to fire.
    Deadlock,
    /// Cycle in the execution's lock-acquisition-order graph.
    LockOrderInversion,
    /// Panic in a controlled thread, step-budget blowout, or replay
    /// divergence.
    Assertion,
}

/// A concrete failing execution, with enough to reproduce it.
#[derive(Clone, Debug)]
pub struct Failure {
    pub kind: FailureKind,
    /// Comma-joined thread ids in scheduling order; pass to [`replay`].
    pub schedule: String,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:?}: {} [schedule: {}]",
            self.kind, self.message, self.schedule
        )
    }
}

/// Outcome of a [`Checker::check`] exploration.
#[derive(Clone, Debug)]
pub struct Report {
    /// Distinct complete schedules executed.
    pub schedules: usize,
    /// Executions abandoned because every candidate at some frame was in the
    /// sleep set (redundant with an already-explored interleaving).
    pub pruned: usize,
    /// First failure found, if any. Exploration stops at the first failure.
    pub failure: Option<Failure>,
}

impl Report {
    /// Panic (with the failure's schedule string) if the exploration found a
    /// bug. Convenience for tests that expect a clean model.
    pub fn assert_ok(&self) {
        if let Some(f) = &self.failure {
            panic!("model check failed: {f}");
        }
    }
}

// ---------------------------------------------------------------------------
// Checker / entry points
// ---------------------------------------------------------------------------

/// Configurable schedule explorer. The defaults suit the in-repo model
/// tests: a few thousand schedules in well under a second per scenario.
#[derive(Clone, Debug)]
pub struct Checker {
    /// Stop after this many complete schedules (default 4096).
    pub max_schedules: usize,
    /// Max context switches away from the currently running thread while it
    /// stays enabled (default 3). Bounds the DFS; most bugs need <= 2.
    pub preemption_bound: usize,
    /// Per-execution scheduling-point budget; exceeding it is reported as a
    /// livelock-flavored Assertion failure (default 20_000).
    pub max_steps: usize,
    /// Report lock-order-graph cycles as failures (default true). Turn off
    /// for scenarios that must run *through* an inversion to reach the
    /// actual deadlock state.
    pub detect_lock_order: bool,
}

impl Default for Checker {
    fn default() -> Self {
        Checker {
            max_schedules: 4096,
            preemption_bound: 3,
            max_steps: 20_000,
            detect_lock_order: true,
        }
    }
}

impl Checker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn max_schedules(mut self, n: usize) -> Self {
        self.max_schedules = n;
        self
    }

    pub fn preemption_bound(mut self, n: usize) -> Self {
        self.preemption_bound = n;
        self
    }

    pub fn max_steps(mut self, n: usize) -> Self {
        self.max_steps = n;
        self
    }

    pub fn detect_lock_order(mut self, on: bool) -> Self {
        self.detect_lock_order = on;
        self
    }

    /// Explore interleavings of `f` until a failure, schedule exhaustion, or
    /// `max_schedules`. `f` runs once per explored schedule and must be
    /// deterministic apart from thread scheduling.
    pub fn check<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        install_panic_hook();
        let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
        let mut report = Report {
            schedules: 0,
            pruned: 0,
            failure: None,
        };

        // First execution: default policy all the way down.
        let outcome = run_execution(self, &f, Vec::new());
        record(&mut report, &outcome);
        if report.failure.is_some() {
            return report;
        }
        // DFS stack mirrors the frames of the most recent execution.
        let mut stack: Vec<ExpFrame> = outcome.frames.iter().map(ExpFrame::from_frame).collect();

        while report.schedules + report.pruned < self.max_schedules {
            // Find the deepest frame with an untried, non-slept,
            // budget-feasible alternative.
            let mut pick: Option<(usize, usize)> = None; // (frame idx, tid)
            while pick.is_none() {
                let i = match stack.len().checked_sub(1) {
                    Some(i) => i,
                    None => break,
                };
                // Preemptions consumed by the committed prefix above frame i:
                // a step preempts when it switches away from a still-enabled
                // running thread.
                let mut used = 0usize;
                for fr in stack[..i].iter() {
                    let chosen = *fr.tried.last().expect("frame always has a choice");
                    if chosen != fr.current_before && fr.enabled.contains(&fr.current_before) {
                        used += 1;
                    }
                }
                let fr = &stack[i];
                let cand = fr.enabled.iter().copied().find(|t| {
                    if fr.tried.contains(t) || fr.sleep_entry.contains(t) {
                        return false;
                    }
                    let extra = if *t != fr.current_before && fr.enabled.contains(&fr.current_before)
                    {
                        1
                    } else {
                        0
                    };
                    used + extra <= self.preemption_bound
                });
                match cand {
                    Some(t) => pick = Some((i, t)),
                    None => {
                        stack.pop();
                    }
                }
            }
            let (i, tid) = match pick {
                Some(p) => p,
                None => break, // schedule tree exhausted
            };

            // Build the replay prefix: frames above i replay their last-tried
            // choice with all *earlier* tried choices added to the sleep set
            // (they lead to already-explored subtrees); frame i diverges to
            // `tid`, sleeping everything tried there before.
            let mut prefix: Vec<PrefixStep> = Vec::with_capacity(i + 1);
            for fr in stack[..i].iter() {
                let n = fr.tried.len();
                prefix.push(PrefixStep {
                    choice: fr.tried[n - 1],
                    sleep_add: fr.tried[..n - 1].to_vec(),
                });
            }
            prefix.push(PrefixStep {
                choice: tid,
                sleep_add: stack[i].tried.clone(),
            });
            stack[i].tried.push(tid);
            stack.truncate(i + 1);

            let outcome = run_execution(self, &f, prefix);
            record(&mut report, &outcome);
            if report.failure.is_some() {
                return report;
            }
            // Extend the stack with the fresh suffix below the divergence.
            for fr in outcome.frames.iter().skip(i + 1) {
                stack.push(ExpFrame::from_frame(fr));
            }
        }
        report
    }
}

/// Explore interleavings of `f` with default [`Checker`] settings.
pub fn model<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    Checker::new().check(f)
}

/// Re-run `f` under exactly one schedule — the comma-joined thread-id string
/// from a [`Failure`]. Returns a single-execution report (schedules == 1).
pub fn replay<F>(f: F, schedule: &str) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    install_panic_hook();
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let prefix: Vec<PrefixStep> = schedule
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| PrefixStep {
            choice: s
                .trim()
                .parse::<usize>()
                .expect("schedule strings are comma-joined thread ids"),
            sleep_add: Vec::new(),
        })
        .collect();
    let checker = Checker::new();
    let outcome = run_execution(&checker, &f, prefix);
    let mut report = Report {
        schedules: 0,
        pruned: 0,
        failure: None,
    };
    record(&mut report, &outcome);
    report
}

fn record(report: &mut Report, outcome: &ExecOutcome) {
    if outcome.pruned {
        report.pruned += 1;
    } else {
        report.schedules += 1;
    }
    if report.failure.is_none() {
        report.failure = outcome.failure.clone();
    }
}

// ---------------------------------------------------------------------------
// Operations and object identity
// ---------------------------------------------------------------------------

/// Identity of a shared object touched by an [`Op`], namespaced so address
/// reuse across object kinds (or channel-counter ids) can't collide.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) enum ObjId {
    /// Mutex, by address.
    M(usize),
    /// Channel, by global counter id (shared between endpoints).
    C(u64),
    /// Atomic cell, by address.
    A(usize),
    /// Condvar, by address.
    Cv(usize),
    /// A thread's lifecycle (Begin/Join), by tid.
    T(usize),
    /// The thread-id allocation order itself: Spawn ops conflict with each
    /// other because reordering them renumbers the children.
    SpawnClock,
}

/// A scheduling-point operation, as declared by a shim before blocking.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Op {
    /// First scheduling point of a spawned thread.
    Begin(usize),
    Yield,
    Lock(usize),
    TryLock(usize),
    Unlock(usize),
    CvWait { cv: usize, lock: usize },
    CvNotify { cv: usize, all: bool },
    Send(u64),
    TrySend(u64),
    Recv(u64),
    TryRecv(u64),
    RecvTimeout(u64),
    Atomic { obj: usize, write: bool },
    Spawn,
    Join(usize),
}

impl Op {
    /// Shared objects this op touches (CvWait touches two).
    fn objs(&self) -> [Option<ObjId>; 2] {
        match *self {
            Op::Begin(t) => [Some(ObjId::T(t)), None],
            Op::Yield => [None, None],
            Op::Lock(m) | Op::TryLock(m) | Op::Unlock(m) => [Some(ObjId::M(m)), None],
            Op::CvWait { cv, lock } => [Some(ObjId::Cv(cv)), Some(ObjId::M(lock))],
            Op::CvNotify { cv, .. } => [Some(ObjId::Cv(cv)), None],
            Op::Send(c) | Op::TrySend(c) | Op::Recv(c) | Op::TryRecv(c) | Op::RecvTimeout(c) => {
                [Some(ObjId::C(c)), None]
            }
            Op::Atomic { obj, .. } => [Some(ObjId::A(obj)), None],
            Op::Spawn => [Some(ObjId::SpawnClock), None],
            Op::Join(t) => [Some(ObjId::T(t)), None],
        }
    }

    /// Does this op mutate its object(s)? Reads commute; everything else is
    /// conservatively a write.
    fn is_write(&self) -> bool {
        !matches!(*self, Op::Atomic { write: false, .. } | Op::Yield)
    }

    fn describe(&self) -> String {
        match *self {
            Op::Begin(t) => format!("begin(t{t})"),
            Op::Yield => "yield".into(),
            Op::Lock(m) => format!("lock({m:#x})"),
            Op::TryLock(m) => format!("try_lock({m:#x})"),
            Op::Unlock(m) => format!("unlock({m:#x})"),
            Op::CvWait { cv, .. } => format!("cv_wait({cv:#x})"),
            Op::CvNotify { cv, all } => {
                format!("cv_notify_{}({cv:#x})", if all { "all" } else { "one" })
            }
            Op::Send(c) => format!("send(ch{c})"),
            Op::TrySend(c) => format!("try_send(ch{c})"),
            Op::Recv(c) => format!("recv(ch{c})"),
            Op::TryRecv(c) => format!("try_recv(ch{c})"),
            Op::RecvTimeout(c) => format!("recv_timeout(ch{c})"),
            Op::Atomic { obj, write } => {
                format!("atomic_{}({obj:#x})", if write { "rmw" } else { "load" })
            }
            Op::Spawn => "spawn".into(),
            Op::Join(t) => format!("join(t{t})"),
        }
    }
}

/// Two ops are dependent when they touch a common object and at least one
/// writes it. Independent ops commute — the basis for sleep-set pruning.
fn dependent(a: &Op, b: &Op) -> bool {
    let (ao, bo) = (a.objs(), b.objs());
    for x in ao.iter().flatten() {
        for y in bo.iter().flatten() {
            if x == y && (a.is_write() || b.is_write()) {
                return true;
            }
        }
    }
    false
}

/// What the scheduler tells the shim after granting an op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Grant {
    /// Go ahead (lock acquired, message enqueued, ...).
    Proceed,
    /// recv/try_recv: an item is available — do the real receive.
    DataReady,
    /// recv on a closed empty channel.
    Disconnected,
    /// recv_timeout: modeled timeout fired (channel stayed empty at
    /// quiescence).
    Timeout,
    /// try_* operation would block.
    WouldBlock,
}

// ---------------------------------------------------------------------------
// Per-execution state
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum TState {
    /// Spawned, OS thread not yet at its first scheduling point.
    Starting,
    /// Declared an op, waiting to be scheduled.
    Pending(Op),
    /// Parked in a modeled cv wait (released its mutex).
    CvBlocked { cv: usize, lock: usize },
    /// Currently scheduled (exactly one thread at a time).
    Running,
    Exited,
}

#[derive(Debug)]
struct ThreadSlot {
    state: TState,
    /// Mutexes held, in acquisition order (for lock-order edges).
    held: Vec<usize>,
    grant: Grant,
}

#[derive(Debug)]
struct ChanState {
    /// None = unbounded.
    cap: Option<usize>,
    len: usize,
    senders: usize,
    recv_alive: bool,
}

/// One frame of the recorded schedule: who was enabled, who ran.
#[derive(Clone, Debug)]
pub(crate) struct Frame {
    pub(crate) enabled: Vec<usize>,
    pub(crate) chosen: usize,
    pub(crate) current_before: usize,
    /// Sleep set at frame entry (sorted) — replays must re-enter with it.
    pub(crate) sleep_at: Vec<usize>,
}

/// One step of a replay prefix.
#[derive(Clone, Debug)]
struct PrefixStep {
    choice: usize,
    /// Tids to add to the sleep set before choosing (subtrees already
    /// explored at this node).
    sleep_add: Vec<usize>,
}

struct ExecState {
    threads: Vec<ThreadSlot>,
    current: usize,
    live: usize,
    mutexes: HashMap<usize, Option<usize>>,
    chans: HashMap<u64, ChanState>,
    /// cv addr -> FIFO of (tid, mutex addr) parked waiters.
    cvs: HashMap<usize, Vec<(usize, usize)>>,
    /// Lock-order graph: edges held -> newly acquired.
    lock_edges: HashMap<usize, HashSet<usize>>,
    choices: Vec<usize>,
    frames: Vec<Frame>,
    prefix: Vec<PrefixStep>,
    sleep: HashSet<usize>,
    failure: Option<Failure>,
    aborting: bool,
    pruned: bool,
    done: bool,
    steps: usize,
    max_steps: usize,
    detect_lock_order: bool,
}

impl ExecState {
    fn schedule_string(&self) -> String {
        let strs: Vec<String> = self.choices.iter().map(|t| t.to_string()).collect();
        strs.join(",")
    }

    fn fail(&mut self, kind: FailureKind, message: String) {
        if self.failure.is_none() {
            self.failure = Some(Failure {
                kind,
                schedule: self.schedule_string(),
                message,
            });
        }
        self.aborting = true;
    }
}

/// Shared between the explorer and all controlled threads of one execution.
pub(crate) struct Execution {
    st: StdMutex<ExecState>,
    cv: StdCondvar,
    /// Monotonic channel-id source (per execution would also work, but a
    /// process-global counter keeps ids unique across nested uses).
    chan_ids: AtomicU64,
}

thread_local! {
    /// (execution, my tid) for threads running under a model; None outside.
    static CTX: std::cell::RefCell<Option<(Arc<Execution>, usize)>> =
        const { std::cell::RefCell::new(None) };
    /// Marks panics raised by model threads so the global hook can mute the
    /// expected ones (Aborted floods, assertion probes).
    static IN_MODEL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Sentinel panic payload used to tear down controlled threads when the
/// execution aborts (failure found or subtree pruned).
struct Aborted;

/// The current thread's model context, if any. Shims call this to decide
/// between the modeled path and plain std behavior.
pub(crate) fn ctx() -> Option<(Arc<Execution>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

pub(crate) fn fresh_chan_id(exec: &Execution) -> u64 {
    exec.chan_ids.fetch_add(1, AOrd::Relaxed)
}

fn install_panic_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            let muted = IN_MODEL.with(|m| m.get());
            if !muted {
                prev(info);
            }
        }));
    });
}

impl Execution {
    fn new(checker: &Checker, prefix: Vec<PrefixStep>) -> Self {
        Execution {
            st: StdMutex::new(ExecState {
                threads: Vec::new(),
                current: 0,
                live: 0,
                mutexes: HashMap::new(),
                chans: HashMap::new(),
                cvs: HashMap::new(),
                lock_edges: HashMap::new(),
                choices: Vec::new(),
                frames: Vec::new(),
                prefix,
                sleep: HashSet::new(),
                failure: None,
                aborting: false,
                pruned: false,
                done: false,
                steps: 0,
                max_steps: checker.max_steps,
                detect_lock_order: checker.detect_lock_order,
            }),
            cv: StdCondvar::new(),
            chan_ids: AtomicU64::new(0),
        }
    }

    fn lock_state(&self) -> std::sync::MutexGuard<'_, ExecState> {
        match self.st.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    // -- channel registry (called by shim constructors / Drop impls) -------

    pub(crate) fn chan_register(&self, id: u64, cap: Option<usize>) {
        let mut st = self.lock_state();
        st.chans.insert(
            id,
            ChanState {
                cap,
                len: 0,
                senders: 1,
                recv_alive: true,
            },
        );
    }

    pub(crate) fn chan_add_sender(&self, id: u64) {
        let mut st = self.lock_state();
        if let Some(ch) = st.chans.get_mut(&id) {
            ch.senders += 1;
        }
    }

    pub(crate) fn chan_drop_sender(&self, id: u64) {
        let mut st = self.lock_state();
        if let Some(ch) = st.chans.get_mut(&id) {
            ch.senders = ch.senders.saturating_sub(1);
        }
        // A closing sender can unblock receivers waiting for Disconnected.
        Self::advance(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    pub(crate) fn chan_drop_receiver(&self, id: u64) {
        let mut st = self.lock_state();
        if let Some(ch) = st.chans.get_mut(&id) {
            ch.recv_alive = false;
        }
        // Unblocks senders on a bounded channel whose receiver vanished.
        Self::advance(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    pub(crate) fn chan_is_registered(&self, id: u64) -> bool {
        self.lock_state().chans.contains_key(&id)
    }

    // -- thread registry ----------------------------------------------------

    /// Allocate a slot for a not-yet-started thread. Caller then actually
    /// spawns the OS thread with [`run_controlled`].
    pub(crate) fn register_thread(&self) -> usize {
        let mut st = self.lock_state();
        st.threads.push(ThreadSlot {
            state: TState::Starting,
            held: Vec::new(),
            grant: Grant::Proceed,
        });
        st.live += 1;
        st.threads.len() - 1
    }

    fn thread_exit(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.lock_state();
        st.threads[tid].state = TState::Exited;
        st.live -= 1;
        if let Some(msg) = panic_msg {
            if !st.aborting {
                let schedule = st.schedule_string();
                if st.failure.is_none() {
                    st.failure = Some(Failure {
                        kind: FailureKind::Assertion,
                        schedule,
                        message: format!("thread {tid} panicked: {msg}"),
                    });
                }
                st.aborting = true;
            }
        }
        Self::advance(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    // -- the heart: declare an op, park until scheduled ---------------------

    pub(crate) fn sched_op(&self, tid: usize, op: Op) -> Grant {
        if std::thread::panicking() {
            // Unwinding (assertion probe or Aborted teardown): never panic
            // again from a Drop — apply silent effects and move on.
            self.silent_op(tid, op);
            return Grant::Proceed;
        }
        let mut st = self.lock_state();
        if st.aborting {
            drop(st);
            panic::panic_any(Aborted);
        }
        st.threads[tid].state = TState::Pending(op);
        Self::advance(&mut st);
        self.cv.notify_all();
        loop {
            if matches!(st.threads[tid].state, TState::Running) {
                return st.threads[tid].grant;
            }
            if st.aborting {
                drop(st);
                panic::panic_any(Aborted);
            }
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// Effects-only op application during panic unwinding: keep bookkeeping
    /// (mutex owners, channel counts) sane so other threads' enabledness
    /// stays accurate, but never block and never panic.
    fn silent_op(&self, tid: usize, op: Op) {
        let mut st = self.lock_state();
        match op {
            Op::Unlock(m) => {
                st.mutexes.insert(m, None);
                if let Some(pos) = st.threads[tid].held.iter().position(|&h| h == m) {
                    st.threads[tid].held.remove(pos);
                }
            }
            Op::CvNotify { cv, all } => {
                Self::apply_notify(&mut st, cv, all);
            }
            _ => {}
        }
        Self::advance(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    fn wait_done(&self) {
        let mut st = self.lock_state();
        while !st.done {
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    // -- scheduling core ----------------------------------------------------

    /// Is `tid`'s pending op enabled (would not block) in the current state?
    fn op_enabled(st: &ExecState, op: &Op) -> bool {
        match *op {
            Op::Lock(m) => st.mutexes.get(&m).copied().flatten().is_none(),
            Op::Send(c) => match st.chans.get(&c) {
                Some(ch) => {
                    !ch.recv_alive || ch.cap.is_none() || ch.len < ch.cap.expect("checked")
                }
                None => true,
            },
            Op::Recv(c) | Op::RecvTimeout(c) => match st.chans.get(&c) {
                Some(ch) => ch.len > 0 || ch.senders == 0,
                None => true,
            },
            Op::Join(t) => matches!(st.threads[t].state, TState::Exited),
            // Try-ops, unlock, notify, cv-park, atomics, yield, begin, spawn
            // never block.
            _ => true,
        }
    }

    /// Drive the execution forward: pick and dispatch the next thread
    /// whenever no thread is Running. Loops because a CvWait dispatch parks
    /// the chosen thread and requires another pick.
    fn advance(st: &mut ExecState) {
        loop {
            if st.aborting || st.done {
                if st.live == 0 {
                    st.done = true;
                }
                return;
            }
            if st.live == 0 {
                st.done = true;
                return;
            }
            // Someone still running or not yet at its first scheduling
            // point: wait for it to arrive.
            if st
                .threads
                .iter()
                .any(|t| matches!(t.state, TState::Running | TState::Starting))
            {
                return;
            }
            let pending: Vec<usize> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| matches!(t.state, TState::Pending(_)))
                .map(|(i, _)| i)
                .collect();
            if pending.is_empty() {
                // Only CvBlocked threads remain: classic lost-wakeup
                // deadlock.
                let blocked: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t.state, TState::CvBlocked { .. }))
                    .map(|(i, _)| format!("t{i} in cv wait"))
                    .collect();
                st.fail(
                    FailureKind::Deadlock,
                    format!("all live threads parked on condvars: {}", blocked.join(", ")),
                );
                return;
            }
            let enabled: Vec<usize> = pending
                .iter()
                .copied()
                .filter(|&t| {
                    let op = match st.threads[t].state {
                        TState::Pending(op) => op,
                        _ => unreachable!(),
                    };
                    Self::op_enabled(st, &op)
                })
                .collect();

            if enabled.is_empty() {
                // Quiescent. A timed receive fires its timeout; otherwise
                // this is a deadlock.
                if let Some(&t) = pending.iter().find(|&&t| {
                    matches!(st.threads[t].state, TState::Pending(Op::RecvTimeout(_)))
                }) {
                    let depth = st.frames.len();
                    if depth < st.prefix.len() && st.prefix[depth].choice != t {
                        st.fail(
                            FailureKind::Assertion,
                            format!(
                                "replay diverged at step {depth}: expected t{}, \
                                 only timeout-firing t{t} available",
                                st.prefix[depth].choice
                            ),
                        );
                        return;
                    }
                    let mut sleep_at: Vec<usize> = st.sleep.iter().copied().collect();
                    sleep_at.sort_unstable();
                    st.frames.push(Frame {
                        enabled: vec![t],
                        chosen: t,
                        current_before: st.current,
                        sleep_at,
                    });
                    st.choices.push(t);
                    st.steps += 1;
                    st.threads[t].grant = Grant::Timeout;
                    st.threads[t].state = TState::Running;
                    st.current = t;
                    return;
                }
                let desc: Vec<String> = pending
                    .iter()
                    .map(|&t| {
                        let op = match st.threads[t].state {
                            TState::Pending(op) => op,
                            _ => unreachable!(),
                        };
                        format!("t{t} blocked on {}", op.describe())
                    })
                    .collect();
                let cv_parked: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t.state, TState::CvBlocked { .. }))
                    .map(|(i, _)| format!("t{i} in cv wait"))
                    .collect();
                let mut all = desc;
                all.extend(cv_parked);
                st.fail(FailureKind::Deadlock, all.join("; "));
                return;
            }

            // Choose.
            let depth = st.frames.len();
            let chosen = if depth < st.prefix.len() {
                for &s in st.prefix[depth].sleep_add.iter() {
                    st.sleep.insert(s);
                }
                let c = st.prefix[depth].choice;
                if !enabled.contains(&c) {
                    st.fail(
                        FailureKind::Assertion,
                        format!(
                            "replay diverged at step {depth}: t{c} not enabled \
                             (enabled: {enabled:?})"
                        ),
                    );
                    return;
                }
                c
            } else {
                let candidates: Vec<usize> = enabled
                    .iter()
                    .copied()
                    .filter(|t| !st.sleep.contains(t))
                    .collect();
                if candidates.is_empty() {
                    // Every enabled move is redundant with an explored
                    // subtree — abandon this execution.
                    st.pruned = true;
                    st.aborting = true;
                    return;
                }
                // Default policy: keep running the current thread when
                // possible (fewer context switches ≙ preemption budget),
                // else lowest tid.
                if candidates.contains(&st.current) {
                    st.current
                } else {
                    candidates[0]
                }
            };

            let mut sleep_at: Vec<usize> = st.sleep.iter().copied().collect();
            sleep_at.sort_unstable();
            st.frames.push(Frame {
                enabled: enabled.clone(),
                chosen,
                current_before: st.current,
                sleep_at,
            });
            st.choices.push(chosen);
            st.steps += 1;
            if st.steps > st.max_steps {
                let limit = st.max_steps;
                st.fail(
                    FailureKind::Assertion,
                    format!("execution exceeded {limit} scheduling points (livelock?)"),
                );
                return;
            }

            let op = match st.threads[chosen].state {
                TState::Pending(op) => op,
                _ => unreachable!(),
            };
            // Forward sleep-set filtering: executing `op` wakes any slept
            // thread whose pending op depends on it.
            let sleepers: Vec<usize> = st.sleep.iter().copied().collect();
            for s in sleepers {
                if s == chosen {
                    st.sleep.remove(&s);
                    continue;
                }
                let wake = match st.threads[s].state {
                    TState::Pending(sop) => dependent(&op, &sop),
                    _ => true,
                };
                if wake {
                    st.sleep.remove(&s);
                }
            }

            let parked = Self::apply_op(st, chosen, op);
            if st.aborting {
                return;
            }
            if parked {
                // CvWait: chosen thread went to CvBlocked; pick again.
                continue;
            }
            st.threads[chosen].state = TState::Running;
            st.current = chosen;
            return;
        }
    }

    /// Mutate state for `op` by `tid`. Returns true when the thread parked
    /// (CvWait) instead of becoming Running.
    fn apply_op(st: &mut ExecState, tid: usize, op: Op) -> bool {
        st.threads[tid].grant = Grant::Proceed;
        match op {
            Op::Begin(_) | Op::Yield | Op::Spawn | Op::Join(_) => {}
            Op::Lock(m) => {
                Self::note_lock_acquire(st, tid, m);
                st.mutexes.insert(m, Some(tid));
                st.threads[tid].held.push(m);
            }
            Op::TryLock(m) => {
                if st.mutexes.get(&m).copied().flatten().is_none() {
                    Self::note_lock_acquire(st, tid, m);
                    st.mutexes.insert(m, Some(tid));
                    st.threads[tid].held.push(m);
                } else {
                    st.threads[tid].grant = Grant::WouldBlock;
                }
            }
            Op::Unlock(m) => {
                st.mutexes.insert(m, None);
                if let Some(pos) = st.threads[tid].held.iter().position(|&h| h == m) {
                    st.threads[tid].held.remove(pos);
                }
            }
            Op::CvWait { cv, lock } => {
                st.mutexes.insert(lock, None);
                if let Some(pos) = st.threads[tid].held.iter().position(|&h| h == lock) {
                    st.threads[tid].held.remove(pos);
                }
                st.cvs.entry(cv).or_default().push((tid, lock));
                st.threads[tid].state = TState::CvBlocked { cv, lock };
                return true;
            }
            Op::CvNotify { cv, all } => {
                Self::apply_notify(st, cv, all);
            }
            Op::Send(c) => {
                if let Some(ch) = st.chans.get_mut(&c) {
                    if ch.recv_alive {
                        ch.len += 1;
                    } else {
                        // Real send() will return SendError; model just
                        // lets it proceed to observe that.
                    }
                }
            }
            Op::TrySend(c) => {
                if let Some(ch) = st.chans.get_mut(&c) {
                    if !ch.recv_alive {
                        // Real try_send returns Disconnected.
                    } else if ch.cap.map_or(true, |cap| ch.len < cap) {
                        ch.len += 1;
                    } else {
                        st.threads[tid].grant = Grant::WouldBlock;
                    }
                }
            }
            Op::Recv(c) | Op::RecvTimeout(c) => {
                if let Some(ch) = st.chans.get_mut(&c) {
                    if ch.len > 0 {
                        ch.len -= 1;
                        st.threads[tid].grant = Grant::DataReady;
                    } else {
                        // Enabled with len == 0 means senders == 0.
                        st.threads[tid].grant = Grant::Disconnected;
                    }
                }
            }
            Op::TryRecv(c) => {
                if let Some(ch) = st.chans.get_mut(&c) {
                    if ch.len > 0 {
                        ch.len -= 1;
                        st.threads[tid].grant = Grant::DataReady;
                    } else if ch.senders == 0 {
                        st.threads[tid].grant = Grant::Disconnected;
                    } else {
                        st.threads[tid].grant = Grant::WouldBlock;
                    }
                }
            }
            Op::Atomic { .. } => {}
        }
        false
    }

    fn apply_notify(st: &mut ExecState, cv: usize, all: bool) {
        let waiters = st.cvs.entry(cv).or_default();
        let woken: Vec<(usize, usize)> = if all {
            std::mem::take(waiters)
        } else if waiters.is_empty() {
            Vec::new()
        } else {
            vec![waiters.remove(0)]
        };
        for (t, lock) in woken {
            // Woken waiter must reacquire its mutex: becomes a pending Lock.
            st.threads[t].state = TState::Pending(Op::Lock(lock));
        }
    }

    /// Record held->m edges and check for a cycle (lock-order inversion).
    fn note_lock_acquire(st: &mut ExecState, tid: usize, m: usize) {
        let held = st.threads[tid].held.clone();
        if held.is_empty() {
            return;
        }
        for &h in &held {
            st.lock_edges.entry(h).or_default().insert(m);
        }
        if !st.detect_lock_order {
            return;
        }
        // Cycle check: can we reach any held lock from m?
        let mut seen: HashSet<usize> = HashSet::new();
        let mut stack = vec![m];
        while let Some(x) = stack.pop() {
            if !seen.insert(x) {
                continue;
            }
            if held.contains(&x) && x != m {
                st.fail(
                    FailureKind::LockOrderInversion,
                    format!(
                        "t{tid} acquires {m:#x} while holding {held:?}, but an \
                         earlier acquisition ordered them the other way \
                         (latent deadlock)"
                    ),
                );
                return;
            }
            if let Some(next) = st.lock_edges.get(&x) {
                stack.extend(next.iter().copied());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Controlled-thread entry points (used by the thread shim)
// ---------------------------------------------------------------------------

/// Run `f` as controlled thread `tid` of `exec`: set TLS, hit the Begin
/// scheduling point, catch panics, report exit.
pub(crate) fn run_controlled<T, F>(exec: Arc<Execution>, tid: usize, f: F) -> Option<T>
where
    F: FnOnce() -> T,
{
    CTX.with(|c| *c.borrow_mut() = Some((exec.clone(), tid)));
    IN_MODEL.with(|m| m.set(true));
    exec.sched_op(tid, Op::Begin(tid));
    let result = panic::catch_unwind(AssertUnwindSafe(f));
    match result {
        Ok(v) => {
            exec.thread_exit(tid, None);
            CTX.with(|c| *c.borrow_mut() = None);
            IN_MODEL.with(|m| m.set(false));
            Some(v)
        }
        Err(payload) => {
            let msg = if payload.downcast_ref::<Aborted>().is_some() {
                None // teardown, not a failure
            } else if let Some(s) = payload.downcast_ref::<&str>() {
                Some((*s).to_string())
            } else if let Some(s) = payload.downcast_ref::<String>() {
                Some(s.clone())
            } else {
                Some("panic with non-string payload".to_string())
            };
            exec.thread_exit(tid, msg);
            CTX.with(|c| *c.borrow_mut() = None);
            IN_MODEL.with(|m| m.set(false));
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Explorer plumbing
// ---------------------------------------------------------------------------

struct ExecOutcome {
    frames: Vec<Frame>,
    failure: Option<Failure>,
    pruned: bool,
}

struct ExpFrame {
    enabled: Vec<usize>,
    current_before: usize,
    /// Choices taken from this node so far (first = the recorded run's).
    tried: Vec<usize>,
    /// Sleep set on entry — permanently-excluded candidates at this node.
    sleep_entry: Vec<usize>,
}

impl ExpFrame {
    fn from_frame(f: &Frame) -> Self {
        ExpFrame {
            enabled: f.enabled.clone(),
            current_before: f.current_before,
            tried: vec![f.chosen],
            sleep_entry: f.sleep_at.clone(),
        }
    }
}

fn run_execution(
    checker: &Checker,
    f: &Arc<dyn Fn() + Send + Sync>,
    prefix: Vec<PrefixStep>,
) -> ExecOutcome {
    let exec = Arc::new(Execution::new(checker, prefix));
    let tid = exec.register_thread();
    debug_assert_eq!(tid, 0);
    let f = f.clone();
    let exec2 = exec.clone();
    let handle = std::thread::Builder::new()
        .name("pa-model-t0".into())
        .spawn(move || {
            run_controlled(exec2, 0, move || f());
        })
        .expect("spawning model root thread");
    exec.wait_done();
    let _ = handle.join();
    let st = exec.lock_state();
    ExecOutcome {
        frames: st.frames.clone(),
        failure: st.failure.clone(),
        pruned: st.pruned,
    }
}
