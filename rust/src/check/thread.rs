//! `std::thread` drop-ins: transparent re-exports normally, scheduler-aware
//! wrappers under the `pa_modelcheck` feature.
//!
//! Under a model run, [`spawn`] registers the child with the execution so
//! the scheduler controls it from its first operation, and
//! [`JoinHandle::join`] is a scheduling point (enabled once the target
//! exits). Scoped threads (`scope`) and `sleep` are re-exported from `std`
//! unmodeled — model tests must use `spawn`/`join`; production code using
//! `scope` (metrics tests) runs them as plain std threads even under the
//! feature.

// ---------------------------------------------------------------------------
// Feature OFF: transparent re-exports.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pa_modelcheck"))]
pub use std::thread::{
    available_parallelism, scope, sleep, spawn, yield_now, Builder, JoinHandle, Scope,
    ScopedJoinHandle,
};

// ---------------------------------------------------------------------------
// Feature ON: scheduler-aware wrappers.
// ---------------------------------------------------------------------------

#[cfg(feature = "pa_modelcheck")]
pub use std::thread::{available_parallelism, scope, sleep, Scope, ScopedJoinHandle};

#[cfg(feature = "pa_modelcheck")]
mod modeled {
    use crate::check::sched::{self, Op};
    use std::sync::Arc;

    /// Join handle over either a modeled child (scheduler-registered) or a
    /// plain std thread (spawned outside any model run).
    pub struct JoinHandle<T> {
        inner: std::thread::JoinHandle<Option<T>>,
        /// `Some((execution, child tid))` when the child is modeled.
        model: Option<(Arc<sched::Execution>, usize)>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let Some((_, target)) = &self.model {
                if let Some((exec, tid)) = sched::ctx() {
                    // Scheduling point: enabled only once the target exited,
                    // so a modeled join never blocks the real thread.
                    exec.sched_op(tid, Op::Join(*target));
                }
            }
            match self.inner.join() {
                Ok(Some(v)) => Ok(v),
                // A modeled child that panicked already reported the failure
                // to the scheduler; surface a generic payload to the joiner.
                Ok(None) => Err(Box::new("modeled thread panicked".to_string())),
                Err(e) => Err(e),
            }
        }

        pub fn is_finished(&self) -> bool {
            self.inner.is_finished()
        }

        pub fn thread(&self) -> &std::thread::Thread {
            self.inner.thread()
        }
    }

    impl<T> std::fmt::Debug for JoinHandle<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("JoinHandle").finish_non_exhaustive()
        }
    }

    fn spawn_inner<F, T>(builder: std::thread::Builder, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        match sched::ctx() {
            Some((exec, tid)) => {
                // Spawn order is itself a scheduled op (it assigns the child
                // tid, so two racing spawns must not silently commute).
                exec.sched_op(tid, Op::Spawn);
                let child = exec.register_thread();
                let exec2 = exec.clone();
                let inner =
                    builder.spawn(move || sched::run_controlled(exec2, child, f))?;
                Ok(JoinHandle {
                    inner,
                    model: Some((exec, child)),
                })
            }
            None => {
                let inner = builder.spawn(move || Some(f()))?;
                Ok(JoinHandle { inner, model: None })
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        spawn_inner(std::thread::Builder::new(), f).expect("failed to spawn thread")
    }

    /// Mirror of `std::thread::Builder` (name + stack size only).
    pub struct Builder {
        inner: std::thread::Builder,
    }

    impl Default for Builder {
        fn default() -> Self {
            Builder::new()
        }
    }

    impl Builder {
        pub fn new() -> Self {
            Builder {
                inner: std::thread::Builder::new(),
            }
        }

        pub fn name(self, name: String) -> Self {
            Builder {
                inner: self.inner.name(name),
            }
        }

        pub fn stack_size(self, size: usize) -> Self {
            Builder {
                inner: self.inner.stack_size(size),
            }
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            spawn_inner(self.inner, f)
        }
    }

    /// A pure scheduling point under a model run; plain `yield_now` outside.
    pub fn yield_now() {
        match sched::ctx() {
            Some((exec, tid)) => {
                exec.sched_op(tid, Op::Yield);
            }
            None => std::thread::yield_now(),
        }
    }
}

#[cfg(feature = "pa_modelcheck")]
pub use modeled::{spawn, yield_now, Builder, JoinHandle};
