//! Drop-in concurrency shims + an offline mini-loom model checker.
//!
//! Every concurrency-bearing module in this crate imports its primitives
//! from here instead of `std`:
//!
//! * [`check::sync`](sync) — `Mutex`, `Condvar`, `mpsc` channels, atomics,
//!   and the sanctioned [`sync::lock_or_poison`] helper;
//! * [`check::thread`](thread) — `spawn`, `Builder`, `JoinHandle`,
//!   `yield_now`.
//!
//! With the `pa_modelcheck` cargo feature **off** (the default) these are
//! transparent re-exports of the `std` types: zero overhead, bit-identical
//! behavior, nothing to audit. With the feature **on**, the same names
//! resolve to thin wrappers that route every acquire / release / send /
//! recv / atomic operation through a deterministic cooperative scheduler —
//! but *only* for threads spawned inside a [`model`] execution; ordinary
//! code running under the feature still behaves exactly like `std` (the
//! wrappers detect that no model is active and pass straight through).
//!
//! # Model checking
//!
//! [`model`] runs a closure repeatedly, exploring distinct thread
//! interleavings with a bounded DFS (preemption bound + sleep-set pruning).
//! One thread runs at a time; every shim operation is a scheduling point.
//! The explorer detects and reports, each with a compact replayable
//! schedule string (see [`Failure::schedule`] and [`replay`]):
//!
//! * **deadlocks** — every live thread blocked and no timed wait to fire;
//! * **lock-order inversions** — a cycle in the execution's accumulated
//!   lock-acquisition-order graph (a latent deadlock even when this
//!   particular schedule got lucky);
//! * **assertion failures / panics** in any controlled thread.
//!
//! Ground rules for writing model tests (enforced by construction, see
//! docs/CONCURRENCY.md for the full discipline):
//!
//! * create every shared object (mutexes, channels, stores, registries)
//!   *inside* the model closure, so each explored execution starts from a
//!   fresh deterministic state;
//! * the closure must be deterministic apart from scheduling — no wall
//!   clock, no OS randomness;
//! * keep per-thread operation counts small: the schedule space is
//!   exponential and the explorer caps out at
//!   [`Checker::max_schedules`] executions.
//!
//! The scheduler serializes controlled threads, so data races are not
//! observable as such — races surface as interleaving-dependent invariant
//! violations (torn-read asserts, lost updates), which is what the model
//! tests assert on. Atomics execute sequentially consistent under the
//! model; the shims preserve the caller's `Ordering` argument for the real
//! execution path.

#[cfg(feature = "pa_modelcheck")]
pub(crate) mod sched;
pub mod sync;
pub mod thread;

#[cfg(feature = "pa_modelcheck")]
pub use sched::{model, replay, Checker, Failure, FailureKind, Report};
