//! `std::sync` drop-ins: transparent re-exports normally, scheduler-routed
//! wrappers under the `pa_modelcheck` feature.
//!
//! Either way the public surface is the same set of names — `Mutex`,
//! `MutexGuard`, `Condvar`, `Arc`, the [`atomic`] and [`mpsc`] submodules,
//! [`channel`] / [`sync_channel`], and the sanctioned [`lock_or_poison`]
//! helper — so call sites never mention the feature.
//!
//! Model-mode wrappers keep a *real* `std` primitive inside and mirror its
//! state in the scheduler: the scheduler decides *when* an operation runs
//! (and whether it would block); the real primitive then performs it
//! uncontended. Threads not spawned through a [`crate::check::model`] run
//! (no TLS context) bypass the scheduler entirely and behave exactly like
//! `std`.

// ---------------------------------------------------------------------------
// Feature OFF: transparent re-exports.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pa_modelcheck"))]
pub use std::sync::{
    Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError, TryLockError, TryLockResult, Weak,
};

/// `std::sync::atomic` verbatim (feature off).
#[cfg(not(feature = "pa_modelcheck"))]
pub mod atomic {
    pub use std::sync::atomic::*;
}

/// `std::sync::mpsc` verbatim (feature off).
#[cfg(not(feature = "pa_modelcheck"))]
pub mod mpsc {
    pub use std::sync::mpsc::*;
}

#[cfg(not(feature = "pa_modelcheck"))]
pub use std::sync::mpsc::{channel, sync_channel};

// ---------------------------------------------------------------------------
// Feature ON: scheduler-routed wrappers.
// ---------------------------------------------------------------------------

#[cfg(feature = "pa_modelcheck")]
pub use std::sync::{Arc, LockResult, PoisonError, TryLockError, TryLockResult, Weak};

#[cfg(feature = "pa_modelcheck")]
mod modeled {
    use crate::check::sched::{self, Grant, Op};
    use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, PoisonError};
    use std::sync::{LockResult, TryLockError, TryLockResult};

    type Ctl = Option<(Arc<sched::Execution>, usize)>;

    /// A mutex whose acquire/release are scheduling points under a model
    /// run. `T: Sized` (the id is the wrapper's address).
    pub struct Mutex<T> {
        inner: StdMutex<T>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Mutex {
                inner: StdMutex::new(t),
            }
        }

        fn id(&self) -> usize {
            self as *const Mutex<T> as usize
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            let ctl: Ctl = sched::ctx();
            if let Some((exec, tid)) = &ctl {
                exec.sched_op(*tid, Op::Lock(self.id()));
            }
            // Under a model the scheduler only granted the lock when free,
            // so this acquire is uncontended; outside a model it blocks like
            // plain std.
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard {
                    inner: Some(g),
                    lock: self,
                    ctl,
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    inner: Some(p.into_inner()),
                    lock: self,
                    ctl,
                })),
            }
        }

        pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
            let ctl: Ctl = sched::ctx();
            if let Some((exec, tid)) = &ctl {
                if exec.sched_op(*tid, Op::TryLock(self.id())) == Grant::WouldBlock {
                    return Err(TryLockError::WouldBlock);
                }
            }
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard {
                    inner: Some(g),
                    lock: self,
                    ctl,
                }),
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                        inner: Some(p.into_inner().into_inner()),
                        lock: self,
                        ctl,
                    })))
                }
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.inner.get_mut()
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Mutex::new(T::default())
        }
    }

    impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Mutex").finish_non_exhaustive()
        }
    }

    pub struct MutexGuard<'a, T> {
        // `Option` so Drop can release the real guard *before* telling the
        // scheduler (which may park, or panic during teardown).
        inner: Option<std::sync::MutexGuard<'a, T>>,
        lock: &'a Mutex<T>,
        ctl: Ctl,
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard present until drop")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard present until drop")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            drop(self.inner.take());
            if let Some((exec, tid)) = &self.ctl {
                exec.sched_op(*tid, Op::Unlock(self.lock.id()));
            }
        }
    }

    /// A condvar whose wait/notify are scheduling points under a model run.
    /// Lost-wakeup schedules surface as deadlocks.
    pub struct Condvar {
        inner: StdCondvar,
    }

    impl Condvar {
        pub const fn new() -> Self {
            Condvar {
                inner: StdCondvar::new(),
            }
        }

        fn id(&self) -> usize {
            self as *const Condvar as usize
        }

        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            match guard.ctl.clone() {
                Some((exec, tid)) => {
                    let lock = guard.lock;
                    // Release the real mutex, park in the scheduler, then
                    // reacquire (the scheduler wakes us as a pending Lock).
                    drop(guard.inner.take());
                    guard.ctl = None; // neuter: its Drop must not double-unlock
                    let _wake = exec.sched_op(
                        tid,
                        Op::CvWait {
                            cv: self.id(),
                            lock: lock.id(),
                        },
                    );
                    std::mem::forget(guard);
                    match lock.inner.lock() {
                        Ok(g) => Ok(MutexGuard {
                            inner: Some(g),
                            lock,
                            ctl: Some((exec, tid)),
                        }),
                        Err(p) => Err(PoisonError::new(MutexGuard {
                            inner: Some(p.into_inner()),
                            lock,
                            ctl: Some((exec, tid)),
                        })),
                    }
                }
                None => {
                    let lock = guard.lock;
                    let real = guard.inner.take().expect("guard present until drop");
                    std::mem::forget(guard);
                    match self.inner.wait(real) {
                        Ok(g) => Ok(MutexGuard {
                            inner: Some(g),
                            lock,
                            ctl: None,
                        }),
                        Err(p) => Err(PoisonError::new(MutexGuard {
                            inner: Some(p.into_inner()),
                            lock,
                            ctl: None,
                        })),
                    }
                }
            }
        }

        pub fn notify_one(&self) {
            if let Some((exec, tid)) = sched::ctx() {
                exec.sched_op(
                    tid,
                    Op::CvNotify {
                        cv: self.id(),
                        all: false,
                    },
                );
            }
            self.inner.notify_one();
        }

        pub fn notify_all(&self) {
            if let Some((exec, tid)) = sched::ctx() {
                exec.sched_op(
                    tid,
                    Op::CvNotify {
                        cv: self.id(),
                        all: true,
                    },
                );
            }
            self.inner.notify_all();
        }
    }

    impl Default for Condvar {
        fn default() -> Self {
            Condvar::new()
        }
    }

    impl std::fmt::Debug for Condvar {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Condvar").finish_non_exhaustive()
        }
    }
}

#[cfg(feature = "pa_modelcheck")]
pub use modeled::{Condvar, Mutex, MutexGuard};

/// Scheduler-routed atomics (feature on). Every operation is a scheduling
/// point; the caller's `Ordering` still reaches the real `std` atomic, but
/// under a model the serialized schedule makes everything effectively
/// sequentially consistent.
#[cfg(feature = "pa_modelcheck")]
pub mod atomic {
    use crate::check::sched::{self, Op};
    pub use std::sync::atomic::Ordering;

    fn hook(addr: usize, write: bool) {
        if let Some((exec, tid)) = sched::ctx() {
            exec.sched_op(tid, Op::Atomic { obj: addr, write });
        }
    }

    macro_rules! modeled_int_atomic {
        ($name:ident, $std:ty, $prim:ty) => {
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $prim) -> Self {
                    $name {
                        inner: <$std>::new(v),
                    }
                }

                fn id(&self) -> usize {
                    self as *const $name as usize
                }

                pub fn load(&self, ord: Ordering) -> $prim {
                    hook(self.id(), false);
                    self.inner.load(ord)
                }

                pub fn store(&self, v: $prim, ord: Ordering) {
                    hook(self.id(), true);
                    self.inner.store(v, ord)
                }

                pub fn swap(&self, v: $prim, ord: Ordering) -> $prim {
                    hook(self.id(), true);
                    self.inner.swap(v, ord)
                }

                pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                    hook(self.id(), true);
                    self.inner.fetch_add(v, ord)
                }

                pub fn fetch_sub(&self, v: $prim, ord: Ordering) -> $prim {
                    hook(self.id(), true);
                    self.inner.fetch_sub(v, ord)
                }

                pub fn fetch_min(&self, v: $prim, ord: Ordering) -> $prim {
                    hook(self.id(), true);
                    self.inner.fetch_min(v, ord)
                }

                pub fn fetch_max(&self, v: $prim, ord: Ordering) -> $prim {
                    hook(self.id(), true);
                    self.inner.fetch_max(v, ord)
                }

                pub fn compare_exchange(
                    &self,
                    current: $prim,
                    new: $prim,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$prim, $prim> {
                    hook(self.id(), true);
                    self.inner.compare_exchange(current, new, success, failure)
                }

                pub fn compare_exchange_weak(
                    &self,
                    current: $prim,
                    new: $prim,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$prim, $prim> {
                    // Spurious CAS failures would make model runs
                    // schedule-nondeterministic, so weak compiles to strong
                    // here.
                    hook(self.id(), true);
                    self.inner.compare_exchange(current, new, success, failure)
                }

                pub fn into_inner(self) -> $prim {
                    self.inner.into_inner()
                }

                pub fn get_mut(&mut self) -> &mut $prim {
                    self.inner.get_mut()
                }
            }

            impl Default for $name {
                fn default() -> Self {
                    Self::new(Default::default())
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    write!(f, "{}({:?})", stringify!($name), self.inner)
                }
            }
        };
    }

    modeled_int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    modeled_int_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    modeled_int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    modeled_int_atomic!(AtomicI64, std::sync::atomic::AtomicI64, i64);

    pub struct AtomicBool {
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            AtomicBool {
                inner: std::sync::atomic::AtomicBool::new(v),
            }
        }

        fn id(&self) -> usize {
            self as *const AtomicBool as usize
        }

        pub fn load(&self, ord: Ordering) -> bool {
            hook(self.id(), false);
            self.inner.load(ord)
        }

        pub fn store(&self, v: bool, ord: Ordering) {
            hook(self.id(), true);
            self.inner.store(v, ord)
        }

        pub fn swap(&self, v: bool, ord: Ordering) -> bool {
            hook(self.id(), true);
            self.inner.swap(v, ord)
        }

        pub fn fetch_and(&self, v: bool, ord: Ordering) -> bool {
            hook(self.id(), true);
            self.inner.fetch_and(v, ord)
        }

        pub fn fetch_or(&self, v: bool, ord: Ordering) -> bool {
            hook(self.id(), true);
            self.inner.fetch_or(v, ord)
        }

        pub fn compare_exchange(
            &self,
            current: bool,
            new: bool,
            success: Ordering,
            failure: Ordering,
        ) -> Result<bool, bool> {
            hook(self.id(), true);
            self.inner.compare_exchange(current, new, success, failure)
        }
    }

    impl Default for AtomicBool {
        fn default() -> Self {
            AtomicBool::new(false)
        }
    }

    impl std::fmt::Debug for AtomicBool {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "AtomicBool({:?})", self.inner)
        }
    }
}

/// Scheduler-routed mpsc channels (feature on). Channels created *inside* a
/// model closure are registered with that execution; channels created
/// outside behave exactly like `std` (including for non-model threads under
/// the feature). Using an unregistered channel from a modeled thread
/// panics with guidance — create every shared channel inside the closure.
#[cfg(feature = "pa_modelcheck")]
pub mod mpsc {
    use crate::check::sched::{self, Grant, Op};
    pub use std::sync::mpsc::{
        RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
    };
    use std::time::Duration;

    /// Channel id for endpoints created outside any model execution.
    const UNREGISTERED: u64 = u64::MAX;

    fn model_ctx_for(id: u64) -> Option<(std::sync::Arc<sched::Execution>, usize)> {
        let (exec, tid) = sched::ctx()?;
        if id == UNREGISTERED || !exec.chan_is_registered(id) {
            panic!(
                "model-checked thread is using a channel that was created \
                 outside the model closure; create every shared channel \
                 inside the closure passed to check::model (see \
                 docs/CONCURRENCY.md)"
            );
        }
        Some((exec, tid))
    }

    pub struct Sender<T> {
        inner: std::sync::mpsc::Sender<T>,
        id: u64,
    }

    pub struct SyncSender<T> {
        inner: std::sync::mpsc::SyncSender<T>,
        id: u64,
    }

    pub struct Receiver<T> {
        inner: std::sync::mpsc::Receiver<T>,
        id: u64,
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = match sched::ctx() {
            Some((exec, _)) => {
                let id = sched::fresh_chan_id(&exec);
                exec.chan_register(id, None);
                id
            }
            None => UNREGISTERED,
        };
        (Sender { inner: tx, id }, Receiver { inner: rx, id })
    }

    pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
        let (tx, rx) = std::sync::mpsc::sync_channel(bound);
        let id = match sched::ctx() {
            Some((exec, _)) => {
                assert!(
                    bound > 0,
                    "rendezvous channels (sync_channel(0)) are not supported \
                     under the model checker; use a capacity >= 1"
                );
                let id = sched::fresh_chan_id(&exec);
                exec.chan_register(id, Some(bound));
                id
            }
            None => UNREGISTERED,
        };
        (SyncSender { inner: tx, id }, Receiver { inner: rx, id })
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            if let Some((exec, tid)) = model_ctx_for(self.id) {
                exec.sched_op(tid, Op::Send(self.id));
            }
            self.inner.send(t)
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            if let Some((exec, _)) = sched::ctx() {
                if self.id != UNREGISTERED {
                    exec.chan_add_sender(self.id);
                }
            }
            Sender {
                inner: self.inner.clone(),
                id: self.id,
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            if self.id == UNREGISTERED {
                return;
            }
            if let Some((exec, _)) = sched::ctx() {
                exec.chan_drop_sender(self.id);
            }
        }
    }

    impl<T> std::fmt::Debug for Sender<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Sender(ch{})", self.id)
        }
    }

    impl<T> SyncSender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            if let Some((exec, tid)) = model_ctx_for(self.id) {
                exec.sched_op(tid, Op::Send(self.id));
            }
            // The scheduler only granted the send when the buffer had room
            // (or the receiver is gone), so the real send cannot block a
            // modeled thread.
            self.inner.send(t)
        }

        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            if let Some((exec, tid)) = model_ctx_for(self.id) {
                if exec.sched_op(tid, Op::TrySend(self.id)) == Grant::WouldBlock {
                    return Err(TrySendError::Full(t));
                }
            }
            self.inner.try_send(t)
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> Self {
            if let Some((exec, _)) = sched::ctx() {
                if self.id != UNREGISTERED {
                    exec.chan_add_sender(self.id);
                }
            }
            SyncSender {
                inner: self.inner.clone(),
                id: self.id,
            }
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            if self.id == UNREGISTERED {
                return;
            }
            if let Some((exec, _)) = sched::ctx() {
                exec.chan_drop_sender(self.id);
            }
        }
    }

    impl<T> std::fmt::Debug for SyncSender<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "SyncSender(ch{})", self.id)
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            if let Some((exec, tid)) = model_ctx_for(self.id) {
                exec.sched_op(tid, Op::Recv(self.id));
                // DataReady: an item is buffered, the real recv returns
                // immediately. Disconnected: all senders gone, it errors
                // immediately. Either way no real blocking.
            }
            self.inner.recv()
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            if let Some((exec, tid)) = model_ctx_for(self.id) {
                match exec.sched_op(tid, Op::TryRecv(self.id)) {
                    Grant::WouldBlock => return Err(TryRecvError::Empty),
                    _ => {}
                }
            }
            self.inner.try_recv()
        }

        pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
            match model_ctx_for(self.id) {
                Some((exec, tid)) => match exec.sched_op(tid, Op::RecvTimeout(self.id)) {
                    // Modeled timeout: fires only when the whole system is
                    // quiescent (no enabled thread could still send), so the
                    // model never races a wall clock.
                    Grant::Timeout => Err(RecvTimeoutError::Timeout),
                    Grant::Disconnected => Err(RecvTimeoutError::Disconnected),
                    _ => match self.inner.recv() {
                        Ok(v) => Ok(v),
                        Err(RecvError) => Err(RecvTimeoutError::Disconnected),
                    },
                },
                None => self.inner.recv_timeout(timeout),
            }
        }

        pub fn iter(&self) -> std::sync::mpsc::Iter<'_, T> {
            self.inner.iter()
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            if self.id == UNREGISTERED {
                return;
            }
            if let Some((exec, _)) = sched::ctx() {
                exec.chan_drop_receiver(self.id);
            }
        }
    }

    impl<T> std::fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Receiver(ch{})", self.id)
        }
    }
}

#[cfg(feature = "pa_modelcheck")]
pub use mpsc::{channel, sync_channel};

// ---------------------------------------------------------------------------
// Sanctioned helpers (both modes).
// ---------------------------------------------------------------------------

static POISONED: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total poisoned-lock recoveries since process start. The coordinator
/// exports the per-iteration delta as the `pa_rl_lock_poisoned` counter.
pub fn poisoned_lock_count() -> u64 {
    POISONED.load(std::sync::atomic::Ordering::SeqCst)
}

/// Acquire `m`, recovering the guard if a previous holder panicked instead
/// of propagating the poison panic — a dying publisher must not
/// cascade-kill every other thread sharing the lock. Each recovery bumps
/// the process-wide counter behind [`poisoned_lock_count`].
///
/// This is the **only** sanctioned way to ignore lock poisoning in this
/// repo; `tools/pa-lint` flags bare `.lock().unwrap()` / `.lock().expect()`.
pub fn lock_or_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => {
            POISONED.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            p.into_inner()
        }
    }
}
