//! Held-out accuracy evaluation (the paper's AIME24/GSM8K accuracy columns,
//! in analog): greedy decoding over an evaluation set with exact-match
//! scoring. Runs on a dedicated engine instance with evaluation sampling
//! settings, outside the TPSPD-timed path — mirroring the paper, where
//! evaluation uses different sampling (temp 0.6 / top-p 0.95) and separate
//! passes.

use crate::config::Config;
use crate::data::{TaskGen, Tokenizer};
use crate::engine::{Engine, GenRequest, SamplerCfg};
use crate::grpo::reward;
use crate::runtime::{HostParams, Runtime};
use anyhow::{Context, Result};
use std::path::Path;

/// Accuracy result.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub n: usize,
    pub correct: usize,
    pub accuracy: f64,
    pub mean_response_len: f64,
}

/// Evaluate `params` on `n` held-out prompts with greedy decoding.
pub fn evaluate(
    cfg: &Config,
    artifacts_dir: &Path,
    params: &HostParams,
    n: usize,
) -> Result<EvalReport> {
    let rt = Runtime::load_validated(artifacts_dir, cfg).context("eval runtime")?;
    let mut engine = Engine::new(cfg.clone(), rt, 0xE7A1);
    engine.set_sampler(SamplerCfg::greedy());
    engine.set_weights(params)?;
    let gen = TaskGen::new(cfg.data.clone());
    let tokenizer = Tokenizer::new();

    let mut correct = 0usize;
    let mut total_len = 0usize;
    // Request ids are the enumeration index — assigned deterministically at
    // enqueue, like the driver's dispatch-order ids. Greedy evaluation never
    // consumes the per-request sampling streams, but keeping the id scheme
    // deterministic means a temperature>0 eval would inherit
    // placement-independent sampling for free.
    let prompts: Vec<_> = (0..n as u64).map(|i| gen.eval_prompt(i)).collect();
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest { request_id: i as u64, prompt: p.tokens.clone(), ..Default::default() })
        .collect();
    let results = engine.generate_all(reqs)?;
    for r in &results {
        let p = &prompts[r.request_id as usize];
        if reward::score(&tokenizer, &r.tokens, p.answer) > 0.5 {
            correct += 1;
        }
        total_len += r.tokens.len();
    }
    Ok(EvalReport {
        n,
        correct,
        accuracy: correct as f64 / n.max(1) as f64,
        mean_response_len: total_len as f64 / n.max(1) as f64,
    })
}
