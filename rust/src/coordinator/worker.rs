//! Engine worker threads — the producer side of the pipeline.
//!
//! Each worker owns one [`Engine`] instance (its own PJRT client + compiled
//! artifacts), pulls jobs from its inbox, steps the engine, scores finished
//! rollouts with the rule-based reward, and pushes [`ScoredRollout`]s into
//! the shared bounded queue. The send blocks when the queue is full —
//! backpressure toward the inference side, bounding rollout memory exactly
//! like the paper's shared queue.
//!
//! Workers join and leave the fleet mid-run: the coordinator spawns a new
//! worker at any iteration boundary ([`super::Driver::spawn_engine`]) and
//! retires one gracefully via [`EngineMsg::Drain`] — the drained worker
//! stops admitting, pulls never-admitted jobs back out of its engine, runs
//! the in-flight sequences to completion (their rollouts still flow through
//! the shared queue), and hands the leftover jobs plus its final counters
//! back so nothing is lost and the fleet-wide metrics stay exact.
//!
//! This module is the coordinator's **only** thread-creation site
//! (pa-lint's `coordinator-threads` rule): everything the driver does with
//! these workers runs through the shared protocol loops in
//! [`super::ctrl`], which the simulated fleet ([`crate::sim::fleet`])
//! drives identically over the deterministic executor ([`super::exec`])
//! with mock engines and virtual time — see *Deterministic coordinator* in
//! `docs/CONCURRENCY.md`.

use super::messages::{DrainAck, EngineMsg, GenJob, ScoredRollout, WeightSyncAck, WorkerStats};
use crate::config::Config;
use crate::data::Tokenizer;
use crate::engine::{Engine, GenResult};
use crate::grpo::reward;
use crate::metrics::Trace;
use crate::runtime::Runtime;
use crate::check::sync::mpsc::{self, Receiver, Sender, SyncSender, TryRecvError};
use crate::check::thread::{Builder, JoinHandle};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Handle to a spawned worker.
pub struct WorkerHandle {
    pub thread: JoinHandle<Result<()>>,
    pub inbox: Sender<EngineMsg>,
}

/// Spawn an engine worker. `artifacts_dir` is loaded inside the thread (the
/// PJRT client is thread-bound). Errors when the OS refuses to give us a
/// thread — the caller decides whether a smaller fleet is acceptable.
pub fn spawn_worker(
    idx: usize,
    cfg: Config,
    artifacts_dir: PathBuf,
    seed: u64,
    queue: SyncSender<ScoredRollout>,
    trace: Trace,
) -> Result<WorkerHandle> {
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let thread = Builder::new()
        .name(format!("engine-{idx}"))
        .spawn(move || worker_main(idx, cfg, artifacts_dir, seed, rx, queue, trace))
        .with_context(|| format!("spawning engine worker {idx}"))?;
    Ok(WorkerHandle { thread, inbox: tx })
}

/// What the message handler told the main loop to do next.
enum Flow {
    Continue,
    /// [`EngineMsg::Shutdown`]: exit now.
    Shutdown,
    /// [`EngineMsg::Drain`]: finish in-flight work, ack, then exit.
    Drain(mpsc::Sender<DrainAck>),
}

fn worker_main(
    idx: usize,
    cfg: Config,
    artifacts_dir: PathBuf,
    seed: u64,
    inbox: Receiver<EngineMsg>,
    queue: SyncSender<ScoredRollout>,
    trace: Trace,
) -> Result<()> {
    let rt = Runtime::load_validated(&artifacts_dir, &cfg)
        .with_context(|| format!("engine-{idx}: loading artifacts"))?;
    let mut artifacts = vec!["prefill", "decode"];
    if cfg.engine.prefix_cache
        && cfg.engine.chunked_prefill
        && rt.manifest().artifacts.contains_key("prefill_chunk")
    {
        // Chunked admission is on the first-iteration path: compile eagerly
        // alongside the other engine artifacts so iteration 0 isn't skewed.
        artifacts.push("prefill_chunk");
    }
    rt.prepare(&artifacts)
        .with_context(|| format!("engine-{idx}: compiling artifacts"))?;
    let full_metrics = cfg.metrics.level.is_full();
    // Every engine gets the SAME run seed: sampling is keyed per request
    // (`(run_seed, request_id, decode_step)` streams inside the engine), so
    // perturbing the seed by worker index would re-introduce the placement
    // dependence this scheme exists to remove.
    let mut engine = Engine::new(cfg, rt, seed);
    if full_metrics {
        // Full telemetry: the engine stamps admit / first-token / finish on
        // every request timeline, on the same clock as the trace spans.
        engine.set_telemetry(trace.clock());
    }
    let tokenizer = Tokenizer::new();
    let lane = format!("infer-{idx}");
    let req_lane = format!("req-{idx}");
    // request_id -> job metadata for scoring
    let mut jobs: HashMap<u64, GenJob> = HashMap::new();

    loop {
        // Block when idle; otherwise drain without blocking.
        if engine.idle() {
            match inbox.recv() {
                Ok(msg) => match handle_msg(msg, idx, &mut engine, &mut jobs, &trace, &lane)? {
                    Flow::Continue => {}
                    Flow::Shutdown => return Ok(()),
                    Flow::Drain(ack) => {
                        return drain_exit(ack, idx, &mut engine, &mut jobs, &tokenizer, &queue)
                    }
                },
                Err(_) => return Ok(()), // coordinator dropped
            }
        }
        loop {
            match inbox.try_recv() {
                Ok(msg) => match handle_msg(msg, idx, &mut engine, &mut jobs, &trace, &lane)? {
                    Flow::Continue => {}
                    Flow::Shutdown => return Ok(()),
                    Flow::Drain(ack) => {
                        return drain_exit(ack, idx, &mut engine, &mut jobs, &tokenizer, &queue)
                    }
                },
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return Ok(()),
            }
        }
        if !engine.idle() {
            let t0 = trace.now();
            let finished = engine.step().with_context(|| format!("engine-{idx}: step"))?;
            trace.record(&lane, "step", t0);
            if full_metrics {
                // Causal per-request spans: one `gen` span per finished
                // request on this engine's request lane, admit -> finish,
                // linked back to the driver's dispatch span that sent it
                // (`timeline.parent_span`). Full mode only — new spans would
                // change the rendered basic trace.
                for r in &finished {
                    let tl = &r.timeline;
                    if tl.admit_s >= 0.0 && tl.finish_s >= 0.0 {
                        trace.record_abs_child(
                            &req_lane,
                            "gen",
                            tl.admit_s,
                            tl.finish_s,
                            tl.parent_span,
                        );
                    }
                }
            }
            if !score_and_send(finished, idx, &mut jobs, &tokenizer, &queue)? {
                return Ok(()); // consumer gone; shut down quietly
            }
        }
    }
}

/// Score finished rollouts and push them into the shared queue ("each
/// coroutine independently evaluates the reward", paper §4.2.1). A full
/// queue blocks — backpressure when the trainer lags. Returns `false` when
/// the consumer is gone and the worker should exit quietly.
fn score_and_send(
    finished: Vec<GenResult>,
    idx: usize,
    jobs: &mut HashMap<u64, GenJob>,
    tokenizer: &Tokenizer,
    queue: &SyncSender<ScoredRollout>,
) -> Result<bool> {
    for r in finished {
        let job = jobs
            .remove(&r.request_id)
            .context("engine returned unknown request id")?;
        let score = reward::score(tokenizer, &r.tokens, job.answer);
        let rollout = ScoredRollout {
            request_id: r.request_id,
            prompt_id: job.prompt_id,
            sample_idx: job.sample_idx,
            weight_version: r.weight_version,
            tokens: r.tokens,
            logprobs: r.logprobs,
            reward: score,
            gen_seconds: r.seconds,
            engine_idx: idx,
            timeline: r.timeline,
        };
        if queue.send(rollout).is_err() {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Graceful fleet departure: pull the never-admitted jobs back out of the
/// engine, run the in-flight sequences to completion (their rollouts flow to
/// the consumer through the shared queue like any other completion), then
/// hand the leftover jobs and the final counters back and exit.
fn drain_exit(
    ack: mpsc::Sender<DrainAck>,
    idx: usize,
    engine: &mut Engine,
    jobs: &mut HashMap<u64, GenJob>,
    tokenizer: &Tokenizer,
    queue: &SyncSender<ScoredRollout>,
) -> Result<()> {
    let mut pending = Vec::new();
    for req in engine.take_pending() {
        pending.push(
            jobs.remove(&req.request_id)
                .context("draining engine held an unknown request id")?,
        );
    }
    while !engine.idle() {
        let finished = engine
            .step()
            .with_context(|| format!("engine-{idx}: step while draining"))?;
        if !score_and_send(finished, idx, jobs, tokenizer, queue)? {
            break; // consumer gone: nobody is owed the leftovers either
        }
    }
    let _ = ack.send(DrainAck {
        pending,
        stats: engine.stats.clone(),
        cache: engine.cache_stats().cloned(),
    });
    Ok(())
}

/// Handle one coordinator message; returns what the main loop does next.
fn handle_msg(
    msg: EngineMsg,
    idx: usize,
    engine: &mut Engine,
    jobs: &mut HashMap<u64, GenJob>,
    trace: &Trace,
    lane: &str,
) -> Result<Flow> {
    match msg {
        EngineMsg::AttachStore(store) => {
            engine.set_shared_store(store);
        }
        EngineMsg::DetachStore => {
            engine.clear_shared_store();
        }
        EngineMsg::SetWeights(params, ack) => {
            let uploaded = engine.set_weights(&params)?;
            let _ = ack.send(WeightSyncAck { uploaded });
        }
        EngineMsg::Gen(job) => {
            jobs.insert(job.request.request_id, (*job).clone());
            engine.submit(job.request);
        }
        EngineMsg::GenGroup(group) => {
            // The group's requests enter the pending queue back-to-back:
            // the first admission prefills and populates the prefix cache,
            // the remaining G-1 admissions hit it.
            for job in group {
                jobs.insert(job.request.request_id, job.clone());
                engine.submit(job.request);
            }
        }
        EngineMsg::QueryStats(reply) => {
            let cache = engine.cache_stats().cloned();
            if let Some(c) = &cache {
                // Surface the hit rate on this worker's timeline lane so the
                // rendered trace carries it next to the TPSPD spans.
                trace.annotate(lane, "kv_hit", c.hit_rate());
            }
            if engine.stats.cross_engine_hits > 0 {
                // Cross-engine imports on this lane (fig3 trace annotation).
                trace.annotate(lane, "xeng", engine.stats.cross_engine_hits as f64);
            }
            let _ = reply.send(WorkerStats {
                engine_idx: idx,
                engine: engine.stats.clone(),
                cache,
                // Advertise which template prefixes are verifiably resident
                // here — the router's per-engine warmth refresh.
                warm: engine.warm_templates(),
                pending: engine.pending_count(),
                active: engine.active_count(),
            });
        }
        EngineMsg::Drain(ack) => return Ok(Flow::Drain(ack)),
        EngineMsg::Shutdown => return Ok(Flow::Shutdown),
    }
    Ok(Flow::Continue)
}
