//! The coordinator — the paper's system contribution (Algorithm 1).
//!
//! A producer–consumer pipeline over decoupled training and inference
//! instances: engine worker threads generate and score rollouts into a
//! bounded shared queue; the consumer trains on groups in completion-time
//! order; model weights synchronise at iteration boundaries only, keeping
//! every batch strictly on-policy (Prop. 1) while inference and training
//! overlap inside the iteration (periodic asynchrony).
//!
//! The inference fleet is **elastic**: engines join and drain mid-run
//! through the driver ([`Driver::spawn_engine`] / [`Driver::drain_engine`],
//! scheduled via `rl.fleet_schedule`), with joiners weight-synced before
//! they receive work and drains losing no rollout; dispatch is
//! group-affine and residency-aware ([`route`]), with optional TTL decay
//! on the router's warmth beliefs.

pub mod assembler;
pub mod ctrl;
pub mod driver;
pub mod eval;
pub mod exec;
pub mod messages;
pub mod route;
pub mod worker;

pub use assembler::Assembler;
pub use ctrl::{FleetCtrl, QueuePoll, RecvStep, RolloutSource, StallWatchdog};
pub use driver::{
    stall_snapshot_json, Driver, DriverOpts, IterReport, Mode, PhaseAttribution, RolloutRecord,
    RunReport,
};
pub use eval::{evaluate, EvalReport};
pub use messages::{DrainAck, EngineMsg, GenJob, ScoredRollout, WeightSyncAck, WorkerStats};
