//! Group assembly: collects scored rollouts into complete GRPO groups and
//! computes group-normalised advantages on completion.
//!
//! Rollouts arrive in completion-time order, interleaved across prompts (and,
//! in the staleness ablation, across batches); the assembler buffers partial
//! groups and releases each group the moment its G-th rollout lands — the
//! earliest point at which GRPO advantages are computable.
//!
//! Ingest rejects unknown and duplicate `(prompt, sample)` pairs with an
//! error rather than dropping them silently: that check is what the
//! sim-fleet property suite ([`crate::sim::fleet`]) leans on for its
//! no-job-duplicated invariant under drain/re-route schedules.

use super::messages::ScoredRollout;
use crate::data::Prompt;
use crate::grpo::{group_advantages, Group, Rollout};
use anyhow::{bail, Result};
use std::collections::HashMap;

struct Partial {
    prompt: Prompt,
    expected: usize,
    rollouts: Vec<Option<Rollout>>,
    received: usize,
    max_gen_seconds: f64,
}

/// Assembles [`ScoredRollout`]s into [`Group`]s.
#[derive(Default)]
pub struct Assembler {
    partial: HashMap<u64, Partial>,
}

impl Assembler {
    pub fn new() -> Assembler {
        Assembler { partial: HashMap::new() }
    }

    /// Register a prompt expecting `group_size` rollouts.
    pub fn register(&mut self, prompt: Prompt, group_size: usize) {
        let id = prompt.id;
        let prev = self.partial.insert(
            id,
            Partial {
                prompt,
                expected: group_size,
                rollouts: (0..group_size).map(|_| None).collect(),
                received: 0,
                max_gen_seconds: 0.0,
            },
        );
        assert!(prev.is_none(), "prompt {id} registered twice");
    }

    /// Number of prompts still awaiting rollouts.
    pub fn pending_prompts(&self) -> usize {
        self.partial.len()
    }

    /// Ingest one rollout; returns the completed group if this was the last
    /// member. Duplicate or unknown rollouts are errors (they would silently
    /// corrupt advantages).
    pub fn ingest(&mut self, r: ScoredRollout) -> Result<Option<Group>> {
        let Some(p) = self.partial.get_mut(&r.prompt_id) else {
            bail!("rollout for unregistered prompt {}", r.prompt_id);
        };
        if r.sample_idx >= p.expected {
            bail!("sample_idx {} out of range (G={})", r.sample_idx, p.expected);
        }
        if p.rollouts[r.sample_idx].is_some() {
            bail!("duplicate rollout for prompt {} sample {}", r.prompt_id, r.sample_idx);
        }
        p.max_gen_seconds = p.max_gen_seconds.max(r.gen_seconds);
        p.rollouts[r.sample_idx] = Some(Rollout {
            sample_idx: r.sample_idx,
            weight_version: r.weight_version,
            tokens: r.tokens,
            logprobs: r.logprobs,
            reward: r.reward,
            timeline: r.timeline,
        });
        p.received += 1;
        if p.received < p.expected {
            return Ok(None);
        }
        // pa-lint: allow(unwrap): get_mut on the same key succeeded above
        let p = self.partial.remove(&r.prompt_id).unwrap();
        // pa-lint: allow(unwrap): received == expected, so every slot is Some
        let rollouts: Vec<Rollout> = p.rollouts.into_iter().map(|r| r.unwrap()).collect();
        let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
        let weight_version = rollouts[0].weight_version;
        Ok(Some(Group {
            prompt: p.prompt,
            weight_version,
            advantages: group_advantages(&rewards),
            rollouts,
            gen_seconds: p.max_gen_seconds,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn mk_prompt(id: u64) -> Prompt {
        Prompt { id, tokens: vec![1, 5, 6], text: "Q".into(), answer: 3 }
    }

    fn mk_rollout(prompt_id: u64, sample_idx: usize, reward: f32) -> ScoredRollout {
        ScoredRollout {
            request_id: prompt_id * 100 + sample_idx as u64,
            prompt_id,
            sample_idx,
            weight_version: 1,
            tokens: vec![9, 2],
            logprobs: vec![-0.5, -0.1],
            reward,
            gen_seconds: 0.1,
            engine_idx: 0,
            timeline: Default::default(),
        }
    }

    #[test]
    fn completes_group_with_advantages() {
        let mut a = Assembler::new();
        a.register(mk_prompt(0), 3);
        assert!(a.ingest(mk_rollout(0, 1, 1.0)).unwrap().is_none());
        assert!(a.ingest(mk_rollout(0, 0, 0.0)).unwrap().is_none());
        let g = a.ingest(mk_rollout(0, 2, 0.0)).unwrap().unwrap();
        assert_eq!(g.rollouts.len(), 3);
        assert_eq!(g.rollouts[0].reward, 0.0);
        assert_eq!(g.rollouts[1].reward, 1.0);
        assert!(g.advantages[1] > 0.0 && g.advantages[0] < 0.0);
        assert_eq!(a.pending_prompts(), 0);
    }

    #[test]
    fn rejects_duplicates_and_unknown() {
        let mut a = Assembler::new();
        a.register(mk_prompt(7), 2);
        a.ingest(mk_rollout(7, 0, 1.0)).unwrap();
        assert!(a.ingest(mk_rollout(7, 0, 1.0)).is_err());
        assert!(a.ingest(mk_rollout(99, 0, 1.0)).is_err());
        assert!(a.ingest(mk_rollout(7, 5, 1.0)).is_err());
    }

    #[test]
    fn prop_any_arrival_order_completes() {
        prop::quick(
            "assembler completes under arbitrary interleaving",
            |rng: &mut Pcg64, size| {
                let n_prompts = rng.range(1, size.scaled(8).max(1) + 1);
                let g = rng.range(1, 5);
                let mut arrivals: Vec<(u64, usize)> = (0..n_prompts as u64)
                    .flat_map(|p| (0..g).map(move |s| (p, s)))
                    .collect();
                rng.shuffle(&mut arrivals);
                (n_prompts, g, arrivals)
            },
            |(n_prompts, g, arrivals)| {
                let mut a = Assembler::new();
                for p in 0..*n_prompts as u64 {
                    a.register(mk_prompt(p), *g);
                }
                let mut completed = 0;
                for &(p, s) in arrivals {
                    if a.ingest(mk_rollout(p, s, s as f32)).map_err(|e| e.to_string())?.is_some() {
                        completed += 1;
                    }
                }
                if completed != *n_prompts {
                    return Err(format!("{completed} of {n_prompts} groups completed"));
                }
                if a.pending_prompts() != 0 {
                    return Err("assembler left partial groups".into());
                }
                Ok(())
            },
        );
    }
}
