//! Prompt-affinity routing — which engine gets a GRPO group.
//!
//! Group dispatch stays *group-affine* (all G rollouts of a prompt land on
//! one engine; that is what collapses a group's G prefills into 1), but the
//! choice of engine is no longer a blind round-robin pin: the dispatcher
//! hashes the prompt's **template prefix** — its longest block-aligned
//! proper prefix, the same boundary form the shared segment store keys on
//! ([`crate::store::hash`]) — and prefers the engine that prefix hashes to,
//! because earlier groups with the same template already warmed that
//! engine's local radix cache (no store round-trip at all).
//!
//! Affinity alone would hot-spot: on a workload where every prompt shares
//! one template, the preferred engine gets everything. So routing is
//! load-bounded — when the preferred engine's backlog exceeds the
//! least-loaded engine's by more than `slack` jobs, the group *spills* to
//! the least-loaded engine, which imports the template from the shared
//! store instead of recomputing it. Affinity keeps the common case free;
//! the store makes the spill case cheap; together N engines serve
//! template traffic as one logical cache without load imbalance.

use crate::store::hash;

/// Blocks of the prompt head the router hashes. Capping the routed prefix
/// at a fixed depth (rather than "everything but the last partial block")
/// is what keeps same-template prompts with *different question lengths*
/// on the same engine: an uncapped block-aligned prefix would extend past
/// the template into per-prompt question tokens whenever lengths vary, and
/// scatter the template across engines. Two blocks discriminate distinct
/// templates well while staying safely inside any realistic template.
pub const AFFINITY_BLOCKS: usize = 2;

/// The routed prefix: the longest block-aligned proper prefix of the
/// prompt, capped at [`AFFINITY_BLOCKS`] blocks (the final partial block —
/// the per-prompt question tail — never participates). Whole-prompt
/// fallback for prompts shorter than one block.
pub fn affinity_prefix_len(prompt_len: usize, block_tokens: usize) -> usize {
    let bt = block_tokens.max(1);
    let aligned = prompt_len.saturating_sub(1) / bt * bt;
    if aligned == 0 {
        prompt_len
    } else {
        aligned.min(AFFINITY_BLOCKS * bt)
    }
}

/// Pick the engine for a group given per-engine backlogs (outstanding jobs).
/// Returns `(engine index, took_preferred)`; `false` marks a spill to the
/// least-loaded fallback.
pub fn route_group(
    prompt: &[u32],
    block_tokens: usize,
    load: &[usize],
    slack: usize,
) -> (usize, bool) {
    debug_assert!(!load.is_empty(), "no engines to route to");
    let n = load.len();
    if n == 1 {
        return (0, true);
    }
    let len = affinity_prefix_len(prompt.len(), block_tokens);
    let preferred = (hash::hash_prefix(&prompt[..len]) % n as u64) as usize;
    let min = load.iter().copied().min().unwrap_or(0);
    if load[preferred] <= min + slack {
        (preferred, true)
    } else {
        let least = load
            .iter()
            .enumerate()
            .min_by_key(|&(_, l)| *l)
            .map(|(i, _)| i)
            .unwrap_or(0);
        (least, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_prefix_drops_the_partial_tail_block() {
        assert_eq!(affinity_prefix_len(10, 4), 8);
        assert_eq!(affinity_prefix_len(8, 4), 4, "aligned length is itself a tail");
        assert_eq!(affinity_prefix_len(3, 4), 3, "short prompt: whole-prompt fallback");
        assert_eq!(affinity_prefix_len(1, 4), 1);
        // Capped: long prompts hash a fixed head, so a 48-token template
        // with question tails of varying length routes identically.
        assert_eq!(affinity_prefix_len(56, 4), AFFINITY_BLOCKS * 4);
        assert_eq!(affinity_prefix_len(62, 4), AFFINITY_BLOCKS * 4);
    }

    #[test]
    fn variable_length_questions_share_a_template_engine() {
        // Same 48-token template, question tails of 5..12 tokens: every
        // prompt must prefer the same engine (the uncapped form would hash
        // question tokens and scatter them).
        let template: Vec<u32> = (0..48).map(|i| 3 + (i % 7)).collect();
        let load = vec![0usize; 4];
        let engines: std::collections::HashSet<usize> = (5..13)
            .map(|q| {
                let mut p = template.clone();
                p.extend((0..q).map(|i| 60 + i));
                route_group(&p, 4, &load, 8).0
            })
            .collect();
        assert_eq!(engines.len(), 1, "template scattered across {engines:?}");
    }

    #[test]
    fn same_template_same_engine_until_overload() {
        let template: Vec<u32> = (0..8).collect();
        let a: Vec<u32> = [&template[..], &[50, 51]].concat();
        let b: Vec<u32> = [&template[..], &[60][..]].concat();
        let mut load = vec![0usize; 4];
        let (ea, pa) = route_group(&a, 4, &load, 8);
        let (eb, pb) = route_group(&b, 4, &load, 8);
        assert!(pa && pb);
        assert_eq!(ea, eb, "shared template must prefer one engine");
        // Back the preferred engine up past the slack: the next group spills
        // to the least-loaded engine.
        load[ea] = 9;
        load[(ea + 1) % 4] = 3;
        let (es, ps) = route_group(&a, 4, &load, 8);
        assert!(!ps, "overloaded preferred engine must spill");
        assert_ne!(es, ea);
        assert_eq!(load[es], 0, "spill goes to the least-loaded engine");
        // Within slack, affinity wins again.
        load[ea] = 8;
        assert_eq!(route_group(&a, 4, &load, 8), (ea, true));
    }

    #[test]
    fn single_engine_short_circuits() {
        assert_eq!(route_group(&[1, 2, 3], 4, &[7], 0), (0, true));
    }

    #[test]
    fn distinct_templates_spread() {
        // 64 distinct 8-token templates over 4 engines: no engine should be
        // starved (the router must actually use the hash, not a constant).
        let load = vec![0usize; 4];
        let mut hits = [0usize; 4];
        for t in 0..64u32 {
            let prompt: Vec<u32> = (0..10).map(|i| t * 31 + i).collect();
            let (e, p) = route_group(&prompt, 4, &load, 0);
            assert!(p);
            hits[e] += 1;
        }
        assert!(hits.iter().all(|&h| h > 0), "dead engine: {hits:?}");
    }
}
