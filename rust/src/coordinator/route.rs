//! Prompt-affinity routing — which engine gets a GRPO group.
//!
//! Group dispatch stays *group-affine* (all G rollouts of a prompt land on
//! one engine; that is what collapses a group's G prefills into 1), but the
//! choice of engine is no longer a blind hash: the dispatcher identifies the
//! prompt's **template prefix** — its longest block-aligned proper prefix,
//! capped at [`AFFINITY_BLOCKS`] blocks, the same boundary form the shared
//! segment store keys on ([`crate::store::hash`]) — and asks *where that
//! template is actually warm*:
//!
//! 1. **Warmth map** ([`WarmthMap`]): the coordinator remembers which engine
//!    it last sent each template to, and refreshes those beliefs from the
//!    engines' own warm-template advertisements on the existing stats
//!    channel (`WorkerStats::warm` — each engine reports the affinity keys
//!    whose prefixes are *currently resident* in its radix cache, probed
//!    non-mutatingly at query time). A warm hit routes to the engine that
//!    verifiably holds the prefix — even when that engine is not the one the
//!    hash would have picked (e.g. after a spill or an engine leaving).
//! 2. **Hash fallback**: an unknown template routes by hashing the prefix
//!    over the live engines — deterministic spread, the PR-3 behavior.
//! 3. **Load bound with residency-aware slack**: when the chosen engine's
//!    backlog exceeds the least-loaded engine's by more than the slack, the
//!    group *spills* to the least-loaded engine. The slack itself consults
//!    the shared store's residency probe
//!    ([`crate::store::SharedKvStore::residency_blocks`]): if the store
//!    already covers the template, a spill is cheap (the target imports
//!    instead of recomputing) and the normal slack applies; if the store
//!    does *not* cover it but an engine holds it warm, the router tolerates
//!    a deeper backlog before spilling, because a spill would recompute the
//!    template from scratch on a cold engine.
//!
//! Engines joining or leaving mid-run need no special protocol: the warmth
//! map drops a leaving engine's claims ([`WarmthMap::remove_engine`]) and
//! its templates re-route by hash over the remaining fleet — store-covered
//! templates stay cheap wherever they land, which is the whole point of the
//! host-side store. A grown fleet simply exposes more hash targets; warm
//! templates keep routing to their resident engines by warmth, not by hash.
//! The [`Driver`](crate::coordinator::Driver) exercises both directions
//! through `spawn_engine` / `drain_engine` and the `rl.fleet_schedule`.
//!
//! **Belief decay** ([`WarmthMap::advance`]): on a long-running fleet that
//! rarely (or never) syncs weights, beliefs would otherwise live until an
//! engine's advertisement refresh contradicted them — and a server loop that
//! never queries stats (like `serve_infer`'s dispatch path) would pin every
//! template to its first engine forever. With a nonzero TTL
//! ([`WarmthMap::with_ttl`]), each belief must be re-confirmed — by a routed
//! dispatch ([`WarmthMap::note`]) or an engine advertisement
//! ([`WarmthMap::refresh_engine`]) — within its TTL window of decay epochs
//! or it expires and the template falls back to the hash spread. The window
//! scales with the advertised resident length ([`RESIDENT_TTL_UNIT`]): a
//! long few-shot template is worth far more prefill than a short one, so
//! its belief is kept alive proportionally longer. TTL 0 (the default)
//! disables decay — bit-identical to the pre-decay router.

use crate::store::hash;
use std::collections::HashMap;

pub use crate::store::hash::{affinity_key, affinity_prefix_len, AFFINITY_BLOCKS};

/// Why the router picked an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteKind {
    /// The warmth map named an engine that holds the template resident.
    Warm,
    /// Unknown template: deterministic hash spread over the live engines.
    Hashed,
    /// The preferred engine was overloaded; spilled to the least-loaded.
    Spill,
}

impl RouteKind {
    /// Spills are the only dispatch that abandons template affinity.
    pub fn is_spill(&self) -> bool {
        matches!(self, RouteKind::Spill)
    }
}

/// One warmth belief: which engine holds a template resident, how many of
/// its tokens, and the decay-clock epoch at which the belief was last
/// confirmed (dispatched to, or advertised by, that engine).
#[derive(Debug, Clone, Copy)]
struct Belief {
    engine: usize,
    resident: usize,
    last_seen: u64,
}

/// Resident-length unit of the decay TTL: every `RESIDENT_TTL_UNIT` tokens
/// of advertised residency extend a belief's lifetime by one base-TTL
/// window. Longer templates save more prefill per correct routing decision,
/// so their beliefs are worth holding onto proportionally longer.
pub const RESIDENT_TTL_UNIT: usize = 256;

/// The coordinator's per-template warmth beliefs: affinity key ->
/// `(engine, resident tokens)`. Optimistically updated on dispatch
/// ([`WarmthMap::note`]) and corrected from engine advertisements on the
/// stats channel ([`WarmthMap::refresh_engine`]); flushed whenever a real
/// weight sync flushes every cache; optionally decayed per routing epoch
/// ([`WarmthMap::advance`]) so unconfirmed beliefs expire.
#[derive(Debug, Default)]
pub struct WarmthMap {
    map: HashMap<u64, Belief>,
    /// Decay clock, advanced once per routing epoch (an RL iteration in the
    /// driver, a dispatched group in `serve_infer`).
    clock: u64,
    /// Base TTL in decay epochs; 0 disables decay entirely.
    ttl: u64,
}

impl WarmthMap {
    /// A map without belief decay (TTL 0) — the PR-4 router's behavior.
    pub fn new() -> WarmthMap {
        WarmthMap::default()
    }

    /// A map whose beliefs expire unless re-confirmed within `ttl` decay
    /// epochs (scaled up for long resident prefixes — see
    /// [`RESIDENT_TTL_UNIT`]). `ttl` 0 disables decay.
    pub fn with_ttl(ttl: u64) -> WarmthMap {
        WarmthMap { ttl, ..WarmthMap::default() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Engine believed to hold `key` warm, with its resident token count.
    pub fn lookup(&self, key: u64) -> Option<(usize, usize)> {
        self.map.get(&key).map(|b| (b.engine, b.resident))
    }

    /// Record a dispatch: `engine` is about to admit this template, so it
    /// becomes the template's warm home (most recent dispatch wins — that is
    /// also what the engines' LRU caches will believe).
    pub fn note(&mut self, key: u64, engine: usize, resident: usize) {
        self.map.insert(key, Belief { engine, resident, last_seen: self.clock });
    }

    /// Merge one engine's advertised warm templates (stats-channel refresh).
    /// Absence is authoritative: the engine advertises *every* template it
    /// still holds resident, so a belief naming this engine for a key it no
    /// longer advertises is stale (evicted under pressure) and is dropped —
    /// otherwise the router would keep steering that template to a cold
    /// engine, at the *stretched* slack no less (the warm+uncovered case).
    /// For advertised keys, the engine's own claims replace beliefs about
    /// itself; claims about a template currently attributed to another
    /// engine win only when they cover a strictly longer prefix (a longer
    /// resident prefix saves more prefill; ties keep the routing stable).
    /// Every advertisement counts as confirmation for the decay clock.
    pub fn refresh_engine(&mut self, engine: usize, warm: &[(u64, usize)]) {
        let advertised: std::collections::HashSet<u64> =
            warm.iter().map(|&(key, _)| key).collect();
        self.map.retain(|key, b| b.engine != engine || advertised.contains(key));
        let clock = self.clock;
        for &(key, resident) in warm {
            match self.map.get(&key) {
                Some(b) if b.engine != engine && b.resident >= resident => {}
                _ => {
                    self.map.insert(key, Belief { engine, resident, last_seen: clock });
                }
            }
        }
    }

    /// A real weight sync flushed every engine cache: nothing is warm.
    pub fn flush(&mut self) {
        self.map.clear();
    }

    /// Engine `engine` left the fleet: drop every belief naming it, and any
    /// belief naming an index at or past the new fleet size `n_engines`
    /// (callers that compact indices). Its templates re-route by hash until
    /// a surviving engine re-warms them — store-covered templates at import
    /// cost, not recompute cost.
    pub fn remove_engine(&mut self, engine: usize, n_engines: usize) {
        self.map.retain(|_, b| b.engine != engine && b.engine < n_engines);
    }

    /// Advance the decay clock one routing epoch and expire every belief
    /// that has gone unconfirmed longer than its TTL window. The window is
    /// the base TTL scaled by the advertised resident length (one extra
    /// base window per [`RESIDENT_TTL_UNIT`] resident tokens), so a
    /// rarely-used short template stops pinning routing quickly while a
    /// large shared few-shot prefix keeps its home. No-op when the map was
    /// built without a TTL ([`WarmthMap::new`]).
    pub fn advance(&mut self) {
        self.clock += 1;
        if self.ttl == 0 {
            return;
        }
        let (clock, ttl) = (self.clock, self.ttl);
        self.map.retain(|_, b| {
            let window = ttl.saturating_mul(1 + (b.resident / RESIDENT_TTL_UNIT) as u64);
            clock.saturating_sub(b.last_seen) <= window
        });
    }
}

/// Pick the engine for a group by residency: prefer the engine the warmth
/// map proves warm, fall back to the hash spread, and spill to the
/// least-loaded engine when the preferred backlog runs past the (residency
/// -aware) slack. `store_resident` is the shared store's coverage of this
/// prompt ([`crate::store::SharedKvStore::residency_blocks`]; pass 0 with no
/// store): a store-covered template spills at the normal slack, an uncovered
/// warm template tolerates twice the backlog before abandoning its engine.
pub fn route_group_residency(
    prompt: &[u32],
    block_tokens: usize,
    load: &[usize],
    slack: usize,
    warmth: &WarmthMap,
    store_resident: usize,
) -> (usize, RouteKind) {
    debug_assert!(!load.is_empty(), "no engines to route to");
    let n = load.len();
    if n == 1 {
        return (0, RouteKind::Hashed);
    }
    let (key, len) = hash::affinity_key(prompt, block_tokens);
    let (preferred, kind) = match warmth.lookup(key) {
        Some((e, _)) if e < n => (e, RouteKind::Warm),
        _ => ((hash::hash_prefix(&prompt[..len]) % n as u64) as usize, RouteKind::Hashed),
    };
    let min = load.iter().copied().min().unwrap_or(0);
    // Residency-aware slack: spilling an uncovered warm template means a
    // cold recompute on the target, so tolerate a deeper backlog first.
    let eff_slack = if kind == RouteKind::Warm && store_resident == 0 {
        slack.saturating_mul(2)
    } else {
        slack
    };
    if load[preferred] <= min + eff_slack {
        (preferred, kind)
    } else {
        let least = load
            .iter()
            .enumerate()
            .min_by_key(|&(_, l)| *l)
            .map(|(i, _)| i)
            .unwrap_or(0);
        (least, RouteKind::Spill)
    }
}

/// Pick the engine for a group given per-engine backlogs (outstanding jobs)
/// by blind prefix hashing — the warmth-free baseline. Returns
/// `(engine index, took_preferred)`; `false` marks a spill to the
/// least-loaded fallback. Kept for benches and as the degenerate form of
/// [`route_group_residency`] with an empty warmth map and no store.
pub fn route_group(
    prompt: &[u32],
    block_tokens: usize,
    load: &[usize],
    slack: usize,
) -> (usize, bool) {
    let warmth = WarmthMap::new();
    let (idx, kind) = route_group_residency(prompt, block_tokens, load, slack, &warmth, 0);
    (idx, !kind.is_spill())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_template_same_engine_until_overload() {
        let template: Vec<u32> = (0..8).collect();
        let a: Vec<u32> = [&template[..], &[50, 51]].concat();
        let b: Vec<u32> = [&template[..], &[60][..]].concat();
        let mut load = vec![0usize; 4];
        let (ea, pa) = route_group(&a, 4, &load, 8);
        let (eb, pb) = route_group(&b, 4, &load, 8);
        assert!(pa && pb);
        assert_eq!(ea, eb, "shared template must prefer one engine");
        // Back the preferred engine up past the slack: the next group spills
        // to the least-loaded engine.
        load[ea] = 9;
        load[(ea + 1) % 4] = 3;
        let (es, ps) = route_group(&a, 4, &load, 8);
        assert!(!ps, "overloaded preferred engine must spill");
        assert_ne!(es, ea);
        assert_eq!(load[es], 0, "spill goes to the least-loaded engine");
        // Within slack, affinity wins again.
        load[ea] = 8;
        assert_eq!(route_group(&a, 4, &load, 8), (ea, true));
    }

    #[test]
    fn single_engine_short_circuits() {
        assert_eq!(route_group(&[1, 2, 3], 4, &[7], 0), (0, true));
    }

    #[test]
    fn distinct_templates_spread() {
        // 64 distinct 8-token templates over 4 engines: no engine should be
        // starved (the router must actually use the hash, not a constant).
        let load = vec![0usize; 4];
        let mut hits = [0usize; 4];
        for t in 0..64u32 {
            let prompt: Vec<u32> = (0..10).map(|i| t * 31 + i).collect();
            let (e, p) = route_group(&prompt, 4, &load, 0);
            assert!(p);
            hits[e] += 1;
        }
        assert!(hits.iter().all(|&h| h > 0), "dead engine: {hits:?}");
    }

    #[test]
    fn warm_engine_preferred_over_least_loaded() {
        // Engine 2 verifiably holds the template; engine 0 is idle. The
        // router must still pick 2 (importing saves the whole template), not
        // the least-loaded engine, as long as 2 is within slack.
        let template: Vec<u32> = (0..8).collect();
        let prompt: Vec<u32> = [&template[..], &[50, 51]].concat();
        let (key, len) = affinity_key(&prompt, 4);
        assert_eq!(len, 8);
        let mut warmth = WarmthMap::new();
        warmth.note(key, 2, len);
        let load = vec![0usize, 1, 2, 1];
        let (e, kind) = route_group_residency(&prompt, 4, &load, 4, &warmth, 0);
        assert_eq!((e, kind), (2, RouteKind::Warm));
        // An unknown template on the same fleet falls back to the hash.
        let cold: Vec<u32> = (100..110).collect();
        let (_, kind) = route_group_residency(&cold, 4, &load, 4, &warmth, 0);
        assert_eq!(kind, RouteKind::Hashed);
    }

    #[test]
    fn spill_past_slack_still_works_and_respects_store_residency() {
        let template: Vec<u32> = (0..8).collect();
        let prompt: Vec<u32> = [&template[..], &[50, 51]].concat();
        let (key, len) = affinity_key(&prompt, 4);
        let mut warmth = WarmthMap::new();
        warmth.note(key, 1, len);
        let slack = 2usize;
        let mut load = vec![0usize; 4];
        // Backlog within slack: stays warm.
        load[1] = 2;
        let (e, k) = route_group_residency(&prompt, 4, &load, slack, &warmth, 0);
        assert_eq!((e, k), (1, RouteKind::Warm));
        // Backlog past slack but within the stretched (2x) slack and the
        // store does NOT cover the template: a spill would recompute it
        // cold, so the router sticks with the warm engine.
        load[1] = 4;
        let (e, k) = route_group_residency(&prompt, 4, &load, slack, &warmth, 0);
        assert_eq!((e, k), (1, RouteKind::Warm), "uncovered template spilled too eagerly");
        // Same backlog with the template resident in the shared store: the
        // spill is an import, so the normal slack applies and the group
        // moves to the least-loaded engine.
        let (e, k) = route_group_residency(&prompt, 4, &load, slack, &warmth, len);
        assert_eq!(k, RouteKind::Spill);
        assert_eq!(load[e], 0, "spill goes to the least-loaded engine");
        // Past even the stretched slack, an uncovered template spills too —
        // load bounding always wins eventually.
        load[1] = 5;
        let (_, k) = route_group_residency(&prompt, 4, &load, slack, &warmth, 0);
        assert_eq!(k, RouteKind::Spill);
    }

    #[test]
    fn leaving_engine_redistributes_without_losing_store_coverage() {
        // Three templates warm on three engines; engine 2 leaves. Its
        // template must re-route decisively (no panic, an in-range engine),
        // and the other engines' warmth survives untouched.
        let mk = |t: u32| -> Vec<u32> {
            (0..10).map(|i| t * 53 + i).collect()
        };
        let mut warmth = WarmthMap::new();
        let keys: Vec<u64> = (0..3)
            .map(|t| {
                let (key, len) = affinity_key(&mk(t), 4);
                warmth.note(key, t as usize, len);
                key
            })
            .collect();
        // Fleet shrinks 3 -> 2: engine index 2 is gone.
        warmth.remove_engine(2, 2);
        assert_eq!(warmth.lookup(keys[2]), None, "leaver's warmth must be dropped");
        assert!(warmth.lookup(keys[0]).is_some());
        assert!(warmth.lookup(keys[1]).is_some());
        let load = vec![0usize; 2];
        // The orphaned template re-routes by hash over the live fleet; the
        // store still covers it, so the landing engine imports rather than
        // recomputes — the router itself only needs to stay in range.
        let (e, kind) = route_group_residency(&mk(2), 4, &load, 2, &warmth, 8);
        assert!(e < 2);
        assert_eq!(kind, RouteKind::Hashed);
        // Surviving warmth keeps routing by residency.
        let (e, kind) = route_group_residency(&mk(1), 4, &load, 2, &warmth, 8);
        assert_eq!((e, kind), (1, RouteKind::Warm));
        // A stale advertisement naming an out-of-range engine is ignored
        // even if it somehow survives (double safety in the router).
        warmth.note(keys[2], 7, 8);
        let (e, _) = route_group_residency(&mk(2), 4, &load, 2, &warmth, 8);
        assert!(e < 2);
    }

    #[test]
    fn stats_refresh_corrects_beliefs_toward_longer_residency() {
        let mut warmth = WarmthMap::new();
        warmth.note(42, 0, 8);
        // Another engine advertises a shorter prefix: belief unchanged.
        warmth.refresh_engine(1, &[(42, 4)]);
        assert_eq!(warmth.lookup(42), Some((0, 8)));
        // A strictly longer residency elsewhere wins the template.
        warmth.refresh_engine(1, &[(42, 12)]);
        assert_eq!(warmth.lookup(42), Some((1, 12)));
        // An engine's claim about itself always refreshes (eviction shrank
        // its coverage).
        warmth.refresh_engine(1, &[(42, 6)]);
        assert_eq!(warmth.lookup(42), Some((1, 6)));
        // Absence is authoritative: a template the owning engine stopped
        // advertising (evicted) loses its belief instead of attracting
        // traffic to a cold engine at stretched slack — while beliefs about
        // *other* engines survive this engine's refresh untouched.
        warmth.note(7, 1, 4);
        warmth.note(9, 0, 4);
        warmth.refresh_engine(1, &[(42, 6)]);
        assert_eq!(warmth.lookup(7), None, "stale belief must drop");
        assert_eq!(warmth.lookup(42), Some((1, 6)));
        assert_eq!(warmth.lookup(9), Some((0, 4)), "other engines' beliefs survive");
        // Flush on a real weight sync: nothing is warm anywhere.
        warmth.flush();
        assert!(warmth.is_empty());
    }

    #[test]
    fn warmth_decay_expires_stale_beliefs_and_keeps_fresh_ones() {
        let mut w = WarmthMap::with_ttl(2);
        w.note(1, 0, 8); // never confirmed again: must expire
        w.note(2, 1, 8); // re-dispatched every epoch: must survive
        for _ in 0..4 {
            w.advance();
            w.note(2, 1, 8);
        }
        assert_eq!(w.lookup(1), None, "unconfirmed belief must expire past the TTL");
        assert_eq!(w.lookup(2), Some((1, 8)), "a re-confirmed belief must survive");
        // Advertisements on the stats channel count as confirmation too.
        w.note(3, 0, 8);
        for _ in 0..4 {
            w.advance();
            w.refresh_engine(0, &[(3, 8)]);
        }
        assert_eq!(w.lookup(3), Some((0, 8)), "advertised belief must survive decay");
    }

    #[test]
    fn warmth_decay_ttl_scales_with_resident_length() {
        let mut w = WarmthMap::with_ttl(1);
        w.note(1, 0, 4); // short template: one base window
        w.note(2, 1, RESIDENT_TTL_UNIT * 3); // long template: four windows
        w.advance();
        w.advance();
        assert_eq!(w.lookup(1), None, "short resident prefix expires at the base TTL");
        assert_eq!(
            w.lookup(2),
            Some((1, RESIDENT_TTL_UNIT * 3)),
            "long resident prefix must outlive the base TTL"
        );
        for _ in 0..3 {
            w.advance();
        }
        assert_eq!(w.lookup(2), None, "even long beliefs expire eventually");
    }

    #[test]
    fn warmth_ttl_zero_never_decays() {
        let mut w = WarmthMap::new();
        w.note(1, 0, 4);
        for _ in 0..100 {
            w.advance();
        }
        assert_eq!(w.lookup(1), Some((0, 4)), "TTL 0 must be the PR-4 no-decay router");
    }

    /// TTL decay at fleet scale: 128 templates over 64 engines, with the
    /// decay clock driven like the simulated fleet drives it (one `advance`
    /// per stats sweep). Short-resident and long-resident beliefs must expire
    /// on *different* windows, confirmation must reset the clock, and an
    /// engine's advertisement must stay absence-authoritative — interleavings
    /// a 2–3-engine fixture never produces.
    #[test]
    fn warmth_ttl_decay_across_a_64_engine_fleet() {
        let n = 64usize;
        let mut w = WarmthMap::with_ttl(2);
        // Even keys: short templates (one base window of 2 epochs). Odd
        // keys: a full RESIDENT_TTL_UNIT resident, doubling the window to 4.
        for k in 0..128u64 {
            let resident = if k % 2 == 0 { 32 } else { RESIDENT_TTL_UNIT };
            w.note(k, (k as usize) % n, resident);
        }
        assert_eq!(w.len(), 128);
        // Three epochs; engine 0 keeps advertising only template 0 each
        // sweep. The first sweep drops its *other* claim (key 64 — absence
        // on the stats channel is authoritative), leaving 127 beliefs.
        for _ in 0..3 {
            w.refresh_engine(0, &[(0, 32)]);
            w.advance();
        }
        // clock = 3: unconfirmed shorts (window 2) have expired; the
        // re-confirmed short survives, and every long (window 4) survives.
        assert!(w.lookup(0).is_some(), "re-confirmed short belief must survive");
        assert_eq!(w.lookup(2), None, "unconfirmed short belief must expire at clock 3");
        assert!(w.lookup(1).is_some(), "long-resident belief still inside its window");
        assert_eq!(w.len(), 64 + 1, "64 longs + the one confirmed short");
        w.advance(); // clock = 4: longs are exactly at their window edge.
        assert_eq!(w.len(), 65, "window is inclusive: longs live through clock 4");
        w.advance(); // clock = 5: everything left lapses.
        assert!(w.is_empty(), "even stretched windows expire eventually");
    }

    /// `remove_engine` rebalancing at fleet scale: shrink a 64-engine fleet
    /// to 48 one tail-drain at a time. Survivors' beliefs must be untouched
    /// at every step, removed engines' claims must vanish, and routing over
    /// the shrunk fleet must send every orphaned template back to the hash
    /// spread — always in range — while warm templates keep their homes.
    #[test]
    fn remove_engine_rebalances_a_64_engine_fleet() {
        let block = 4usize;
        let mut n = 64usize;
        let mut w = WarmthMap::new();
        // 96 distinct templates: engines 0..31 hold two each, 32..63 one.
        let prompts: Vec<Vec<u32>> = (0..96u32)
            .map(|t| {
                let mut p: Vec<u32> = (0..8).map(|i| t * 131 + i + 1).collect();
                p.push(100_000 + t); // tail past the block-aligned prefix
                p
            })
            .collect();
        for (i, p) in prompts.iter().enumerate() {
            let (key, alen) = affinity_key(p, block);
            w.note(key, i % n, alen);
        }
        assert_eq!(w.len(), 96, "96 distinct templates, 96 beliefs");
        while n > 48 {
            n -= 1;
            w.remove_engine(n, n);
            // Homes are i % 64: engines below n keep their claims, i >= 64
            // wraps back under 32, so the survivor count is n + 32.
            assert_eq!(w.len(), n + 32, "only the drained engine's claims drop");
        }
        let load = vec![0usize; n];
        for (i, p) in prompts.iter().enumerate() {
            let home = i % 64;
            let (key, _) = affinity_key(p, block);
            match w.lookup(key) {
                Some((e, _)) => assert_eq!(e, home, "surviving belief must not move"),
                None => assert!(home >= n, "in-range belief was dropped"),
            }
            let (e, kind) = route_group_residency(p, block, &load, 4, &w, 0);
            assert!(e < n, "routed to a drained engine: {e} >= {n}");
            if home < n {
                assert_eq!((e, kind), (home, RouteKind::Warm), "warm template left home");
            } else {
                assert_eq!(kind, RouteKind::Hashed, "orphaned template must re-hash");
            }
        }
    }
}
