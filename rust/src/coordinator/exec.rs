//! Deterministic single-threaded async executor + discrete-event virtual
//! clock — the coordinator's simulation substrate.
//!
//! Modeled on nexosim's `st_executor`: a slab of tasks (`Pin<Box<dyn
//! Future>>` slots with generation counters so recycled ids can't be woken
//! by stale wakers), a FIFO ready queue fed by the wakers, a **scoped
//! executor context** (thread-local, entered around every poll) through
//! which tasks spawn siblings ([`spawn`]) and read virtual time ([`now`],
//! [`sleep`]), and an explicit **run-to-quiescence** loop ([`Executor::run`]
//! / [`Executor::step`]): poll ready tasks until none remain, then advance
//! the [`VirtualClock`] to the next timer and continue; when neither side
//! can make progress the system is quiescent.
//!
//! Everything is deterministic by construction — FIFO ready order,
//! registration-order timer tie-breaks, no `HashMap` iteration, no wall
//! clock, one OS thread. The ready queue and the wake flags route through
//! the [`check::sync`](crate::check::sync) shims, the same seam the
//! `pa_modelcheck` scheduler instruments, so the model-check suite doubles
//! as the executor's regression harness (`docs/CONCURRENCY.md`).
//!
//! [`channel`] provides the matching deterministic mpsc: bounded sends
//! park the sender exactly like the driver's bounded rollout queue, so the
//! simulated fleet reproduces real backpressure under virtual time.

use crate::check::sync::atomic::{AtomicBool, Ordering};
use crate::check::sync::{lock_or_poison, Arc, Mutex};
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Wake, Waker};

type BoxFut = Pin<Box<dyn Future<Output = ()> + 'static>>;

/// FIFO of `(slot id, generation)` pairs ready to poll. Behind a shim mutex:
/// wakers must be `Send + Sync` by contract even though this executor never
/// leaves its thread, and routing the wake path through `check::sync` keeps
/// it on the model checker's seam.
struct ReadyQueue {
    q: Mutex<VecDeque<(usize, u64)>>,
}

/// One task's waker: re-queues the task unless it is already queued (the
/// `queued` flag dedupes multi-wakes between polls).
struct TaskWaker {
    id: usize,
    gen: u64,
    queued: AtomicBool,
    ready: Arc<ReadyQueue>,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        if !self.queued.swap(true, Ordering::SeqCst) {
            lock_or_poison(&self.ready.q).push_back((self.id, self.gen));
        }
    }
}

struct Slot {
    fut: Option<BoxFut>,
    gen: u64,
    waker: Option<Arc<TaskWaker>>,
}

/// Total order for finite virtual timestamps (timer heap key).
#[derive(PartialEq)]
struct Time(f64);

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Timestamps are finite by construction: sleep() rejects NaN and
        // advance only ever moves forward from a finite origin.
        // pa-lint: allow(expect): finite-by-construction, see above
        self.0.partial_cmp(&other.0).expect("virtual timestamps are finite")
    }
}

struct Timer {
    at: Time,
    seq: u64,
    waker: Waker,
}

impl PartialEq for Timer {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Timer {}
impl PartialOrd for Timer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Earliest deadline first; registration order breaks ties so two
        // sleepers due at the same instant wake deterministically.
        self.at.cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

struct ClockInner {
    now: f64,
    seq: u64,
    timers: BinaryHeap<Reverse<Timer>>,
}

/// Discrete-event virtual clock. Time only moves when the executor has no
/// ready task: it jumps to the next timer deadline (or an explicit
/// [`VirtualClock::advance_to`] bound), so a simulated second costs nothing
/// and a simulated run is a pure function of its inputs.
#[derive(Clone)]
pub struct VirtualClock(Rc<RefCell<ClockInner>>);

impl VirtualClock {
    fn new() -> VirtualClock {
        VirtualClock(Rc::new(RefCell::new(ClockInner {
            now: 0.0,
            seq: 0,
            timers: BinaryHeap::new(),
        })))
    }

    /// Current virtual time in seconds since the executor's epoch.
    pub fn now(&self) -> f64 {
        self.0.borrow().now
    }

    /// A future resolving `dt` virtual seconds from now (clamped to >= 0;
    /// NaN is rejected — it would corrupt the timer order).
    pub fn sleep(&self, dt: f64) -> Sleep {
        assert!(!dt.is_nan(), "sleep duration must not be NaN");
        Sleep { clock: self.clone(), deadline: self.now() + dt.max(0.0) }
    }

    /// Earliest pending timer deadline, if any.
    pub fn next_deadline(&self) -> Option<f64> {
        self.0.borrow().timers.peek().map(|Reverse(t)| t.at.0)
    }

    /// Advance to `t` without firing anything. Panics if a timer is due at
    /// or before `t` (that would silently skip a scheduled wake) or if `t`
    /// is in the past — callers advance through [`Executor::step`] instead.
    pub fn advance_to(&self, t: f64) {
        let mut inner = self.0.borrow_mut();
        assert!(t >= inner.now, "virtual time cannot run backwards");
        if let Some(Reverse(timer)) = inner.timers.peek() {
            assert!(timer.at.0 > t, "advance_to would skip a pending timer");
        }
        inner.now = t;
    }

    fn register(&self, deadline: f64, waker: Waker) {
        let mut inner = self.0.borrow_mut();
        let seq = inner.seq;
        inner.seq += 1;
        inner.timers.push(Reverse(Timer { at: Time(deadline), seq, waker }));
    }

    /// Advance to the earliest timer at or before `bound` and collect every
    /// waker due at that instant. Empty when no timer is due by `bound`.
    fn fire_next(&self, bound: f64) -> Vec<Waker> {
        let mut inner = self.0.borrow_mut();
        let due = match inner.timers.peek() {
            Some(Reverse(t)) if t.at.0 <= bound => t.at.0,
            _ => return Vec::new(),
        };
        inner.now = inner.now.max(due);
        let mut woke = Vec::new();
        while let Some(Reverse(t)) = inner.timers.peek() {
            if t.at.0 > inner.now {
                break;
            }
            // pa-lint: allow(unwrap): peek above proved the heap non-empty
            woke.push(inner.timers.pop().expect("peeked timer present").0.waker);
        }
        woke
    }
}

/// Timer future returned by [`VirtualClock::sleep`] / [`sleep`].
pub struct Sleep {
    clock: VirtualClock,
    deadline: f64,
}

impl Future for Sleep {
    type Output = ();

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.clock.now() >= self.deadline {
            Poll::Ready(())
        } else {
            self.clock.register(self.deadline, cx.waker().clone());
            Poll::Pending
        }
    }
}

// ---------------------------------------------------------------------------
// Scoped executor context
// ---------------------------------------------------------------------------

struct Ctx {
    spawns: Rc<RefCell<VecDeque<BoxFut>>>,
    clock: VirtualClock,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

struct CtxGuard;

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CTX.with(|c| c.borrow_mut().take());
    }
}

fn enter_ctx(spawns: Rc<RefCell<VecDeque<BoxFut>>>, clock: VirtualClock) -> CtxGuard {
    CTX.with(|c| {
        let mut slot = c.borrow_mut();
        assert!(slot.is_none(), "executor context is not reentrant");
        *slot = Some(Ctx { spawns, clock });
    });
    CtxGuard
}

fn with_ctx<R>(f: impl FnOnce(&Ctx) -> R) -> R {
    CTX.with(|c| {
        let slot = c.borrow();
        // Free-function misuse outside a poll is a programming error; the
        // panic message says exactly what to fix.
        let ctx = slot
            .as_ref()
            // pa-lint: allow(expect): misuse panics with an actionable message
            .expect("called outside an executor poll (no scoped context)");
        f(ctx)
    })
}

/// Spawn a sibling task from inside a running task. The task is admitted
/// before the executor's next poll, in spawn order. Panics outside a poll.
pub fn spawn(fut: impl Future<Output = ()> + 'static) {
    with_ctx(|ctx| ctx.spawns.borrow_mut().push_back(Box::pin(fut)));
}

/// Virtual time, read from inside a running task. Panics outside a poll.
pub fn now() -> f64 {
    with_ctx(|ctx| ctx.clock.now())
}

/// Sleep `dt` virtual seconds, from inside a running task. Panics outside a
/// poll.
pub fn sleep(dt: f64) -> Sleep {
    with_ctx(|ctx| ctx.clock.sleep(dt))
}

// ---------------------------------------------------------------------------
// The executor
// ---------------------------------------------------------------------------

/// Deterministic single-threaded executor over a slab of tasks (see module
/// docs). Dropping it drops every live task.
pub struct Executor {
    slots: Vec<Slot>,
    free: Vec<usize>,
    live: usize,
    polls: u64,
    ready: Arc<ReadyQueue>,
    pending_spawns: Rc<RefCell<VecDeque<BoxFut>>>,
    clock: VirtualClock,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new()
    }
}

impl Executor {
    pub fn new() -> Executor {
        Executor {
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            polls: 0,
            ready: Arc::new(ReadyQueue { q: Mutex::new(VecDeque::new()) }),
            pending_spawns: Rc::new(RefCell::new(VecDeque::new())),
            clock: VirtualClock::new(),
        }
    }

    /// The executor's virtual clock (cheap to clone; shared handle).
    pub fn clock(&self) -> VirtualClock {
        self.clock.clone()
    }

    /// Tasks admitted and not yet completed.
    pub fn live_tasks(&self) -> usize {
        self.live
    }

    /// Total polls performed (diagnostic; deterministic per run).
    pub fn polls(&self) -> u64 {
        self.polls
    }

    /// Admit a task from outside any poll (the harness root does this).
    pub fn spawn(&mut self, fut: impl Future<Output = ()> + 'static) {
        self.admit(Box::pin(fut));
    }

    fn admit(&mut self, fut: BoxFut) {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.slots.push(Slot { fut: None, gen: 0, waker: None });
                self.slots.len() - 1
            }
        };
        let slot = &mut self.slots[id];
        slot.gen += 1;
        slot.fut = Some(fut);
        let waker = Arc::new(TaskWaker {
            id,
            gen: slot.gen,
            queued: AtomicBool::new(true),
            ready: self.ready.clone(),
        });
        slot.waker = Some(waker);
        self.live += 1;
        lock_or_poison(&self.ready.q).push_back((id, slot.gen));
    }

    fn admit_spawned(&mut self) {
        loop {
            let next = self.pending_spawns.borrow_mut().pop_front();
            match next {
                Some(fut) => self.admit(fut),
                None => break,
            }
        }
    }

    fn pop_ready(&mut self) -> Option<(usize, u64)> {
        lock_or_poison(&self.ready.q).pop_front()
    }

    fn poll_slot(&mut self, id: usize, gen: u64) {
        let (mut fut, waker_arc) = {
            let slot = &mut self.slots[id];
            if slot.gen != gen {
                return; // stale waker from a recycled slot
            }
            let Some(fut) = slot.fut.take() else {
                return; // duplicate queue entry for a finished task
            };
            // pa-lint: allow(unwrap): a live slot always carries its waker
            (fut, slot.waker.clone().expect("live slot has a waker"))
        };
        waker_arc.queued.store(false, Ordering::SeqCst);
        let waker = Waker::from(waker_arc);
        let mut cx = Context::from_waker(&waker);
        let guard = enter_ctx(self.pending_spawns.clone(), self.clock.clone());
        self.polls += 1;
        let done = fut.as_mut().poll(&mut cx).is_ready();
        drop(guard);
        let slot = &mut self.slots[id];
        if done {
            slot.waker = None;
            self.free.push(id);
            self.live -= 1;
        } else {
            slot.fut = Some(fut);
        }
        self.admit_spawned();
    }

    /// One unit of progress bounded by virtual time `deadline`: poll the
    /// next ready task, or — when none is ready — advance the clock to the
    /// next timer due at or before `deadline` and wake its sleepers.
    /// Returns `false` when neither is possible (quiescent up to
    /// `deadline`); the clock is then still at its last event, and the
    /// caller decides whether to advance further.
    pub fn step(&mut self, deadline: f64) -> bool {
        self.admit_spawned();
        if let Some((id, gen)) = self.pop_ready() {
            self.poll_slot(id, gen);
            return true;
        }
        let woke = self.clock.fire_next(deadline);
        if woke.is_empty() {
            return false;
        }
        for w in woke {
            w.wake();
        }
        true
    }

    /// Run to quiescence: poll and advance until no task is ready and no
    /// timer is pending. Returns the number of progress steps taken.
    pub fn run(&mut self) -> u64 {
        let mut steps = 0;
        while self.step(f64::INFINITY) {
            steps += 1;
        }
        steps
    }

    /// Run until quiescent *up to* virtual time `deadline`, then advance the
    /// clock exactly to `deadline` (timers beyond it stay pending).
    pub fn run_until(&mut self, deadline: f64) {
        while self.step(deadline) {}
        if self.clock.now() < deadline {
            self.clock.advance_to(deadline);
        }
    }
}

// ---------------------------------------------------------------------------
// Deterministic async channels
// ---------------------------------------------------------------------------

/// `try_recv` outcome (mirrors `mpsc::TryRecvError` plus the item).
pub enum TryRecv<T> {
    Item(T),
    Empty,
    /// Every sender dropped and the buffer is empty.
    Closed,
}

/// `try_send` failure.
#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

/// The receiver dropped; the value is returned to the caller.
#[derive(Debug)]
pub struct SendError<T>(pub T);

struct ChanInner<T> {
    buf: VecDeque<T>,
    cap: Option<usize>,
    recv_wakers: Vec<Waker>,
    send_wakers: Vec<Waker>,
    senders: usize,
    rx_alive: bool,
}

impl<T> ChanInner<T> {
    fn wake_receivers(&mut self) {
        for w in self.recv_wakers.drain(..) {
            w.wake();
        }
    }

    fn wake_senders(&mut self) {
        for w in self.send_wakers.drain(..) {
            w.wake();
        }
    }
}

/// Sending half of a deterministic executor channel ([`channel`]).
pub struct SimSender<T>(Rc<RefCell<ChanInner<T>>>);

/// Receiving half of a deterministic executor channel ([`channel`]).
pub struct SimReceiver<T>(Rc<RefCell<ChanInner<T>>>);

/// A deterministic single-threaded mpsc channel for executor tasks.
/// `cap = None` is unbounded (the control inboxes); `cap = Some(n)` bounds
/// the buffer and parks senders when full (the rollout queue's
/// backpressure). All wake-ups are FIFO and deterministic.
pub fn channel<T>(cap: Option<usize>) -> (SimSender<T>, SimReceiver<T>) {
    if let Some(n) = cap {
        assert!(n > 0, "rendezvous (cap 0) channels are not supported");
    }
    let inner = Rc::new(RefCell::new(ChanInner {
        buf: VecDeque::new(),
        cap,
        recv_wakers: Vec::new(),
        send_wakers: Vec::new(),
        senders: 1,
        rx_alive: true,
    }));
    (SimSender(inner.clone()), SimReceiver(inner))
}

impl<T> Clone for SimSender<T> {
    fn clone(&self) -> Self {
        self.0.borrow_mut().senders += 1;
        SimSender(self.0.clone())
    }
}

impl<T> Drop for SimSender<T> {
    fn drop(&mut self) {
        let mut inner = self.0.borrow_mut();
        inner.senders -= 1;
        if inner.senders == 0 {
            inner.wake_receivers();
        }
    }
}

impl<T> Drop for SimReceiver<T> {
    fn drop(&mut self) {
        let mut inner = self.0.borrow_mut();
        inner.rx_alive = false;
        inner.wake_senders();
    }
}

impl<T> SimSender<T> {
    /// Async send: parks on a full bounded channel until the receiver makes
    /// room; errors (returning the value) when the receiver is gone.
    pub fn send(&self, v: T) -> SendFut<T> {
        SendFut { chan: self.0.clone(), v: Some(v) }
    }

    /// Non-blocking send (for the harness root, which is not a task).
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        let mut inner = self.0.borrow_mut();
        if !inner.rx_alive {
            return Err(TrySendError::Closed(v));
        }
        if let Some(cap) = inner.cap {
            if inner.buf.len() >= cap {
                return Err(TrySendError::Full(v));
            }
        }
        inner.buf.push_back(v);
        inner.wake_receivers();
        Ok(())
    }
}

impl<T> SimReceiver<T> {
    /// Async receive: `None` once every sender dropped and the buffer is
    /// empty.
    pub fn recv(&self) -> RecvFut<T> {
        RecvFut { chan: self.0.clone() }
    }

    /// Non-blocking receive (for the harness root, which is not a task).
    pub fn try_recv(&self) -> TryRecv<T> {
        let mut inner = self.0.borrow_mut();
        match inner.buf.pop_front() {
            Some(v) => {
                inner.wake_senders();
                TryRecv::Item(v)
            }
            None if inner.senders == 0 => TryRecv::Closed,
            None => TryRecv::Empty,
        }
    }

    /// Items currently buffered.
    pub fn len(&self) -> usize {
        self.0.borrow().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Future returned by [`SimSender::send`].
pub struct SendFut<T> {
    chan: Rc<RefCell<ChanInner<T>>>,
    v: Option<T>,
}

impl<T> Future for SendFut<T> {
    type Output = Result<(), SendError<T>>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        // Safety not needed: no structural pinning (T is not pinned data).
        let this = unsafe { self.get_unchecked_mut() };
        let mut inner = this.chan.borrow_mut();
        let v = match this.v.take() {
            Some(v) => v,
            None => return Poll::Ready(Ok(())), // polled after completion
        };
        if !inner.rx_alive {
            return Poll::Ready(Err(SendError(v)));
        }
        if let Some(cap) = inner.cap {
            if inner.buf.len() >= cap {
                this.v = Some(v);
                inner.send_wakers.push(cx.waker().clone());
                return Poll::Pending;
            }
        }
        inner.buf.push_back(v);
        inner.wake_receivers();
        Poll::Ready(Ok(()))
    }
}

/// Future returned by [`SimReceiver::recv`].
pub struct RecvFut<T> {
    chan: Rc<RefCell<ChanInner<T>>>,
}

impl<T> Future for RecvFut<T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut inner = self.chan.borrow_mut();
        match inner.buf.pop_front() {
            Some(v) => {
                inner.wake_senders();
                Poll::Ready(Some(v))
            }
            None if inner.senders == 0 => Poll::Ready(None),
            None => {
                inner.recv_wakers.push(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_run_in_spawn_order_to_quiescence() {
        let mut ex = Executor::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..4 {
            let log = log.clone();
            ex.spawn(async move {
                log.borrow_mut().push(i);
            });
        }
        assert_eq!(ex.live_tasks(), 4);
        ex.run();
        assert_eq!(ex.live_tasks(), 0);
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn virtual_time_fires_timers_in_deadline_then_registration_order() {
        let mut ex = Executor::new();
        let clock = ex.clock();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (name, dt) in [("late", 2.0), ("early", 1.0), ("tie-a", 1.5), ("tie-b", 1.5)] {
            let log = log.clone();
            let clock = clock.clone();
            ex.spawn(async move {
                clock.sleep(dt).await;
                log.borrow_mut().push((name, now()));
            });
        }
        ex.run();
        let got = log.borrow().clone();
        assert_eq!(
            got,
            vec![("early", 1.0), ("tie-a", 1.5), ("tie-b", 1.5), ("late", 2.0)]
        );
        assert_eq!(clock.now(), 2.0, "clock rests at the last event");
    }

    #[test]
    fn scoped_context_spawns_and_sleeps() {
        let mut ex = Executor::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        {
            let log = log.clone();
            ex.spawn(async move {
                let inner_log = log.clone();
                spawn(async move {
                    sleep(0.5).await;
                    inner_log.borrow_mut().push(("child", now()));
                });
                log.borrow_mut().push(("parent", now()));
            });
        }
        ex.run();
        assert_eq!(*log.borrow(), vec![("parent", 0.0), ("child", 0.5)]);
    }

    #[test]
    #[should_panic(expected = "outside an executor poll")]
    fn context_is_unavailable_outside_polls() {
        now();
    }

    #[test]
    fn bounded_channel_applies_backpressure_and_closes() {
        let mut ex = Executor::new();
        let (tx, rx) = channel::<u32>(Some(2));
        let sent = Rc::new(RefCell::new(Vec::new()));
        {
            let sent = sent.clone();
            ex.spawn(async move {
                for i in 0..5 {
                    tx.send(i).await.expect("receiver alive");
                    sent.borrow_mut().push(i);
                }
            });
        }
        // Fill the buffer: the producer parks after 2 sends.
        while ex.step(f64::INFINITY) {}
        assert_eq!(*sent.borrow(), vec![0, 1], "cap-2 channel parks the third send");
        // Each receive frees a slot and re-wakes the producer.
        let mut got = Vec::new();
        loop {
            match rx.try_recv() {
                TryRecv::Item(v) => got.push(v),
                TryRecv::Empty => {
                    if !ex.step(f64::INFINITY) {
                        break;
                    }
                }
                TryRecv::Closed => break,
            }
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(ex.live_tasks(), 0);
    }

    #[test]
    fn send_to_dropped_receiver_errors() {
        let mut ex = Executor::new();
        let (tx, rx) = channel::<u32>(None);
        drop(rx);
        let saw_err = Rc::new(RefCell::new(false));
        {
            let saw_err = saw_err.clone();
            ex.spawn(async move {
                if tx.send(1).await.is_err() {
                    *saw_err.borrow_mut() = true;
                }
            });
        }
        ex.run();
        assert!(*saw_err.borrow());
    }

    #[test]
    fn run_until_advances_clock_without_skipping_timers() {
        let mut ex = Executor::new();
        let clock = ex.clock();
        let fired = Rc::new(RefCell::new(false));
        {
            let fired = fired.clone();
            ex.spawn(async move {
                sleep(10.0).await;
                *fired.borrow_mut() = true;
            });
        }
        ex.run_until(5.0);
        assert_eq!(clock.now(), 5.0);
        assert!(!*fired.borrow(), "timer beyond the bound must not fire");
        ex.run_until(10.0);
        assert!(*fired.borrow());
    }

    #[test]
    fn identical_programs_produce_identical_poll_counts() {
        let run = || {
            let mut ex = Executor::new();
            let (tx, rx) = channel::<u64>(Some(3));
            for i in 0..8u64 {
                let tx = tx.clone();
                ex.spawn(async move {
                    sleep(0.1 * (i % 4) as f64).await;
                    let _ = tx.send(i).await;
                });
            }
            drop(tx);
            let order = Rc::new(RefCell::new(Vec::new()));
            {
                let order = order.clone();
                ex.spawn(async move {
                    while let Some(v) = rx.recv().await {
                        order.borrow_mut().push(v);
                    }
                });
            }
            ex.run();
            (ex.polls(), order.borrow().clone())
        };
        let (p1, o1) = run();
        let (p2, o2) = run();
        assert_eq!(p1, p2, "poll count must be deterministic");
        assert_eq!(o1, o2, "delivery order must be deterministic");
        assert_eq!(o1.len(), 8);
    }
}
