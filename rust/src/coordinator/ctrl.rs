//! Execution-agnostic coordinator control plane.
//!
//! The driver's dispatch/drain/join/route bookkeeping, extracted so the
//! *same* code runs in two harnesses (docs/CONCURRENCY.md "Deterministic
//! coordinator"):
//!
//! * **Real threads** (the default): [`Driver`](super::Driver) owns worker
//!   threads and real shim channels, and feeds this module through
//!   [`ChannelSource`]. Pure code movement — bit-identical to the
//!   pre-refactor driver at default knobs.
//! * **Simulated fleets**: [`crate::sim::fleet`] owns mock engine tasks on
//!   the deterministic executor ([`super::exec`]) and feeds this module
//!   through a virtual-time [`RolloutSource`], driving 1000-engine fleets
//!   from seeded, replayable schedules.
//!
//! Everything here is single-threaded state machine + the two protocol
//! loops the driver used to inline: the liveness-checked queue receive
//! ([`recv_step`]) and the drain-ack pump ([`pump_drain_ack`]). Timeouts
//! are seconds-based constants shared by both paths, so the stall watchdog
//! and phase attribution produce identical numbers on simulated and real
//! runs.

use super::messages::{DrainAck, GenJob, ScoredRollout};
use super::route;
use anyhow::{bail, Result};

/// Queue-receive poll window (the real path's 100 ms `recv_timeout`; the
/// simulated path advances virtual time by the same amount).
pub const RECV_POLL_S: f64 = 0.1;

/// Drain-ack pump poll window: while waiting for a draining engine's ack,
/// the control loop keeps consuming rollouts in slices of this long so a
/// full queue cannot deadlock the handshake.
pub const DRAIN_PUMP_POLL_S: f64 = 0.002;

/// Bounded per-reply wait for `QueryStats` while diagnosing a possibly
/// wedged fleet (the stall watchdog's snapshot must not hang). Shared by
/// the real path (`Driver::worker_stats_timeout`) and the simulated fleet,
/// so both report the same responsiveness picture.
pub const STATS_REPLY_TIMEOUT_S: f64 = 0.2;

/// One-shot stall detector over the rollout queue (`metrics.stall_timeout_s`,
/// default off). The control loop accounts every consecutive receive timeout
/// into it and resets it whenever a rollout arrives; when the accumulated
/// silence first crosses the window the watchdog latches and the driver dumps
/// a single diagnostic snapshot (stderr + `stall_snapshot.json`) instead of
/// hanging silently. It never fires twice and never aborts the run — a
/// stalled queue may still resolve (e.g. a slow first-iteration compile),
/// and the all-workers-dead liveness check handles true death. Pure
/// accounting over caller-measured durations, so real (`Instant`) and
/// virtual clocks drive it identically.
#[derive(Debug, Clone)]
pub struct StallWatchdog {
    timeout_s: f64,
    stalled_s: f64,
    fired: bool,
}

impl StallWatchdog {
    pub fn new(timeout_s: f64) -> StallWatchdog {
        StallWatchdog { timeout_s, stalled_s: 0.0, fired: false }
    }

    /// Account `dt` seconds of consecutive queue silence. Returns `true`
    /// exactly once — when the accumulated stall first crosses the window.
    pub fn note_timeout(&mut self, dt: f64) -> bool {
        self.stalled_s += dt;
        if !self.fired && self.stalled_s >= self.timeout_s {
            self.fired = true;
            return true;
        }
        false
    }

    /// A rollout arrived: the pipeline is alive. Resets the stall clock;
    /// the one-shot latch stays latched.
    pub fn note_progress(&mut self) {
        self.stalled_s = 0.0;
    }

    /// Seconds of consecutive queue silence accumulated so far.
    pub fn stalled_s(&self) -> f64 {
        self.stalled_s
    }

    /// Whether the one-shot snapshot has already fired.
    pub fn fired(&self) -> bool {
        self.fired
    }
}

/// Outcome of one bounded queue poll.
pub enum QueuePoll {
    Rollout(ScoredRollout),
    /// Nothing arrived within the window; `waited_s` is how long the caller
    /// actually waited (measured — real elapsed time or virtual advance),
    /// which is what the watchdog accounts.
    TimedOut { waited_s: f64 },
}

/// The control loop's view of the rollout queue plus worker liveness —
/// the seam between the shared protocol loops and the execution substrate.
/// [`ChannelSource`] implements it over real shim channels and thread
/// handles; the simulated fleet implements it over executor channels and
/// virtual time.
pub trait RolloutSource {
    /// Wait up to `timeout_s` for the next scored rollout. Implementations
    /// may return early, but must report the waited duration faithfully.
    /// `Err` means the substrate itself failed (the simulator uses this for
    /// its silence cap — "drains always terminate" violations).
    fn poll(&mut self, timeout_s: f64) -> Result<QueuePoll>;

    /// True when every worker has exited — queue silence is then permanent.
    fn workers_dead(&mut self) -> bool;
}

/// One step of the liveness-checked blocking receive.
pub enum RecvStep {
    Got(ScoredRollout),
    /// The poll window elapsed without a rollout. `watchdog_fired` is true
    /// exactly once per watchdog — the caller dumps its diagnostic snapshot
    /// *during* the wait, not after it resolves.
    Waiting { watchdog_fired: bool },
}

/// One poll of the driver's blocking queue receive, shared by the real and
/// simulated paths. The driver holds a producer handle itself (for
/// joiners), so channel disconnection can no longer signal worker death —
/// instead every timed-out poll checks liveness explicitly and fails once
/// all workers have exited with work still owed.
pub fn recv_step<S: RolloutSource>(
    src: &mut S,
    watchdog: &mut Option<StallWatchdog>,
    timeout_s: f64,
) -> Result<RecvStep> {
    match src.poll(timeout_s)? {
        QueuePoll::Rollout(r) => {
            if let Some(w) = watchdog {
                w.note_progress();
            }
            Ok(RecvStep::Got(r))
        }
        QueuePoll::TimedOut { waited_s } => {
            if src.workers_dead() {
                bail!("all engine workers exited with work outstanding");
            }
            let fired = match watchdog {
                Some(w) => w.note_timeout(waited_s),
                None => false,
            };
            Ok(RecvStep::Waiting { watchdog_fired: fired })
        }
    }
}

/// One non-blocking probe of a drain-ack channel.
pub enum AckPoll {
    Ready(Box<DrainAck>),
    Pending,
    /// The ack sender dropped without sending — the engine died mid-drain.
    Gone,
}

/// The drain handshake's pump loop, shared by the real and simulated paths:
/// probe the ack channel; while it is pending, keep consuming rollouts (the
/// draining engine may be blocked publishing its last completions into a
/// full queue). Rollouts consumed during the pump are returned for the
/// caller to ingest. A dropped ack channel surfaces as an error — a worker
/// dying mid-drain must never hang the control loop.
pub fn pump_drain_ack<S: RolloutSource>(
    src: &mut S,
    engine_idx: usize,
    mut try_ack: impl FnMut() -> AckPoll,
) -> Result<(DrainAck, Vec<ScoredRollout>)> {
    let mut pumped = Vec::new();
    loop {
        match try_ack() {
            AckPoll::Ready(ack) => return Ok((*ack, pumped)),
            AckPoll::Gone => bail!("engine-{engine_idx} exited without acking the drain"),
            AckPoll::Pending => {
                if let QueuePoll::Rollout(r) = src.poll(DRAIN_PUMP_POLL_S)? {
                    pumped.push(r);
                }
            }
        }
    }
}

/// [`RolloutSource`] over a real shim channel plus a worker-liveness probe
/// (the default execution substrate). `waited_s` is measured with the real
/// clock; any receive error (timeout or disconnect — the driver holds a
/// sender, so disconnect cannot happen in practice) reads as a timeout,
/// exactly as the pre-refactor driver treated it.
pub struct ChannelSource<'a, F: FnMut() -> bool> {
    pub rx: &'a crate::check::sync::mpsc::Receiver<ScoredRollout>,
    pub dead: F,
}

impl<F: FnMut() -> bool> RolloutSource for ChannelSource<'_, F> {
    fn poll(&mut self, timeout_s: f64) -> Result<QueuePoll> {
        let t0 = std::time::Instant::now();
        match self.rx.recv_timeout(std::time::Duration::from_secs_f64(timeout_s)) {
            Ok(r) => Ok(QueuePoll::Rollout(r)),
            Err(_) => Ok(QueuePoll::TimedOut { waited_s: t0.elapsed().as_secs_f64() }),
        }
    }

    fn workers_dead(&mut self) -> bool {
        (self.dead)()
    }
}

/// The fleet's routing + accounting state, independent of how engines run:
/// per-engine outstanding load, the warmth beliefs behind residency-aware
/// dispatch, the round-robin fallback, cumulative routing counters, the
/// global outstanding-jobs count and the request-id mint. The driver and the
/// simulated fleet both own one of these and drive it with the same calls in
/// the same order, which is what makes the simulation a faithful model of
/// the real control loop.
pub struct FleetCtrl {
    /// Prompt-affinity routing active (falls back to round-robin when off).
    affinity: bool,
    /// Spill threshold in jobs (`rl.affinity_slack_groups * rl.group_size`).
    slack: usize,
    /// Cache block size in tokens (the affinity-key granularity).
    cache_block: usize,
    /// Per-template warmth beliefs driving residency-aware dispatch.
    pub warmth: route::WarmthMap,
    /// Outstanding jobs per engine — the router's load signal.
    load: Vec<usize>,
    rr_next: usize,
    /// Cumulative routing counters (affinity / spilled groups).
    pub route_hits: u64,
    pub route_spills: u64,
    outstanding: usize,
    next_request_id: u64,
}

impl FleetCtrl {
    pub fn new(
        n_engines: usize,
        affinity: bool,
        warmth_ttl: u64,
        slack: usize,
        cache_block: usize,
    ) -> FleetCtrl {
        FleetCtrl {
            affinity,
            slack,
            cache_block,
            warmth: route::WarmthMap::with_ttl(warmth_ttl),
            load: vec![0; n_engines],
            rr_next: 0,
            route_hits: 0,
            route_spills: 0,
            outstanding: 0,
            next_request_id: 0,
        }
    }

    /// Engines currently in the routing pool.
    pub fn engines(&self) -> usize {
        self.load.len()
    }

    /// Outstanding jobs per engine (the stall snapshot's fleet picture).
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// Jobs dispatched and not yet ingested, fleet-wide.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Re-evaluate the affinity gate after a fleet resize.
    pub fn set_affinity(&mut self, on: bool) {
        self.affinity = on;
    }

    /// Mint the next driver-global request id.
    pub fn mint_request_id(&mut self) -> u64 {
        let id = self.next_request_id;
        self.next_request_id += 1;
        id
    }

    /// Choose the engine for one group over the current fleet: residency-
    /// aware routing when affinity is active, the round-robin pin otherwise.
    /// `count_route` separates fresh dispatches (counted as affinity
    /// hits/spills) from drain re-routes (bookkeeping moves of groups that
    /// already counted once). `store_resident` prices spills from the shared
    /// store's coverage; it is only consulted when affinity is active.
    pub fn pick_engine(
        &mut self,
        prompt_tokens: &[u32],
        count_route: bool,
        store_resident: impl FnOnce() -> usize,
    ) -> usize {
        if self.affinity {
            // Residency-aware dispatch: prefer the engine the warmth map
            // proves warm, consult the store's coverage to price spills.
            let (idx, kind) = route::route_group_residency(
                prompt_tokens,
                self.cache_block,
                &self.load,
                self.slack,
                &self.warmth,
                store_resident(),
            );
            if count_route {
                if kind.is_spill() {
                    self.route_spills += 1;
                } else {
                    self.route_hits += 1;
                }
            }
            // Whoever admits the group becomes the template's warm home.
            let (key, alen) = route::affinity_key(prompt_tokens, self.cache_block);
            self.warmth.note(key, idx, alen);
            idx
        } else {
            let idx = self.rr_next % self.load.len();
            self.rr_next += 1;
            idx
        }
    }

    /// Account a fresh dispatch of `n` jobs to engine `idx`.
    pub fn note_dispatch(&mut self, idx: usize, n: usize) {
        self.load[idx] += n;
        self.outstanding += n;
    }

    /// Account a drain re-route of `n` jobs to engine `idx`. Re-routed jobs
    /// are already outstanding — only the load signal moves.
    pub fn note_reroute(&mut self, idx: usize, n: usize) {
        self.load[idx] += n;
    }

    /// Book-keep one scored rollout off the queue. The engine-load index is
    /// guarded: a drained engine's last completions arrive tagged with an
    /// index that is no longer in the fleet. The global outstanding count is
    /// *not* guarded — ingesting more than was dispatched is a control-loop
    /// bug (it underflows loudly in debug builds).
    pub fn note_ingest(&mut self, engine_idx: usize) {
        self.outstanding -= 1;
        if let Some(load) = self.load.get_mut(engine_idx) {
            *load = load.saturating_sub(1);
        }
    }

    /// Grow the routing pool by one engine (index = new length - 1).
    pub fn add_engine(&mut self) {
        self.load.push(0);
    }

    /// Shrink the routing pool from the tail (the convention the warmth
    /// map's index compaction assumes), rebalancing warmth beliefs over the
    /// survivors. Returns the departed engine's index.
    pub fn remove_tail_engine(&mut self) -> usize {
        let idx = self.load.len() - 1;
        self.load.pop();
        self.warmth.remove_engine(idx, self.load.len());
        idx
    }

    /// Re-route a drained engine's returned jobs over the survivors,
    /// group-affine: jobs regroup by prompt (first-seen order) and each
    /// group lands whole on one engine through the same residency-aware
    /// routing as a fresh batch — without recounting as an affinity hit and
    /// without touching the outstanding count (the jobs never stopped being
    /// outstanding). Returns `(target engine, jobs)` per group for the
    /// caller to deliver.
    pub fn reroute_drained(
        &mut self,
        pending: Vec<GenJob>,
        store_resident: impl Fn(&[u32]) -> usize,
    ) -> Vec<(usize, Vec<GenJob>)> {
        super::driver::group_jobs_by_prompt(pending)
            .into_iter()
            .map(|jobs| {
                let prompt = jobs[0].request.prompt.clone();
                let target = self.pick_engine(&prompt, false, || store_resident(&prompt));
                self.note_reroute(target, jobs.len());
                (target, jobs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::sync::mpsc;
    use crate::coordinator::messages::WorkerStats;
    use crate::engine::{EngineStats, GenRequest};

    fn rollout(request_id: u64, engine_idx: usize) -> ScoredRollout {
        ScoredRollout {
            request_id,
            prompt_id: 0,
            sample_idx: 0,
            weight_version: 0,
            tokens: vec![1],
            logprobs: vec![0.0],
            reward: 0.0,
            gen_seconds: 0.0,
            engine_idx,
            timeline: Default::default(),
        }
    }

    /// A scripted RolloutSource: a queue of poll outcomes plus a liveness
    /// flag, no threads involved.
    struct Scripted {
        polls: std::collections::VecDeque<QueuePoll>,
        dead: bool,
    }

    impl RolloutSource for Scripted {
        fn poll(&mut self, timeout_s: f64) -> Result<QueuePoll> {
            Ok(self
                .polls
                .pop_front()
                .unwrap_or(QueuePoll::TimedOut { waited_s: timeout_s }))
        }
        fn workers_dead(&mut self) -> bool {
            self.dead
        }
    }

    #[test]
    fn recv_step_surfaces_worker_death_instead_of_hanging() {
        let mut src = Scripted { polls: Default::default(), dead: true };
        let err = recv_step(&mut src, &mut None, RECV_POLL_S).unwrap_err();
        assert!(
            err.to_string().contains("all engine workers exited"),
            "got: {err}"
        );
    }

    #[test]
    fn recv_step_accounts_watchdog_and_resets_on_progress() {
        let mut src = Scripted { polls: Default::default(), dead: false };
        let mut wd = Some(StallWatchdog::new(0.25));
        // Two silent polls accumulate 0.2s; the third crosses 0.25 and fires
        // exactly once.
        for _ in 0..2 {
            match recv_step(&mut src, &mut wd, RECV_POLL_S).unwrap() {
                RecvStep::Waiting { watchdog_fired } => assert!(!watchdog_fired),
                RecvStep::Got(_) => panic!("nothing was queued"),
            }
        }
        match recv_step(&mut src, &mut wd, RECV_POLL_S).unwrap() {
            RecvStep::Waiting { watchdog_fired } => assert!(watchdog_fired),
            RecvStep::Got(_) => panic!("nothing was queued"),
        }
        // A rollout resets the stall clock (latch stays latched).
        src.polls.push_back(QueuePoll::Rollout(rollout(1, 0)));
        match recv_step(&mut src, &mut wd, RECV_POLL_S).unwrap() {
            RecvStep::Got(r) => assert_eq!(r.request_id, 1),
            RecvStep::Waiting { .. } => panic!("a rollout was queued"),
        }
        let wd = wd.unwrap();
        assert_eq!(wd.stalled_s(), 0.0);
        assert!(wd.fired());
    }

    #[test]
    fn recv_step_over_real_channel_reports_dead_workers() {
        // The real-path composition: a shim channel nobody feeds plus a
        // liveness probe that flips to dead, exactly how the driver wires
        // ChannelSource. Must error out, not spin forever.
        let (_tx, rx) = mpsc::sync_channel::<ScoredRollout>(4);
        let mut calls = 0;
        let mut src = ChannelSource {
            rx: &rx,
            dead: || {
                calls += 1;
                calls >= 2
            },
        };
        let mut wd = None;
        let err = loop {
            match recv_step(&mut src, &mut wd, 0.005) {
                Ok(RecvStep::Got(_)) => panic!("queue is never fed"),
                Ok(RecvStep::Waiting { .. }) => continue,
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("all engine workers exited"));
    }

    fn ack(pending: Vec<GenJob>) -> DrainAck {
        DrainAck { pending, stats: EngineStats::default(), cache: None }
    }

    #[test]
    fn pump_drain_ack_consumes_rollouts_until_ready() {
        let mut src = Scripted {
            polls: [
                QueuePoll::Rollout(rollout(7, 0)),
                QueuePoll::TimedOut { waited_s: DRAIN_PUMP_POLL_S },
                QueuePoll::Rollout(rollout(8, 1)),
            ]
            .into_iter()
            .collect(),
            dead: false,
        };
        let mut probes = 0;
        let (got, pumped) = pump_drain_ack(&mut src, 2, || {
            probes += 1;
            if probes <= 3 {
                AckPoll::Pending
            } else {
                AckPoll::Ready(Box::new(ack(Vec::new())))
            }
        })
        .unwrap();
        assert!(got.pending.is_empty());
        let ids: Vec<u64> = pumped.iter().map(|r| r.request_id).collect();
        assert_eq!(ids, vec![7, 8], "queue stays pumped while the ack is pending");
    }

    #[test]
    fn pump_drain_ack_errors_when_the_engine_dies() {
        let mut src = Scripted { polls: Default::default(), dead: false };
        let err = pump_drain_ack(&mut src, 5, || AckPoll::Gone).unwrap_err();
        assert!(err.to_string().contains("engine-5 exited without acking"));
    }

    #[test]
    fn fleet_ctrl_round_robin_and_outstanding_accounting() {
        let mut c = FleetCtrl::new(3, false, 0, 4, 16);
        let picks: Vec<usize> =
            (0..6).map(|_| c.pick_engine(&[1, 2], true, || 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!((c.route_hits, c.route_spills), (0, 0), "rr never counts routes");
        c.note_dispatch(1, 4);
        assert_eq!(c.outstanding(), 4);
        assert_eq!(c.load(), &[0, 4, 0]);
        c.note_ingest(1);
        c.note_ingest(99); // drained-engine index: load guarded, outstanding not
        assert_eq!(c.outstanding(), 2);
        assert_eq!(c.load(), &[0, 3, 0]);
        c.note_reroute(2, 2);
        assert_eq!(c.outstanding(), 2, "re-routes never re-count outstanding");
        assert_eq!(c.load(), &[0, 3, 2]);
    }

    #[test]
    fn fleet_ctrl_affinity_keeps_group_home_and_counts_routes() {
        let mut c = FleetCtrl::new(2, true, 0, 100, 4);
        let prompt: Vec<u32> = (0..16).collect();
        let first = c.pick_engine(&prompt, true, || 0);
        c.note_dispatch(first, 2);
        // Same template routes home while slack allows, and a drain re-route
        // (count_route = false) moves no counters.
        let (h0, s0) = (c.route_hits, c.route_spills);
        let again = c.pick_engine(&prompt, false, || 0);
        assert_eq!(again, first, "warm template stays home");
        assert_eq!((c.route_hits, c.route_spills), (h0, s0));
        let counted = c.pick_engine(&prompt, true, || 0);
        assert_eq!(counted, first);
        assert!(c.route_hits > h0);
    }

    #[test]
    fn fleet_ctrl_resize_round_trip() {
        let mut c = FleetCtrl::new(2, false, 0, 4, 16);
        c.add_engine();
        assert_eq!(c.engines(), 3);
        let departed = c.remove_tail_engine();
        assert_eq!(departed, 2);
        assert_eq!(c.engines(), 2);
    }

    #[test]
    fn reroute_drained_is_group_affine_and_load_accounted() {
        let mk = |prompt_id: u64, sample_idx: usize, rid: u64| GenJob {
            prompt_id,
            sample_idx,
            request: GenRequest {
                request_id: rid,
                prompt: vec![prompt_id as u32; 8],
                ..Default::default()
            },
            answer: 0,
        };
        let mut c = FleetCtrl::new(2, false, 0, 4, 16);
        c.note_dispatch(0, 4); // the jobs being returned were outstanding
        let routed = c.reroute_drained(
            vec![mk(1, 0, 10), mk(2, 0, 11), mk(1, 1, 12), mk(2, 1, 13)],
            |_| 0,
        );
        assert_eq!(routed.len(), 2, "two prompts, two group-affine deliveries");
        for (_, jobs) in &routed {
            assert!(jobs.windows(2).all(|w| w[0].prompt_id == w[1].prompt_id));
        }
        let total: usize = c.load().iter().sum();
        // 4 original minus nothing ingested, plus 4 re-routed onto survivors.
        assert_eq!(total, 8);
        assert_eq!(c.outstanding(), 4, "re-route leaves outstanding unchanged");
    }

    #[test]
    fn stats_reply_timeout_is_shared_and_bounded() {
        // Satellite guard: the bounded stats query and the sim fleet must
        // read the same constant, and it must stay well under the recv poll
        // cadence's order of magnitude (a stall dump may query hundreds of
        // engines).
        assert!(STATS_REPLY_TIMEOUT_S > 0.0 && STATS_REPLY_TIMEOUT_S <= 1.0);
        // Keep WorkerStats constructible without a live engine — the sim
        // fleet fabricates these.
        let ws = WorkerStats {
            engine_idx: 3,
            engine: EngineStats::default(),
            cache: None,
            warm: Vec::new(),
            pending: 0,
            active: 0,
        };
        assert_eq!(ws.engine_idx, 3);
    }
}
