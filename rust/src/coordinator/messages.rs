//! Messages flowing between the coordinator's threads.
//!
//! The simulated fleet mirrors this protocol one-for-one (`SimMsg` in
//! [`crate::sim::fleet`]) so the shared control loops in [`super::ctrl`]
//! exercise the same message shapes — `SetWeights`/`QueryStats`/`Drain`
//! acks included — against mock engines on the deterministic executor.

use crate::check::sync::{mpsc, Arc};
use crate::engine::{CacheStats, EngineStats, GenRequest};
use crate::metrics::RequestTimeline;
use crate::runtime::HostParams;
use crate::store::SharedKvStore;

/// Commands to an engine worker thread.
pub enum EngineMsg {
    /// Attach the coordinator's cross-engine shared segment store. Sent once
    /// per worker right after spawn (channel order guarantees it lands
    /// before any generation work); the engine then consults/feeds the
    /// store on every chunked admission.
    AttachStore(Arc<SharedKvStore>),
    /// Drop the shared store: the fleet shrank to one engine, whose local
    /// radix cache already covers everything the store could offer — the
    /// per-admission store round-trips would be pure overhead. A later
    /// join re-creates and re-attaches a store fleet-wide.
    DetachStore,
    /// Install new policy weights (iteration-boundary sync, Alg. 1 line 3).
    /// The worker acks on the provided channel once the upload completes
    /// (`uploaded: false` = no-op sync skipped on an identical version);
    /// the coordinator blocks on all acks before dispatching the batch.
    SetWeights(Arc<HostParams>, mpsc::Sender<WeightSyncAck>),
    /// Generate one rollout.
    Gen(Box<GenJob>),
    /// Generate a whole GRPO group's rollouts on this worker. Group-affine
    /// dispatch is what makes the engine's shared-prefix KV cache bite: the
    /// G requests share one prompt, so landing them on one engine turns G
    /// compiled prefills into 1 (the inference-side dual of SPA).
    GenGroup(Vec<GenJob>),
    /// Report engine + prefix-cache counters on the provided channel.
    QueryStats(mpsc::Sender<WorkerStats>),
    /// Leave the fleet gracefully: stop admitting, finish the in-flight
    /// sequences (their scored rollouts still flow through the shared
    /// queue), then reply with everything never admitted plus the final
    /// counters and exit. The coordinator re-routes the returned jobs over
    /// the surviving engines ([`super::Driver::drain_engine`]), so a mid-run
    /// departure loses no rollout.
    Drain(mpsc::Sender<DrainAck>),
    /// Exit immediately (end of run; any pending work is abandoned).
    Shutdown,
}

/// Reply to [`EngineMsg::Drain`], sent once the engine is idle.
pub struct DrainAck {
    /// Jobs the departing engine had queued but never admitted — still owed
    /// to the run; the coordinator re-dispatches them group-affine.
    pub pending: Vec<GenJob>,
    /// Final cumulative engine counters, folded into the coordinator's
    /// retired-engine baseline so per-iteration metric deltas stay exact
    /// after the engine stops reporting.
    pub stats: EngineStats,
    /// Final prefix-cache counters, when the cache was enabled.
    pub cache: Option<CacheStats>,
}

/// Acknowledgement of one worker's [`EngineMsg::SetWeights`].
#[derive(Debug, Clone, Copy)]
pub struct WeightSyncAck {
    /// False when the engine skipped a no-op sync (version already
    /// installed) and kept its prefix cache warm.
    pub uploaded: bool,
}

/// A generation job: the request plus everything the worker needs to score
/// the rollout on completion ("each coroutine independently evaluates the
/// reward", paper §4.2.1).
#[derive(Debug, Clone)]
pub struct GenJob {
    pub prompt_id: u64,
    pub sample_idx: usize,
    pub request: GenRequest,
    /// Ground-truth answer for the rule-based reward.
    pub answer: i64,
}

/// A scored rollout produced by an engine worker — the unit that travels
/// through the shared queue to the consumer.
#[derive(Debug, Clone)]
pub struct ScoredRollout {
    /// Driver-minted id (assigned deterministically at enqueue, before any
    /// routing decision): the key of the request's sampling stream, and the
    /// join key for cross-run determinism diffs.
    pub request_id: u64,
    pub prompt_id: u64,
    pub sample_idx: usize,
    pub weight_version: u64,
    pub tokens: Vec<u32>,
    pub logprobs: Vec<f32>,
    pub reward: f32,
    pub gen_seconds: f64,
    /// Which engine instance produced it (timeline lanes).
    pub engine_idx: usize,
    /// Lifecycle stamps accumulated along the request's path (all-unset in
    /// basic metrics mode); the consumer adds the train-consume stamp.
    pub timeline: RequestTimeline,
}

/// Cumulative counters snapshot from one engine worker (response to
/// [`EngineMsg::QueryStats`]).
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub engine_idx: usize,
    pub engine: EngineStats,
    /// Present when the worker's shared-prefix KV cache is enabled.
    pub cache: Option<CacheStats>,
    /// Warm-template advertisement: `(affinity key, resident tokens)` for
    /// each template prefix this engine's radix cache currently holds
    /// (probed non-mutatingly at query time). The coordinator folds these
    /// into its routing warmth map, so dispatch consults *actual* per-engine
    /// residency instead of hashing blindly.
    pub warm: Vec<(u64, usize)>,
    /// Jobs queued on the engine but not yet admitted to a decode slot —
    /// the per-engine backlog the stall watchdog snapshots.
    pub pending: usize,
    /// Sequences currently occupying decode slots (in flight right now).
    pub active: usize,
}
