//! Shared-prefix KV cache — the inference-side dual of shared-prompt
//! attention.
//!
//! The paper's SPA kernel computes each GRPO group's common prompt **once**
//! on the training side; this subsystem does the same for the inference
//! engine's prefill. The engine's compiled `prefill` artifact writes a
//! prompt's KV rows into the monolithic host-resident cache tensor
//! (`[layers, slots, 2, cache_len, kv_heads, head_dim]`), and those rows
//! depend only on the prompt tokens and the weights — never on the slot — so
//! they are relocatable. On admission the engine first consults this cache:
//!
//! * **miss** — run the compiled `prefill`, then copy the prompt's KV rows
//!   (and the last-position logits) into ref-counted pool blocks indexed by a
//!   radix tree over token prefixes ([`radix`], [`blocks`]);
//! * **full hit** — copy the cached rows into the claimed slot (a private
//!   fork of the shared prefix: decode appends beyond `prompt_len` without
//!   ever touching cache memory), sample the first token from the cached
//!   logits, and **skip the compiled `prefill` entirely**;
//! * **partial hit** ([`PrefixCache::match_prefix`]) — copy the rows of the
//!   *longest cached prefix* into the slot and let chunked admission prefill
//!   only the uncached suffix, publishing each completed chunk back via
//!   [`PrefixCache::insert_prefix`] so concurrent group members and future
//!   prompts resume from it.
//!
//! A G-rollout GRPO group therefore triggers exactly one compiled prefill:
//! prefill cost scales with *unique prompts*, not total rollouts, and the
//! prompt-token hit rate on grouped traffic approaches `(G-1)/G`. With
//! chunked admission the same holds across *different* prompts sharing a
//! few-shot template: prefill compute scales with uncached suffix tokens.
//!
//! Consistency: cached KV/logits are functions of the weights, so
//! [`PrefixCache::clear`] must run on every weight sync (the engine does this
//! inside `set_weights`, which already requires idleness). Leases taken by
//! in-flight requests pin their prefix against eviction until retirement and
//! are epoch-tagged so a flush cannot be corrupted by a stale release.

pub mod blocks;
pub mod radix;
pub mod stats;

pub use radix::EvictPolicy;
pub use stats::CacheStats;

use anyhow::{bail, Result};
use blocks::BlockPool;
use radix::RadixTree;

/// Geometry of the engine's KV-cache tensor
/// `[n_layers, n_slots, 2, cache_len, kv_heads, head_dim]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_slots: usize,
    pub cache_len: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
}

impl KvGeometry {
    /// Derive the geometry from the manifest's KV-cache shape.
    pub fn from_kv_shape(shape: &[usize]) -> Result<KvGeometry> {
        let [l, s, two, c, h, d] = shape else {
            bail!("kv cache shape {shape:?} is not 6-dimensional");
        };
        if *two != 2 {
            bail!("kv cache shape {shape:?}: expected K/V pair axis of 2");
        }
        Ok(KvGeometry { n_layers: *l, n_slots: *s, cache_len: *c, kv_heads: *h, head_dim: *d })
    }

    /// f32 elements in one token row (every layer's K and V vectors).
    pub fn row_elems(&self) -> usize {
        self.n_layers * 2 * self.kv_heads * self.head_dim
    }

    /// Element offset of `(layer, slot, k_or_v, position)` in the flat tensor.
    fn chunk_offset(&self, layer: usize, slot: usize, pair: usize, pos: usize) -> usize {
        ((((layer * self.n_slots + slot) * 2) + pair) * self.cache_len + pos)
            * self.kv_heads
            * self.head_dim
    }
}

/// Copy the first `n_tokens` KV rows of `slot` out of the flat cache tensor,
/// token-major (`[token][layer][k/v][kv_heads * head_dim]`).
pub fn gather_prompt_rows(kv: &[f32], g: &KvGeometry, slot: usize, n_tokens: usize) -> Vec<f32> {
    gather_rows_range(kv, g, slot, 0, n_tokens)
}

/// Copy KV rows for positions `[start, end)` of `slot`, token-major. Chunked
/// admission appends each freshly prefilled chunk's rows to its running
/// prefix buffer with this, so publication copies every row once instead of
/// re-gathering the whole prefix per chunk.
pub fn gather_rows_range(
    kv: &[f32],
    g: &KvGeometry,
    slot: usize,
    start: usize,
    end: usize,
) -> Vec<f32> {
    let chunk = g.kv_heads * g.head_dim;
    let mut out = Vec::with_capacity(end.saturating_sub(start) * g.row_elems());
    for pos in start..end {
        for layer in 0..g.n_layers {
            for pair in 0..2 {
                let o = g.chunk_offset(layer, slot, pair, pos);
                out.extend_from_slice(&kv[o..o + chunk]);
            }
        }
    }
    out
}

/// Inverse of [`gather_prompt_rows`]: write token-major rows into `slot`'s
/// positions `[0, rows / row_elems)` of the flat cache tensor.
pub fn scatter_prompt_rows(kv: &mut [f32], g: &KvGeometry, slot: usize, rows: &[f32]) {
    let chunk = g.kv_heads * g.head_dim;
    let row_elems = g.row_elems();
    assert_eq!(rows.len() % row_elems, 0, "ragged row scatter");
    for pos in 0..rows.len() / row_elems {
        let row = &rows[pos * row_elems..(pos + 1) * row_elems];
        for layer in 0..g.n_layers {
            for pair in 0..2 {
                let o = g.chunk_offset(layer, slot, pair, pos);
                let r = (layer * 2 + pair) * chunk;
                kv[o..o + chunk].copy_from_slice(&row[r..r + chunk]);
            }
        }
    }
}

/// Cache sizing/eviction knobs (validated by `config::Config`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixCacheCfg {
    /// Tokens per pool block.
    pub block_tokens: usize,
    /// Pool capacity in blocks.
    pub capacity_blocks: usize,
    pub policy: EvictPolicy,
}

/// Pin on a cached prefix held by one in-flight request; returned on a hit or
/// an insert and released when the request retires. Epoch-tagged: releases
/// that outlive a [`PrefixCache::clear`] are ignored.
#[derive(Debug)]
pub struct Lease {
    node: usize,
    epoch: u64,
}

/// A full-prompt hit: everything the engine needs to admit the request
/// without running the compiled prefill.
#[derive(Debug)]
pub struct PrefixHit {
    /// Token-major KV rows for the whole prompt (scatter into the slot).
    pub rows: Vec<f32>,
    /// Last-position logits (sample the first response token on the host).
    pub logits: Vec<f32>,
    pub lease: Lease,
}

/// Longest-cached-prefix match ([`PrefixCache::match_prefix`]): the
/// restorable rows plus everything chunked admission needs to resume.
#[derive(Debug)]
pub struct PrefixMatch {
    /// Prompt tokens covered by cached nodes (node-boundary granularity);
    /// 0 on a complete miss.
    pub matched: usize,
    /// Token-major KV rows for positions `[0, matched)`.
    pub rows: Vec<f32>,
    /// Last-position logits — present only when `matched == prompt_len` and
    /// a complete cached prompt ends exactly there (a *full* hit).
    pub logits: Option<Vec<f32>>,
    /// Pin on the deepest matched node; `None` when nothing matched.
    pub lease: Option<Lease>,
}

/// The prefix cache: radix index + block pool + counters.
#[derive(Debug)]
pub struct PrefixCache {
    geom: KvGeometry,
    tree: RadixTree,
    pool: BlockPool,
    pub stats: CacheStats,
    epoch: u64,
    /// Params version the cached KV was computed under (cache-generation
    /// tag): [`PrefixCache::set_params_version`] flushes only on a real
    /// bump, so a no-op weight sync keeps frozen prompt templates warm.
    params_version: Option<u64>,
}

impl PrefixCache {
    pub fn new(geom: KvGeometry, cfg: PrefixCacheCfg) -> PrefixCache {
        let pool = BlockPool::new(cfg.capacity_blocks, cfg.block_tokens, geom.row_elems());
        PrefixCache {
            geom,
            tree: RadixTree::new(cfg.policy),
            pool,
            stats: CacheStats::default(),
            epoch: 0,
            params_version: None,
        }
    }

    pub fn geometry(&self) -> &KvGeometry {
        &self.geom
    }

    pub fn live_blocks(&self) -> usize {
        self.pool.live_count()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.pool.capacity()
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    /// Look up a prompt on admission. Only a full-length, node-boundary match
    /// with cached logits counts as a hit — the compiled prefill is
    /// monolithic, so a partial prefix cannot save the call. Every prompt
    /// token is accounted to exactly one of `hit_tokens` / `miss_tokens`.
    pub fn match_prompt(&mut self, seq: &[u32]) -> Option<PrefixHit> {
        self.stats.lookups += 1;
        let m = self.tree.lookup(seq);
        let full = m
            .terminal
            .filter(|&t| m.matched == seq.len() && self.tree.logits(t).is_some());
        match full {
            Some(t) => {
                let rows = self.tree.path_rows(t, &self.pool);
                // pa-lint: allow(unwrap): `full` filtered on logits(t).is_some()
                let logits = self.tree.logits(t).unwrap().to_vec();
                self.tree.acquire(t);
                self.stats.hits += 1;
                self.stats.hit_tokens += seq.len() as u64;
                self.stats.bytes_saved +=
                    (seq.len() * self.geom.row_elems() * std::mem::size_of::<f32>()) as u64;
                Some(PrefixHit { rows, logits, lease: Lease { node: t, epoch: self.epoch } })
            }
            None => {
                self.stats.misses += 1;
                self.stats.miss_tokens += seq.len() as u64;
                None
            }
        }
    }

    /// Longest-prefix lookup for chunked admission. Restorable coverage is
    /// token-granular — a match may end partway into a cached fragment; when
    /// the whole prompt is covered *and* terminal logits are cached at that
    /// exact boundary, the result is a full hit and no compiled call is
    /// needed at all. Every prompt token is accounted to exactly one of
    /// `hit_tokens` (restored from cache) / `miss_tokens` (left for the
    /// compiled prefill).
    pub fn match_prefix(&mut self, seq: &[u32]) -> PrefixMatch {
        self.stats.lookups += 1;
        let (node, matched) = self.tree.lookup_longest(seq);
        if matched == 0 {
            self.stats.misses += 1;
            self.stats.miss_tokens += seq.len() as u64;
            return PrefixMatch { matched: 0, rows: Vec::new(), logits: None, lease: None };
        }
        let logits = if matched == seq.len() && self.tree.path_tokens(node) == matched {
            self.tree.logits(node).map(<[f32]>::to_vec)
        } else {
            None
        };
        // Tokens actually restored: all of them on a full hit; otherwise the
        // prompt's last position is always recomputed (its logits are not
        // cached), mirroring the engine's resume point — so hit/miss
        // accounting reports real reuse, not optimistic matching.
        let restored = if logits.is_some() { matched } else { matched.min(seq.len() - 1) };
        if restored == 0 {
            // Only the prompt's last position matched, and without its
            // logits: nothing is reusable, so this is a miss for every
            // practical purpose (no lease, no partial-hit credit).
            self.stats.misses += 1;
            self.stats.miss_tokens += seq.len() as u64;
            return PrefixMatch { matched: 0, rows: Vec::new(), logits: None, lease: None };
        }
        let rows = self.tree.path_rows_prefix(node, matched, &self.pool);
        if logits.is_some() {
            self.stats.hits += 1;
        } else {
            self.stats.partial_hits += 1;
        }
        self.tree.acquire(node);
        self.stats.hit_tokens += restored as u64;
        self.stats.miss_tokens += (seq.len() - restored) as u64;
        self.stats.bytes_saved +=
            (restored * self.geom.row_elems() * std::mem::size_of::<f32>()) as u64;
        PrefixMatch { matched, rows, logits, lease: Some(Lease { node, epoch: self.epoch }) }
    }

    /// Insert a prompt after a miss (rows gathered from the slot the compiled
    /// prefill just wrote). Evicts cold leaves to make room; returns `None`
    /// (and counts an `insert_drop`) when the prompt cannot fit even after
    /// evicting everything evictable.
    pub fn insert(&mut self, seq: &[u32], rows: &[f32], logits: Vec<f32>) -> Option<Lease> {
        self.insert_prefix(seq, rows, Some(logits))
    }

    /// Insert a prompt *prefix* — the per-chunk publication step of chunked
    /// admission (and the landing point of cross-engine store imports).
    /// `logits` is `Some` only on the final chunk (a complete prompt);
    /// intermediate prefixes are resumable but not full hits, and a `None`
    /// here never erases logits already cached at the same boundary.
    ///
    /// Eviction budget: blocks are reserved only for the *non-resident tail*
    /// (plus one copy-on-write fork), not the whole prefix — per-chunk
    /// re-publication of a mostly-resident prefix must not over-evict under
    /// a tight pool (ROADMAP open item). The deepest resident node is pinned
    /// for the duration of the eviction pass so the pass cannot free the
    /// very rows the tail budget assumes are stored.
    pub fn insert_prefix(
        &mut self,
        seq: &[u32],
        rows: &[f32],
        logits: Option<Vec<f32>>,
    ) -> Option<Lease> {
        let (anchor, resident) = self.tree.resident_prefix(seq);
        let budget = RadixTree::insert_budget_tail(seq.len(), resident, self.pool.block_tokens());
        if budget > self.pool.capacity() {
            self.stats.insert_drops += 1;
            return None;
        }
        if let Some(a) = anchor {
            self.tree.acquire(a);
        }
        while self.pool.free_count() < budget {
            match self.tree.evict_one(&mut self.pool) {
                Some(freed) => {
                    self.stats.evictions += 1;
                    self.stats.blocks_evicted += freed as u64;
                }
                None => {
                    if let Some(a) = anchor {
                        self.tree.release(a);
                    }
                    self.stats.insert_drops += 1;
                    return None;
                }
            }
        }
        if let Some(a) = anchor {
            // Unpin before inserting: nothing evicts between here and the
            // insert (the engine is single-threaded per cache), and a
            // lingering pin would block in-place leaf extension.
            self.tree.release(a);
        }
        let node = self.tree.insert(seq, rows, logits, &mut self.pool, &mut self.stats);
        self.tree.acquire(node);
        self.stats.inserts += 1;
        Some(Lease { node, epoch: self.epoch })
    }

    /// Tokens of `seq` whose rows are already resident, without touching LRU
    /// state or hit/miss counters — the cross-engine import probe (only
    /// fetch from the shared store what the local tree does not cover).
    pub fn resident_tokens(&self, seq: &[u32]) -> usize {
        self.tree.resident_prefix(seq).1
    }

    /// Bind the cache contents to a params version. Flushes on a real bump
    /// (cached KV is a function of the weights); a re-announced identical
    /// version keeps the cache warm — the point of skipping no-op weight
    /// syncs. Returns true when a flush happened.
    pub fn set_params_version(&mut self, version: u64) -> bool {
        if self.params_version == Some(version) {
            return false;
        }
        self.params_version = Some(version);
        self.clear();
        true
    }

    /// Release a lease (request retirement). Stale leases from before a
    /// [`PrefixCache::clear`] are ignored.
    pub fn release(&mut self, lease: Lease) {
        if lease.epoch == self.epoch {
            self.tree.release(lease.node);
        }
    }

    /// Flush everything (weight sync: cached KV/logits are functions of the
    /// weights). Bumps the lease epoch so in-flight leases cannot corrupt the
    /// fresh tree — the engine only calls this while idle anyway.
    pub fn clear(&mut self) {
        self.tree.clear();
        self.pool.clear();
        self.epoch += 1;
        self.stats.clears += 1;
    }

    /// Structural invariants (tree linkage + block ownership == refcounts).
    pub fn check(&self) -> Result<(), String> {
        self.tree.check(&self.pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn tiny_geom() -> KvGeometry {
        KvGeometry { n_layers: 2, n_slots: 3, cache_len: 12, kv_heads: 2, head_dim: 2 }
    }

    fn cache(capacity_blocks: usize, block_tokens: usize) -> PrefixCache {
        PrefixCache::new(
            tiny_geom(),
            PrefixCacheCfg { block_tokens, capacity_blocks, policy: EvictPolicy::Lru },
        )
    }

    /// Deterministic per-prefix rows, mirroring real KV: row p depends on
    /// tokens[..=p] only.
    fn rows_for(seq: &[u32], row_elems: usize) -> Vec<f32> {
        let mut acc = 3u64;
        let mut out = Vec::with_capacity(seq.len() * row_elems);
        for &t in seq {
            acc = acc.wrapping_mul(2862933555777941757).wrapping_add(t as u64 + 1);
            for e in 0..row_elems {
                out.push(((acc >> (e % 56)) & 0x7F) as f32);
            }
        }
        out
    }

    fn logits_for(seq: &[u32]) -> Vec<f32> {
        vec![seq.iter().sum::<u32>() as f32, seq.len() as f32]
    }

    #[test]
    fn gather_scatter_roundtrip_between_slots() {
        let g = tiny_geom();
        let total = g.n_layers * g.n_slots * 2 * g.cache_len * g.kv_heads * g.head_dim;
        let mut kv: Vec<f32> = (0..total).map(|i| i as f32).collect();
        let rows = gather_prompt_rows(&kv, &g, 1, 5);
        assert_eq!(rows.len(), 5 * g.row_elems());
        scatter_prompt_rows(&mut kv, &g, 0, &rows);
        // Slot 0's first 5 positions now mirror slot 1's.
        assert_eq!(gather_prompt_rows(&kv, &g, 0, 5), rows);
        // Positions beyond the scatter and other slots are untouched.
        let o = g.chunk_offset(0, 0, 0, 5);
        assert_eq!(kv[o], o as f32);
        let o2 = g.chunk_offset(1, 2, 1, 0);
        assert_eq!(kv[o2], o2 as f32);
    }

    #[test]
    fn group_admission_hits_g_minus_1_of_g() {
        let mut c = cache(16, 4);
        let re = c.geometry().row_elems();
        let prompt = vec![5, 6, 7, 8, 9, 10];
        let g = 8usize;
        let mut leases = Vec::new();
        for i in 0..g {
            match c.match_prompt(&prompt) {
                Some(hit) => {
                    assert!(i > 0, "first admission must miss");
                    assert_eq!(hit.rows, rows_for(&prompt, re));
                    assert_eq!(hit.logits, logits_for(&prompt));
                    leases.push(hit.lease);
                }
                None => {
                    assert_eq!(i, 0, "only the first admission may miss");
                    let lease = c
                        .insert(&prompt, &rows_for(&prompt, re), logits_for(&prompt))
                        .expect("insert fits");
                    leases.push(lease);
                }
            }
        }
        assert_eq!(c.stats.hits, (g - 1) as u64);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.prompt_tokens(), (g * prompt.len()) as u64);
        assert!((c.stats.hit_rate() - (g - 1) as f64 / g as f64).abs() < 1e-12);
        for l in leases {
            c.release(l);
        }
        c.check().unwrap();
    }

    #[test]
    fn partial_prefix_match_restores_template_rows() {
        let mut c = cache(32, 2);
        let re = c.geometry().row_elems();
        let template: Vec<u32> = vec![7, 7, 5, 5, 3, 3];
        let a: Vec<u32> = [&template[..], &[10, 11]].concat();

        // Admit A cold, publishing each completed chunk like the engine does
        // (the previous chunk's lease is dropped once the longer one holds).
        let cold = c.match_prefix(&a);
        assert_eq!(cold.matched, 0);
        assert!(cold.lease.is_none());
        let mut lease = None;
        for end in [2, 4, 6, 8] {
            let rows = rows_for(&a[..end], re);
            let logits = (end == a.len()).then(|| logits_for(&a[..end]));
            let nl = c.insert_prefix(&a[..end], &rows, logits);
            assert!(nl.is_some(), "chunk prefix must fit");
            if let Some(l) = lease.take() {
                c.release(l);
            }
            lease = nl;
            c.check().unwrap();
        }

        // B shares the 6-token template with a different suffix: the cached
        // prefix is restorable at the chunk boundary, compute only the rest.
        let b: Vec<u32> = [&template[..], &[20, 21, 22]].concat();
        let m = c.match_prefix(&b);
        assert_eq!(m.matched, template.len());
        assert!(m.logits.is_none(), "partial hit has no terminal logits");
        assert_eq!(m.rows, rows_for(&template, re));
        assert_eq!(c.stats.partial_hits, 1);

        // The template alone is fully covered but ends without logits: still
        // a partial hit (the engine recomputes the last position for them).
        let t = c.match_prefix(&template);
        assert_eq!(t.matched, template.len());
        assert!(t.logits.is_none());
        assert_eq!(c.stats.partial_hits, 2);

        // A itself is now a full hit.
        let f = c.match_prefix(&a);
        assert_eq!(f.matched, a.len());
        assert_eq!(f.logits.as_deref(), Some(&logits_for(&a)[..]));
        assert_eq!(c.stats.hits, 1);

        // Token accounting: every admitted token is hit or miss, never both,
        // and hits count only rows the engine actually restores — the
        // full-row template match still recomputes its last position for
        // logits, so it credits one token less.
        let admitted = (a.len() + b.len() + template.len() + a.len()) as u64;
        assert_eq!(c.stats.prompt_tokens(), admitted);
        assert_eq!(
            c.stats.hit_tokens,
            (template.len() + (template.len() - 1) + a.len()) as u64
        );

        for l in [lease, m.lease, t.lease, f.lease].into_iter().flatten() {
            c.release(l);
        }
        c.check().unwrap();
    }

    #[test]
    fn clear_invalidates_and_stale_release_is_ignored() {
        let mut c = cache(8, 4);
        let re = c.geometry().row_elems();
        let p = vec![1, 2, 3];
        let lease = c.insert(&p, &rows_for(&p, re), logits_for(&p)).unwrap();
        c.clear();
        assert!(c.match_prompt(&p).is_none(), "flushed entry must miss");
        c.release(lease); // stale epoch: no-op, must not corrupt the new tree
        c.check().unwrap();
        assert_eq!(c.stats.clears, 1);
        assert_eq!(c.live_blocks(), 0);
    }

    #[test]
    fn republication_reserves_only_the_tail() {
        // bt=2, capacity 6. Resident: A = 2 blocks, B = 1 block (3 live,
        // 3 free). Re-publishing A extended by one chunk (2 tokens) under
        // the old whole-prefix budget would reserve ceil(6/2)+1 = 4 > 3 free
        // and evict B for nothing; the tail budget reserves ceil(2/2)+1 = 2,
        // so B survives and no eviction runs.
        let mut c = cache(6, 2);
        let re = c.geometry().row_elems();
        let a: Vec<u32> = vec![1, 1, 1, 1];
        let b: Vec<u32> = vec![9, 9];
        let la = c.insert_prefix(&a, &rows_for(&a, re), None).expect("A fits");
        let lb = c.insert(&b, &rows_for(&b, re), logits_for(&b)).expect("B fits");
        let ext: Vec<u32> = [&a[..], &[2, 2]].concat();
        let le = c.insert_prefix(&ext, &rows_for(&ext, re), Some(logits_for(&ext)));
        assert!(le.is_some(), "tail republication fits without eviction");
        assert_eq!(c.stats.evictions, 0, "whole-prefix budget would have evicted");
        let m = c.match_prefix(&b);
        assert_eq!(m.matched, b.len(), "unrelated entry survived the republication");
        assert_eq!(c.match_prefix(&ext).matched, ext.len());
        c.check().unwrap();
        drop((la, lb, le));
    }

    #[test]
    fn eviction_pass_spares_the_resident_prefix_it_budgets_on() {
        // Everything unleased, pool nearly full: extending A needs one more
        // block than is free, and A's own (older, LRU-first) leaf would be
        // the victim — the anchor pin must steer eviction to the churn
        // entry instead, or the tail budget's "resident" assumption breaks.
        let mut c = cache(5, 2);
        let re = c.geometry().row_elems();
        let a: Vec<u32> = vec![1, 1, 1, 1];
        let la = c.insert_prefix(&a, &rows_for(&a, re), None).unwrap();
        c.release(la);
        let churn: Vec<u32> = vec![7, 7, 7, 7];
        let lc = c.insert_prefix(&churn, &rows_for(&churn, re), None).unwrap();
        c.release(lc);
        assert_eq!(c.live_blocks(), 4);
        let ext: Vec<u32> = [&a[..], &[2, 2]].concat(); // needs 2 free, has 1
        let l = c.insert_prefix(&ext, &rows_for(&ext, re), Some(logits_for(&ext)));
        assert!(l.is_some());
        assert!(c.stats.evictions > 0, "churn had to go");
        let m = c.match_prefix(&ext);
        assert_eq!(m.matched, ext.len());
        assert_eq!(m.rows, rows_for(&ext, re), "resident prefix survived its own insert");
        assert_eq!(c.match_prefix(&churn).matched, 0, "churn was the victim");
        c.check().unwrap();
    }

    #[test]
    fn params_version_tag_skips_noop_flush() {
        let mut c = cache(8, 4);
        let re = c.geometry().row_elems();
        let p = vec![1, 2, 3];
        assert!(c.set_params_version(10), "first bind flushes (empty) state");
        let lease = c.insert(&p, &rows_for(&p, re), logits_for(&p)).unwrap();
        c.release(lease);
        assert!(!c.set_params_version(10), "same version: cache stays warm");
        let hit = c.match_prompt(&p).expect("entry survived the no-op sync");
        c.release(hit.lease);
        assert!(c.set_params_version(11), "real bump flushes");
        assert!(c.match_prompt(&p).is_none());
        assert_eq!(c.stats.clears, 2);
        c.check().unwrap();
    }

    #[test]
    fn oversized_prompt_is_dropped_not_wedged() {
        let mut c = cache(2, 4); // capacity 2 blocks; budget for 8 tokens = 3
        let re = c.geometry().row_elems();
        let p: Vec<u32> = (0..8).collect();
        assert!(c.insert(&p, &rows_for(&p, re), logits_for(&p)).is_none());
        assert_eq!(c.stats.insert_drops, 1);
        c.check().unwrap();
    }

    /// The acceptance invariants, under random grouped traffic with leases,
    /// eviction pressure and flushes:
    /// * insert/match round-trip (hit rows+logits always byte-exact),
    /// * hit_tokens + miss_tokens == total admitted prompt tokens,
    /// * block ownership == pool refcounts after every op (no leak, no
    ///   premature free),
    /// * releasing every lease and evicting to dryness empties the pool.
    #[test]
    fn prop_cache_traffic_invariants() {
        prop::quick(
            "prefix cache: traffic invariants",
            |rng: &mut Pcg64, size| {
                let n_ops = size.scaled(60);
                let ops: Vec<(u64, Vec<u32>)> = (0..n_ops)
                    .map(|_| {
                        // Small alphabet + short lengths force prefix overlap,
                        // splits and shared boundary blocks.
                        let len = rng.range(1, 10);
                        let seq: Vec<u32> = (0..len).map(|_| rng.range(0, 3) as u32).collect();
                        (rng.next_u64(), seq)
                    })
                    .collect();
                let capacity = rng.range(3, 20);
                (capacity, ops)
            },
            |(capacity, ops)| {
                let mut c = PrefixCache::new(
                    tiny_geom(),
                    PrefixCacheCfg {
                        block_tokens: 4,
                        capacity_blocks: *capacity,
                        policy: EvictPolicy::Lru,
                    },
                );
                let re = c.geometry().row_elems();
                let mut leases = Vec::new();
                let mut admitted_tokens = 0u64;
                for (op, seq) in ops {
                    match op % 8 {
                        0..=5 => {
                            // admission: lookup, insert on miss
                            admitted_tokens += seq.len() as u64;
                            match c.match_prompt(seq) {
                                Some(hit) => {
                                    if hit.rows != rows_for(seq, re) {
                                        return Err(format!("hit rows corrupt for {seq:?}"));
                                    }
                                    if hit.logits != logits_for(seq) {
                                        return Err(format!("hit logits corrupt for {seq:?}"));
                                    }
                                    leases.push(hit.lease);
                                }
                                None => {
                                    if let Some(l) =
                                        c.insert(seq, &rows_for(seq, re), logits_for(seq))
                                    {
                                        leases.push(l);
                                    }
                                }
                            }
                        }
                        6 => {
                            // retire a random in-flight request
                            if !leases.is_empty() {
                                let i = (*op as usize / 8) % leases.len();
                                c.release(leases.swap_remove(i));
                            }
                        }
                        _ => {
                            // weight sync: flush; outstanding leases go stale
                            c.clear();
                            leases.clear();
                        }
                    }
                    c.check().map_err(|e| format!("after {seq:?}: {e}"))?;
                    if c.stats.prompt_tokens() != admitted_tokens {
                        return Err(format!(
                            "accounting: {} hit+miss tokens vs {admitted_tokens} admitted",
                            c.stats.prompt_tokens()
                        ));
                    }
                }
                // Drain: release every lease, then evict to dryness. With no
                // pins left, every block must find its way back to the pool —
                // the "refcount never leaks blocks" acceptance invariant.
                for l in leases.drain(..) {
                    c.release(l);
                }
                while c.tree.evict_one(&mut c.pool).is_some() {}
                if c.live_blocks() != 0 {
                    return Err(format!("{} blocks leaked after full drain", c.live_blocks()));
                }
                c.check().map_err(|e| e.to_string())
            },
        );
    }

    /// Eviction pressure with held leases: a leased prefix's blocks are never
    /// freed (hits stay byte-exact) while unleased entries get evicted.
    #[test]
    fn prop_leases_pin_against_eviction() {
        prop::quick(
            "prefix cache: leases pin blocks under pressure",
            |rng: &mut Pcg64, size| {
                let pinned: Vec<u32> = (0..rng.range(2, 8)).map(|_| rng.range(0, 4) as u32).collect();
                let churn: Vec<Vec<u32>> = (0..size.scaled(30))
                    .map(|_| (0..rng.range(1, 8)).map(|_| rng.range(0, 50) as u32).collect())
                    .collect();
                (pinned, churn)
            },
            |(pinned, churn)| {
                let mut c = PrefixCache::new(
                    tiny_geom(),
                    PrefixCacheCfg { block_tokens: 2, capacity_blocks: 6, policy: EvictPolicy::Lru },
                );
                let re = c.geometry().row_elems();
                let _lease = match c.match_prompt(pinned) {
                    Some(h) => h.lease,
                    None => c
                        .insert(pinned, &rows_for(pinned, re), logits_for(pinned))
                        .ok_or("pinned prompt must fit an empty cache")?,
                };
                for seq in churn {
                    if c.match_prompt(seq).is_none() {
                        let _ = c.insert(seq, &rows_for(seq, re), logits_for(seq));
                    }
                    c.check().map_err(|e| e.to_string())?;
                }
                // The pinned terminal was never evictable; unless a later
                // insert split it (moving logits below the pin), it must
                // still hit byte-exactly. Either way its blocks were never
                // freed — check() above verifies ownership each step.
                if let Some(hit) = c.match_prompt(pinned) {
                    if hit.rows != rows_for(pinned, re) {
                        return Err("pinned rows corrupted under pressure".into());
                    }
                    c.release(hit.lease);
                }
                Ok(())
            },
        );
    }
}
