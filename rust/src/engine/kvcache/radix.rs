//! Radix tree over cached prompt prefixes.
//!
//! Each node owns one token fragment of a cached prefix plus the KV rows for
//! that fragment, stored as segments of ref-counted pool blocks
//! ([`super::blocks`]). The tree supports longest-prefix match (both the
//! mid-fragment form used for full-prompt hits and the node-boundary form
//! chunked admission restores from), insert with node splitting (the block
//! straddling a split point is *shared* between the two halves via the pool
//! refcount), in-place extension of unshared leaf tails (copy-on-write
//! forking the tail block when it is shared or no longer packed), and
//! LRU/FIFO eviction of leaves with no active lease.
//!
//! Lease semantics: a lease pins its terminal node (`refs > 0`), which keeps
//! that node — and, structurally, every ancestor — out of eviction's reach.
//! When a node is split, the pin conservatively stays on the upper half; the
//! lower half may be evicted early, which only shortens future matches (hits
//! copy rows out of the cache, so no reader ever holds a freed block).
//! Safety is block-level: a shared block is freed only when its last owning
//! segment is released, which `check` cross-verifies against the pool.
//!
//! Eviction is O(log n) amortised via a lazily-invalidated min-heap of
//! candidate leaves: every transition *into* evictability (leaf created,
//! last lease released, last child evicted) and every policy-key change while
//! evictable pushes an entry; pops discard entries whose node has since been
//! freed, re-pinned, re-keyed, or grown children. `check` verifies the
//! covering invariant — every currently evictable leaf has a live entry
//! carrying its current key — so the proptests pin eviction-order behavior.

use super::blocks::{BlockId, BlockPool};
use super::stats::CacheStats;
use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Which refcount-zero leaf to evict first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Least-recently-matched leaf first (default).
    Lru,
    /// Oldest-inserted leaf first.
    Fifo,
}

impl EvictPolicy {
    pub fn parse(s: &str) -> Result<EvictPolicy> {
        match s {
            "lru" => Ok(EvictPolicy::Lru),
            "fifo" => Ok(EvictPolicy::Fifo),
            other => bail!("unknown eviction policy '{other}' (lru|fifo)"),
        }
    }
}

/// Rows `[start, start + len)` of one pool block.
#[derive(Debug, Clone)]
struct Seg {
    block: BlockId,
    start: usize,
    len: usize,
}

#[derive(Debug)]
struct Node {
    parent: usize,
    /// Edge fragment from the parent; empty only for the root.
    tokens: Vec<u32>,
    /// KV storage covering `tokens` (segment lens sum to `tokens.len()`).
    segs: Vec<Seg>,
    /// Children keyed by the first token of their fragment.
    children: HashMap<u32, usize>,
    /// Active leases whose prefix ends at this node.
    refs: u32,
    last_use: u64,
    created: u64,
    /// Allocation stamp distinguishing reuses of a recycled node id
    /// (heap-entry validation).
    stamp: u64,
    /// Last-position prefill logits when a complete cached prompt ends
    /// exactly at this node's fragment end.
    logits: Option<Vec<f32>>,
}

/// Longest-prefix match result.
#[derive(Debug)]
pub struct Match {
    /// Tokens of the query covered by cached nodes.
    pub matched: usize,
    /// The node whose fragment end coincides with the query end — present
    /// only for exact full-length, node-boundary matches.
    pub terminal: Option<usize>,
}

/// A lazily-invalidated eviction candidate: `(policy key, node id, stamp)`.
/// Min-ordered by key then id, matching the old linear scan's tie-break
/// (first == lowest id wins on equal keys).
type HeapEntry = Reverse<(u64, usize, u64)>;

/// The prefix index. Block budget discipline: callers reserve pool capacity
/// (via eviction) before [`RadixTree::insert`]; an alloc failure inside an
/// insert is a caller bug and panics rather than corrupting the tree.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Option<Node>>,
    free_ids: Vec<usize>,
    root: usize,
    tick: u64,
    policy: EvictPolicy,
    /// Min-heap of eviction candidates; entries go stale instead of being
    /// removed and are discarded at pop time (see module docs).
    evictable: BinaryHeap<HeapEntry>,
}

impl RadixTree {
    pub fn new(policy: EvictPolicy) -> RadixTree {
        let root = Node {
            parent: 0,
            tokens: Vec::new(),
            segs: Vec::new(),
            children: HashMap::new(),
            refs: 0,
            last_use: 0,
            created: 0,
            stamp: 0,
            logits: None,
        };
        RadixTree {
            nodes: vec![Some(root)],
            free_ids: Vec::new(),
            root: 0,
            tick: 1,
            policy,
            evictable: BinaryHeap::new(),
        }
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    /// Live nodes, excluding the root.
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count() - 1
    }

    fn node(&self, id: usize) -> &Node {
        // pa-lint: allow(expect): ids are arena indices handed out by add_node
        self.nodes[id].as_ref().expect("dangling node id")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        // pa-lint: allow(expect): ids are arena indices handed out by add_node
        self.nodes[id].as_mut().expect("dangling node id")
    }

    /// The eviction-policy ordering key for a node.
    fn evict_key(&self, n: &Node) -> u64 {
        match self.policy {
            EvictPolicy::Lru => n.last_use,
            EvictPolicy::Fifo => n.created,
        }
    }

    /// Push a heap entry if `id` is currently an evictable leaf. Cheap and
    /// idempotent: duplicates and soon-stale entries are discarded on pop —
    /// or swept by [`RadixTree::compact_heap`] when they pile up faster than
    /// eviction drains them (touch-heavy workloads that never evict).
    fn heap_push(&mut self, id: usize) {
        if id == self.root {
            return;
        }
        let entry = match self.nodes[id].as_ref() {
            Some(n) if n.refs == 0 && n.children.is_empty() => {
                Reverse((self.evict_key(n), id, n.stamp))
            }
            _ => return,
        };
        self.evictable.push(entry);
        // Amortised O(1): a rebuild costs O(live nodes) and is triggered only
        // after at least that many pushes since the last one, so the heap is
        // bounded by ~2x the live node count even if eviction never runs.
        let live = self.nodes.len() - self.free_ids.len();
        if self.evictable.len() > live * 2 + 64 {
            self.compact_heap();
        }
    }

    /// Rebuild the candidate heap from scratch: one current-key entry per
    /// evictable leaf, every stale entry dropped.
    fn compact_heap(&mut self) {
        let mut fresh = BinaryHeap::with_capacity(self.nodes.len());
        for (id, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            if id != self.root && n.refs == 0 && n.children.is_empty() {
                fresh.push(Reverse((self.evict_key(n), id, n.stamp)));
            }
        }
        self.evictable = fresh;
    }

    fn touch(&mut self, id: usize) {
        let t = self.tick;
        self.tick += 1;
        self.node_mut(id).last_use = t;
        // An LRU key change re-keys any live heap entry for this node.
        if self.policy == EvictPolicy::Lru {
            self.heap_push(id);
        }
    }

    fn add_node(&mut self, mut node: Node) -> usize {
        node.stamp = self.tick;
        self.tick += 1;
        let id = match self.free_ids.pop() {
            Some(id) => {
                debug_assert!(self.nodes[id].is_none());
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        self.heap_push(id);
        id
    }

    /// Longest-prefix match; refreshes LRU stamps along fully matched nodes.
    pub fn lookup(&mut self, seq: &[u32]) -> Match {
        let mut i = 0usize;
        let mut cur = self.root;
        loop {
            if i == seq.len() {
                let terminal = if cur != self.root { Some(cur) } else { None };
                return Match { matched: i, terminal };
            }
            let Some(&child) = self.node(cur).children.get(&seq[i]) else {
                return Match { matched: i, terminal: None };
            };
            let frag = &self.node(child).tokens;
            let common = frag.iter().zip(&seq[i..]).take_while(|(a, b)| a == b).count();
            if common < frag.len() {
                // diverged (or query exhausted) inside the fragment
                return Match { matched: i + common, terminal: None };
            }
            i += common;
            self.touch(child);
            cur = child;
        }
    }

    /// Longest *restorable* prefix of `seq`: like [`RadixTree::lookup`] it
    /// counts tokens matched even partway into a diverging fragment, but it
    /// also returns the node to pin — the diverging child itself when the
    /// match ends mid-fragment (KV rows are row-granular inside a node, so a
    /// fragment prefix restores fine; pinning the child keeps its blocks
    /// live). Returns `(root, 0)` when even the first token misses.
    /// Refreshes LRU stamps along the matched path.
    pub fn lookup_longest(&mut self, seq: &[u32]) -> (usize, usize) {
        let mut i = 0usize;
        let mut cur = self.root;
        loop {
            if i == seq.len() {
                return (cur, i);
            }
            let Some(&child) = self.node(cur).children.get(&seq[i]) else {
                return (cur, i);
            };
            let frag = &self.node(child).tokens;
            let common = frag.iter().zip(&seq[i..]).take_while(|(a, b)| a == b).count();
            if common < frag.len() {
                // Diverged (or query exhausted) inside the fragment: the
                // first `common` of the child's rows are still restorable.
                self.touch(child);
                return (child, i + common);
            }
            i += common;
            self.touch(child);
            cur = child;
        }
    }

    /// Non-mutating longest-prefix probe: `(deepest node the match reaches,
    /// tokens of `seq` whose KV rows are already resident)`. Unlike
    /// [`RadixTree::lookup_longest`] this refreshes no LRU stamps and pushes
    /// no heap entries — it is the sizing pass of
    /// [`RadixTree::insert_budget_tail`] (how much of a re-published prefix
    /// is already stored) and the cross-engine import probe (is the shared
    /// store's coverage longer than ours?).
    pub fn resident_prefix(&self, seq: &[u32]) -> (Option<usize>, usize) {
        let mut i = 0usize;
        let mut cur = self.root;
        let mut deepest = None;
        loop {
            if i == seq.len() {
                return (deepest, i);
            }
            let Some(&child) = self.node(cur).children.get(&seq[i]) else {
                return (deepest, i);
            };
            let frag = &self.node(child).tokens;
            let common = frag.iter().zip(&seq[i..]).take_while(|(a, b)| a == b).count();
            deepest = Some(child);
            i += common;
            if common < frag.len() {
                return (deepest, i);
            }
            cur = child;
        }
    }

    /// Concatenated KV rows for the first `take` tokens of the path
    /// root -> `id` (the restorable prefix a [`RadixTree::lookup_longest`]
    /// match reported; `take` may end inside `id`'s own fragment).
    pub fn path_rows_prefix(&self, id: usize, take: usize, pool: &BlockPool) -> Vec<f32> {
        let mut chain = Vec::new();
        let mut cur = id;
        while cur != self.root {
            chain.push(cur);
            cur = self.node(cur).parent;
        }
        chain.reverse();
        let mut out = Vec::with_capacity(take * pool.row_elems());
        let mut remaining = take;
        for nid in chain {
            for seg in &self.node(nid).segs {
                if remaining == 0 {
                    return out;
                }
                let n = seg.len.min(remaining);
                out.extend_from_slice(pool.rows(seg.block, seg.start, n));
                remaining -= n;
            }
        }
        assert_eq!(remaining, 0, "take exceeds the path's rows");
        out
    }

    /// Tokens covered by the path root -> `id`.
    pub fn path_tokens(&self, id: usize) -> usize {
        let mut n = 0;
        let mut cur = id;
        while cur != self.root {
            n += self.node(cur).tokens.len();
            cur = self.node(cur).parent;
        }
        n
    }

    /// Concatenated KV rows for the path root -> `id`, in prompt order.
    pub fn path_rows(&self, id: usize, pool: &BlockPool) -> Vec<f32> {
        let mut chain = Vec::new();
        let mut cur = id;
        while cur != self.root {
            chain.push(cur);
            cur = self.node(cur).parent;
        }
        chain.reverse();
        let mut out = Vec::with_capacity(self.path_tokens(id) * pool.row_elems());
        for nid in chain {
            for seg in &self.node(nid).segs {
                out.extend_from_slice(pool.rows(seg.block, seg.start, seg.len));
            }
        }
        out
    }

    /// Cached logits at `id`, if a complete prompt ends there.
    pub fn logits(&self, id: usize) -> Option<&[f32]> {
        self.node(id).logits.as_deref()
    }

    /// Pin `id` against eviction (lease acquire). Any live heap entry goes
    /// stale and is discarded at pop time.
    pub fn acquire(&mut self, id: usize) {
        self.node_mut(id).refs += 1;
    }

    /// Drop one lease on `id`; at zero the node (if a leaf) becomes an
    /// eviction candidate again.
    pub fn release(&mut self, id: usize) {
        {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "lease release without acquire");
            n.refs = n.refs.saturating_sub(1);
        }
        self.heap_push(id);
    }

    /// Worst-case pool blocks an insert of `seq` may allocate: storage for
    /// every token plus one block for a copy-on-write tail fork.
    pub fn insert_budget(seq_len: usize, block_tokens: usize) -> usize {
        Self::insert_budget_tail(seq_len, 0, block_tokens)
    }

    /// Worst-case pool blocks an insert may allocate when `resident` of the
    /// `seq_len` tokens already have rows in the tree (per
    /// [`RadixTree::resident_prefix`]): the insert walk re-uses every
    /// resident row — splits share the straddling block instead of copying —
    /// so only the non-resident tail allocates storage, plus one block for a
    /// copy-on-write tail fork. This is what keeps per-chunk re-publication
    /// of a mostly-resident prefix from evicting the world under a tight
    /// pool (ROADMAP: chunked-insert eviction budget).
    pub fn insert_budget_tail(seq_len: usize, resident: usize, block_tokens: usize) -> usize {
        (seq_len - resident.min(seq_len)).div_ceil(block_tokens) + 1
    }

    /// Insert a prompt (or, for chunked admission, a prompt *prefix*) with
    /// its KV rows (`seq.len() * row_elems` f32s) and optional terminal
    /// logits. The caller must have reserved [`RadixTree::insert_budget`]
    /// free blocks. Returns the terminal node. `None` logits never erase
    /// previously cached logits at the terminal (a chunk boundary may land
    /// exactly on a complete cached prompt).
    pub fn insert(
        &mut self,
        seq: &[u32],
        rows: &[f32],
        logits: Option<Vec<f32>>,
        pool: &mut BlockPool,
        stats: &mut CacheStats,
    ) -> usize {
        let row_elems = pool.row_elems();
        assert_eq!(rows.len(), seq.len() * row_elems, "rows/seq mismatch");
        assert!(!seq.is_empty(), "cannot cache an empty prompt");
        let mut i = 0usize;
        let mut cur = self.root;
        loop {
            if i == seq.len() {
                if logits.is_some() {
                    self.node_mut(cur).logits = logits;
                }
                self.touch(cur);
                return cur;
            }
            match self.node(cur).children.get(&seq[i]).copied() {
                None => {
                    let n = self.node(cur);
                    let extendable = cur != self.root
                        && n.children.is_empty()
                        && n.logits.is_none()
                        && n.refs == 0;
                    if extendable {
                        self.extend_node(cur, &seq[i..], &rows[i * row_elems..], pool, stats);
                        self.node_mut(cur).logits = logits;
                        self.touch(cur);
                        return cur;
                    }
                    let leaf = self.new_leaf(cur, &seq[i..], &rows[i * row_elems..], pool);
                    self.node_mut(cur).children.insert(seq[i], leaf);
                    self.node_mut(leaf).logits = logits;
                    return leaf;
                }
                Some(child) => {
                    let frag = &self.node(child).tokens;
                    let common =
                        frag.iter().zip(&seq[i..]).take_while(|(a, b)| a == b).count();
                    debug_assert!(common > 0, "child keyed by first token");
                    if common < self.node(child).tokens.len() {
                        self.split(child, common, pool);
                    }
                    i += common;
                    self.touch(child);
                    cur = child;
                }
            }
        }
    }

    /// Allocate a fresh leaf storing `tokens`/`rows` under `parent`.
    fn new_leaf(&mut self, parent: usize, tokens: &[u32], rows: &[f32], pool: &mut BlockPool) -> usize {
        let mut segs = Vec::new();
        let mut off = 0usize; // rows stored so far
        while off < tokens.len() {
            // pa-lint: allow(expect): insert reserved the block budget up front
            let b = pool.alloc().expect("block budget reserved by caller");
            let n = pool.push_rows(b, &rows[off * pool.row_elems()..]);
            debug_assert!(n > 0);
            segs.push(Seg { block: b, start: 0, len: n });
            off += n;
        }
        let t = self.tick;
        self.tick += 1;
        self.add_node(Node {
            parent,
            tokens: tokens.to_vec(),
            segs,
            children: HashMap::new(),
            refs: 0,
            last_use: t,
            created: t,
            stamp: 0, // assigned by add_node
            logits: None,
        })
    }

    /// Append `tokens`/`rows` to an unshared leaf's fragment, forking the
    /// tail block (copy-on-write) when it is shared or not the block's
    /// packed tail.
    fn extend_node(
        &mut self,
        id: usize,
        tokens: &[u32],
        rows: &[f32],
        pool: &mut BlockPool,
        stats: &mut CacheStats,
    ) {
        let row_elems = pool.row_elems();
        let mut off = 0usize;
        if let Some(last) = self.node(id).segs.last().cloned() {
            let at_packed_tail = last.start + last.len == pool.len(last.block);
            let has_room = pool.len(last.block) < pool.block_tokens() || !at_packed_tail;
            if has_room {
                let block = if pool.refs(last.block) > 1 || !at_packed_tail {
                    let forked = pool
                        .cow(last.block, last.start, last.len)
                        // pa-lint: allow(expect): insert reserved the budget up front
                        .expect("block budget reserved by caller");
                    if forked != last.block {
                        stats.cow_forks += 1;
                    }
                    forked
                } else {
                    last.block
                };
                let n = pool.push_rows(block, rows);
                // pa-lint: allow(unwrap): segs.last() was Some at the branch entry
                let seg = self.node_mut(id).segs.last_mut().unwrap();
                *seg = Seg { block, start: if block == last.block { last.start } else { 0 }, len: last.len + n };
                off += n;
            }
        }
        while off < tokens.len() {
            // pa-lint: allow(expect): insert reserved the block budget up front
            let b = pool.alloc().expect("block budget reserved by caller");
            let n = pool.push_rows(b, &rows[off * row_elems..]);
            debug_assert!(n > 0);
            self.node_mut(id).segs.push(Seg { block: b, start: 0, len: n });
            off += n;
        }
        self.node_mut(id).tokens.extend_from_slice(tokens);
    }

    /// Split `id` at fragment offset `p` (0 < p < len). The upper half keeps
    /// the id (parent links stay valid); the lower half takes the children,
    /// logits and trailing storage. A block straddling `p` becomes shared.
    fn split(&mut self, id: usize, p: usize, pool: &mut BlockPool) {
        let node = self.node_mut(id);
        debug_assert!(p > 0 && p < node.tokens.len(), "split point out of range");
        let bottom_tokens = node.tokens.split_off(p);
        // Find the segment containing row p.
        let mut cum = 0usize;
        let mut k = 0usize;
        while cum + node.segs[k].len <= p {
            cum += node.segs[k].len;
            k += 1;
        }
        let o = p - cum; // offset within seg k
        let mut bottom_segs;
        if o == 0 {
            bottom_segs = node.segs.split_off(k);
        } else {
            let seg = node.segs[k].clone();
            bottom_segs = vec![Seg { block: seg.block, start: seg.start + o, len: seg.len - o }];
            bottom_segs.extend(node.segs.split_off(k + 1));
            node.segs[k].len = o;
            pool.retain(seg.block); // straddling block now has two owners
        }
        let children = std::mem::take(&mut node.children);
        let logits = node.logits.take();
        let (last_use, created) = (node.last_use, node.created);
        let first = bottom_tokens[0];
        let bottom = self.add_node(Node {
            parent: id,
            tokens: bottom_tokens,
            segs: bottom_segs,
            children,
            refs: 0,
            last_use,
            created,
            stamp: 0, // assigned by add_node
            logits,
        });
        // Reparent the grandchildren onto the lower half.
        let grandchildren: Vec<usize> = self.node(bottom).children.values().copied().collect();
        for g in grandchildren {
            self.node_mut(g).parent = bottom;
        }
        self.node_mut(id).children.insert(first, bottom);
    }

    /// Evict the best refcount-zero leaf per the policy. Returns the number
    /// of blocks actually freed, or `None` when nothing is evictable.
    ///
    /// O(log n) amortised: pops the lazily-invalidated candidate heap,
    /// discarding entries whose node was freed, re-pinned, re-keyed (LRU
    /// touch), or grew children since the push. Every discarded entry was
    /// paid for by the push that created it, so the scan the seed engine did
    /// per eviction is gone (ROADMAP open item).
    pub fn evict_one(&mut self, pool: &mut BlockPool) -> Option<usize> {
        let id = loop {
            let Reverse((key, id, stamp)) = self.evictable.pop()?;
            let live = self
                .nodes
                .get(id)
                .and_then(|n| n.as_ref())
                .is_some_and(|n| {
                    n.stamp == stamp
                        && n.refs == 0
                        && n.children.is_empty()
                        && self.evict_key(n) == key
                });
            if live && id != self.root {
                break id;
            }
        };
        // pa-lint: allow(expect): the loop above broke only on a live node
        let node = self.nodes[id].take().expect("validated above");
        self.free_ids.push(id);
        let parent_id = node.parent;
        let parent = self.node_mut(parent_id);
        let removed = parent.children.remove(&node.tokens[0]);
        debug_assert_eq!(removed, Some(id), "parent/child link corrupt");
        let mut freed = 0usize;
        for seg in &node.segs {
            if pool.refs(seg.block) == 1 {
                freed += 1;
            }
            pool.release(seg.block);
        }
        // Losing its last child may have turned the parent into a candidate.
        self.heap_push(parent_id);
        Some(freed)
    }

    /// Drop every node (cache flush); the caller clears the pool.
    pub fn clear(&mut self) {
        let policy = self.policy;
        *self = RadixTree::new(policy);
    }

    /// Structural invariants for the proptests: tree linkage, fragment/row
    /// conservation, and block ownership exactly matching pool refcounts.
    pub fn check(&self, pool: &BlockPool) -> Result<(), String> {
        pool.check()?;
        let root = self.nodes[self.root].as_ref().ok_or("root missing")?;
        if !root.tokens.is_empty() {
            return Err("root has a fragment".into());
        }
        let mut owners: HashMap<BlockId, u32> = HashMap::new();
        for (id, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else {
                if !self.free_ids.contains(&id) {
                    return Err(format!("node {id} neither live nor free"));
                }
                continue;
            };
            if id != self.root {
                if n.tokens.is_empty() {
                    return Err(format!("node {id} has empty fragment"));
                }
                let p = self
                    .nodes
                    .get(n.parent)
                    .and_then(|p| p.as_ref())
                    .ok_or(format!("node {id} has dead parent"))?;
                if p.children.get(&n.tokens[0]) != Some(&id) {
                    return Err(format!("parent of {id} does not link back"));
                }
                let rows: usize = n.segs.iter().map(|s| s.len).sum();
                if rows != n.tokens.len() {
                    return Err(format!(
                        "node {id}: {rows} rows for {} tokens",
                        n.tokens.len()
                    ));
                }
            }
            for seg in &n.segs {
                if seg.len == 0 {
                    return Err(format!("node {id} holds an empty segment"));
                }
                *owners.entry(seg.block).or_insert(0) += 1;
            }
            for (&tok, &c) in &n.children {
                let child = self
                    .nodes
                    .get(c)
                    .and_then(|c| c.as_ref())
                    .ok_or(format!("node {id} has dead child"))?;
                if child.tokens.first() != Some(&tok) {
                    return Err(format!("child key mismatch under {id}"));
                }
            }
        }
        if owners.len() != pool.live_count() {
            return Err(format!(
                "{} blocks owned by segments, pool says {} live",
                owners.len(),
                pool.live_count()
            ));
        }
        for (&b, &count) in &owners {
            if pool.refs(b) != count {
                return Err(format!(
                    "block {b}: {count} owning segments but refcount {}",
                    pool.refs(b)
                ));
            }
        }
        // Heap covering invariant: every currently evictable leaf must have a
        // live entry carrying its current policy key, or eviction could miss
        // it (or pick a worse victim than the old linear scan).
        for (id, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            if id == self.root || !n.children.is_empty() || n.refs > 0 {
                continue;
            }
            let key = self.evict_key(n);
            let covered = self
                .evictable
                .iter()
                .any(|Reverse((k, i, s))| *i == id && *s == n.stamp && *k == key);
            if !covered {
                return Err(format!(
                    "evictable leaf {id} (key {key}) has no live heap entry"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B: usize = 4; // block tokens
    const R: usize = 2; // row elems

    /// Deterministic "KV" rows: row p of a prompt depends on the whole prefix
    /// up to p, mirroring real KV (attention over positions <= p).
    fn rows_for(seq: &[u32]) -> Vec<f32> {
        let mut acc = 17u64;
        let mut out = Vec::with_capacity(seq.len() * R);
        for &t in seq {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(t as u64);
            for e in 0..R {
                out.push(((acc >> (8 * e)) & 0xFF) as f32);
            }
        }
        out
    }

    fn insert(tree: &mut RadixTree, pool: &mut BlockPool, seq: &[u32]) -> usize {
        let mut stats = CacheStats::default();
        tree.insert(seq, &rows_for(seq), Some(vec![seq.len() as f32]), pool, &mut stats)
    }

    #[test]
    fn roundtrip_and_shared_prefix() {
        let mut pool = BlockPool::new(32, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        let a = vec![1, 2, 3, 4, 5, 6];
        let b = vec![1, 2, 3, 9, 9];
        insert(&mut tree, &mut pool, &a);
        insert(&mut tree, &mut pool, &b);
        tree.check(&pool).unwrap();

        for seq in [&a, &b] {
            let m = tree.lookup(seq);
            assert_eq!(m.matched, seq.len());
            let t = m.terminal.expect("exact boundary match");
            assert_eq!(tree.path_tokens(t), seq.len());
            assert_eq!(tree.path_rows(t, &pool), rows_for(seq));
            assert_eq!(tree.logits(t), Some(&[seq.len() as f32][..]));
        }
        // Split at offset 3 (inside block 0): the straddling block is shared.
        assert_eq!(tree.node_count(), 3, "top half + two tails");
        // Prefix-only query: full tokens matched but no terminal boundary.
        let m = tree.lookup(&[1, 2]);
        assert_eq!(m.matched, 2);
        assert!(m.terminal.is_none());
        // Divergent query: partial match.
        let m = tree.lookup(&[1, 2, 7]);
        assert_eq!(m.matched, 2);
        assert!(m.terminal.is_none());
    }

    #[test]
    fn leased_leaf_survives_eviction_pressure() {
        let mut pool = BlockPool::new(4, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        let hot = vec![1, 1, 1, 1];
        let id = insert(&mut tree, &mut pool, &hot);
        tree.acquire(id);
        let cold = insert(&mut tree, &mut pool, &[2, 2, 2, 2]);
        assert_ne!(id, cold);
        // Pool is at 2/4; force evictions until dry.
        let mut freed = 0;
        while let Some(f) = tree.evict_one(&mut pool) {
            freed += f;
        }
        assert_eq!(freed, 1, "only the unleased leaf may be evicted");
        assert!(tree.lookup(&hot).terminal.is_some(), "leased entry intact");
        tree.check(&pool).unwrap();
        tree.release(id);
        assert_eq!(tree.evict_one(&mut pool), Some(1), "released entry now evictable");
        assert_eq!(pool.live_count(), 0);
    }

    #[test]
    fn fifo_and_lru_pick_different_victims() {
        for (policy, expect_victim) in [(EvictPolicy::Fifo, 1u32), (EvictPolicy::Lru, 2u32)] {
            let mut pool = BlockPool::new(8, B, R);
            let mut tree = RadixTree::new(policy);
            insert(&mut tree, &mut pool, &[1, 10]);
            insert(&mut tree, &mut pool, &[2, 20]);
            // Refresh the older entry's LRU stamp.
            assert_eq!(tree.lookup(&[1, 10]).matched, 2);
            tree.evict_one(&mut pool).unwrap();
            let survivor = if expect_victim == 1 { [2, 20] } else { [1, 10] };
            let victim = if expect_victim == 1 { [1, 10] } else { [2, 20] };
            assert!(tree.lookup(&survivor).terminal.is_some(), "{policy:?}");
            assert_eq!(tree.lookup(&victim).matched, 0, "{policy:?}");
        }
    }

    #[test]
    fn extend_in_place_forks_unpacked_tail() {
        let mut pool = BlockPool::new(16, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        let a = vec![1, 2, 3, 4, 5, 6];
        insert(&mut tree, &mut pool, &a);
        // Diverge at offset 5 -> split inside block 1; tail block shared.
        let b = vec![1, 2, 3, 4, 5, 7];
        insert(&mut tree, &mut pool, &b);
        tree.check(&pool).unwrap();
        // Evict both tails; the upper half [1,2,3,4,5] becomes a bare leaf
        // whose tail segment is row 0 of a 2-row block (not the packed tail).
        tree.evict_one(&mut pool).unwrap();
        tree.evict_one(&mut pool).unwrap();
        tree.check(&pool).unwrap();
        let c = vec![1, 2, 3, 4, 5, 8, 9];
        let mut stats = CacheStats::default();
        let id = tree.insert(&c, &rows_for(&c), Some(vec![7.0]), &mut pool, &mut stats);
        assert_eq!(stats.cow_forks, 1, "shared/unpacked tail must fork");
        assert_eq!(tree.path_rows(id, &pool), rows_for(&c));
        assert_eq!(tree.node_count(), 1, "extension stayed in place");
        tree.check(&pool).unwrap();
    }

    #[test]
    fn lookup_longest_restores_partial_fragments() {
        let mut pool = BlockPool::new(32, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        let a = vec![1, 2, 3, 4, 5, 6];
        let b = vec![1, 2, 3, 9, 9];
        insert(&mut tree, &mut pool, &a);
        insert(&mut tree, &mut pool, &b); // splits: [1,2,3] + [4,5,6] + [9,9]

        let (n, m) = tree.lookup_longest(&a);
        assert_eq!(m, 6);
        assert_eq!(tree.path_tokens(n), 6);
        assert_eq!(tree.path_rows_prefix(n, 6, &pool), rows_for(&a));

        // Diverging inside [4,5,6]: the matched rows — including the partial
        // fragment — are exactly what chunked admission restores.
        let (n, m) = tree.lookup_longest(&[1, 2, 3, 4, 7]);
        assert_eq!(m, 4);
        assert_eq!(tree.path_tokens(n), 6, "pin lands on the diverging child");
        assert_eq!(tree.path_rows_prefix(n, m, &pool), rows_for(&[1, 2, 3, 4]));

        // Query exhausted mid-fragment: restorable, but not a terminal.
        let (n, m) = tree.lookup_longest(&[1, 2]);
        assert_eq!(m, 2);
        assert!(tree.path_tokens(n) > m, "no node boundary at the query end");
        assert_eq!(tree.path_rows_prefix(n, m, &pool), rows_for(&[1, 2]));

        // First-token miss.
        assert_eq!(tree.lookup_longest(&[7]).1, 0);
        tree.check(&pool).unwrap();
    }

    #[test]
    fn heap_eviction_matches_policy_after_churn() {
        let mut pool = BlockPool::new(64, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        insert(&mut tree, &mut pool, &[1, 1]);
        insert(&mut tree, &mut pool, &[2, 2]);
        insert(&mut tree, &mut pool, &[3, 3]);
        // Refresh 1 and 2; 3 stays least-recently-used.
        tree.lookup(&[1, 1]);
        tree.lookup(&[2, 2]);
        tree.evict_one(&mut pool).unwrap();
        assert_eq!(tree.lookup(&[3, 3]).matched, 0, "oldest LRU leaf evicted");
        tree.check(&pool).unwrap();
        // Pin 1 (stale heap entries must be skipped); 2 is the next victim.
        let id = tree.lookup(&[1, 1]).terminal.unwrap();
        tree.acquire(id);
        tree.evict_one(&mut pool).unwrap();
        assert_eq!(tree.lookup(&[2, 2]).matched, 0);
        assert!(tree.lookup(&[1, 1]).terminal.is_some(), "pinned leaf survives");
        tree.check(&pool).unwrap();
        tree.release(id);
        assert!(tree.evict_one(&mut pool).is_some(), "released leaf evictable");
        assert_eq!(tree.evict_one(&mut pool), None, "nothing left to evict");
        assert_eq!(pool.live_count(), 0);
        tree.check(&pool).unwrap();
    }

    #[test]
    fn heap_stays_bounded_under_touch_churn() {
        // A hit-heavy workload that never evicts must not grow the candidate
        // heap without bound (every LRU touch pushes a re-keyed entry).
        let mut pool = BlockPool::new(16, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        insert(&mut tree, &mut pool, &[1, 2, 3]);
        insert(&mut tree, &mut pool, &[4, 5]);
        for _ in 0..10_000 {
            tree.lookup(&[1, 2, 3]);
            tree.lookup(&[4, 5]);
        }
        let live = tree.nodes.iter().filter(|n| n.is_some()).count();
        assert!(
            tree.evictable.len() <= live * 2 + 64,
            "candidate heap grew unbounded: {} entries for {live} live nodes",
            tree.evictable.len()
        );
        tree.check(&pool).unwrap();
        // Eviction order is still intact after compactions.
        tree.evict_one(&mut pool).unwrap();
        tree.check(&pool).unwrap();
    }

    #[test]
    fn insert_budget_is_sufficient() {
        // Worst case: brand-new prompt, every block partial-capable + 1 cow.
        assert_eq!(RadixTree::insert_budget(6, 4), 3);
        assert_eq!(RadixTree::insert_budget(8, 4), 3);
        assert_eq!(RadixTree::insert_budget(1, 4), 2);
        // Tail budget: only the non-resident suffix allocates.
        assert_eq!(RadixTree::insert_budget_tail(8, 0, 4), 3);
        assert_eq!(RadixTree::insert_budget_tail(8, 6, 4), 2);
        assert_eq!(RadixTree::insert_budget_tail(8, 8, 4), 1, "fully resident: cow fork only");
        assert_eq!(RadixTree::insert_budget_tail(4, 9, 4), 1, "over-resident clamps");
    }

    #[test]
    fn resident_prefix_probe_is_non_mutating() {
        let mut pool = BlockPool::new(32, B, R);
        let mut tree = RadixTree::new(EvictPolicy::Lru);
        let a = vec![1, 2, 3, 4, 5, 6];
        insert(&mut tree, &mut pool, &a);
        insert(&mut tree, &mut pool, &[1, 2, 3, 9, 9]); // split at 3

        let (n, m) = tree.resident_prefix(&a);
        assert_eq!(m, 6);
        assert_eq!(tree.path_tokens(n.unwrap()), 6);
        // Mid-fragment divergence still counts the restorable rows.
        let (n, m) = tree.resident_prefix(&[1, 2, 3, 4, 7]);
        assert_eq!(m, 4);
        assert!(n.is_some());
        // Cold query: nothing resident, no node.
        assert_eq!(tree.resident_prefix(&[7, 7]), (None, 0));
        // No LRU refresh happened: the probe must not have re-keyed any
        // evictable leaf (heap covering invariant would catch a push with a
        // changed key; `check` also verifies no stamp drifted).
        tree.check(&pool).unwrap();
        // Probing must not change eviction order: refresh a's branch with a
        // real lookup (so b's tail is the LRU victim), then probe b's branch
        // many times — a mutating lookup would refresh it and flip the
        // victim back to a's tail.
        tree.lookup(&a);
        for _ in 0..8 {
            tree.resident_prefix(&[1, 2, 3, 9, 9]);
        }
        tree.evict_one(&mut pool).unwrap();
        assert_eq!(tree.lookup(&[1, 2, 3, 9, 9]).matched, 3, "probe refreshed LRU");
        assert_eq!(tree.lookup(&a).matched, 6, "probed branch survives");
        tree.check(&pool).unwrap();
    }
}
