//! Ref-counted KV block allocator with copy-on-write fork.
//!
//! Storage currency of the prefix cache: a **block** holds the host-side KV
//! rows (one row = every layer's K and V vectors for one token position) for
//! up to `block_tokens` consecutive tokens of some cached prefix. Blocks are
//! ref-counted because a radix-tree split leaves the block that straddles the
//! split point shared between the two halves; a block is only returned to the
//! free list when its last owner lets go — the "eviction never frees a
//! referenced block" invariant the proptests pin down.
//!
//! Mutation discipline: appending rows requires exclusive ownership
//! (`refs == 1`) *and* that the segment being extended is the block's packed
//! tail. [`BlockPool::cow`] forks a segment into a fresh exclusively-owned
//! block when either condition fails — the copy-on-write path taken when a
//! cached prefix is extended past a previously shared boundary.

/// Handle to one pooled block. Stable for the lifetime of the block (ids are
/// recycled only after the block is freed).
pub type BlockId = usize;

#[derive(Debug)]
struct Block {
    /// Row storage, `len * row_elems` f32s used.
    data: Vec<f32>,
    /// Rows (token positions) currently stored.
    len: usize,
    /// Owners: radix segments referencing any rows of this block.
    refs: u32,
}

/// Fixed-capacity pool of KV blocks.
#[derive(Debug)]
pub struct BlockPool {
    block_tokens: usize,
    row_elems: usize,
    capacity: usize,
    blocks: Vec<Option<Block>>,
    free: Vec<BlockId>,
    live: usize,
}

impl BlockPool {
    /// A pool of at most `capacity` blocks, each holding up to `block_tokens`
    /// rows of `row_elems` f32s.
    pub fn new(capacity: usize, block_tokens: usize, row_elems: usize) -> BlockPool {
        assert!(block_tokens > 0 && row_elems > 0, "degenerate block geometry");
        BlockPool { block_tokens, row_elems, capacity, blocks: Vec::new(), free: Vec::new(), live: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Blocks currently owned by at least one segment.
    pub fn live_count(&self) -> usize {
        self.live
    }

    /// Blocks that could still be allocated without eviction.
    pub fn free_count(&self) -> usize {
        self.capacity - self.live
    }

    /// Allocate an empty block with `refs == 1`. `None` when the pool is at
    /// capacity (the caller evicts and retries, or drops the insert).
    pub fn alloc(&mut self) -> Option<BlockId> {
        if self.live == self.capacity {
            return None;
        }
        let block = Block {
            data: Vec::with_capacity(self.block_tokens * self.row_elems),
            len: 0,
            refs: 1,
        };
        self.live += 1;
        match self.free.pop() {
            Some(id) => {
                debug_assert!(self.blocks[id].is_none(), "free list held a live block");
                self.blocks[id] = Some(block);
                Some(id)
            }
            None => {
                self.blocks.push(Some(block));
                Some(self.blocks.len() - 1)
            }
        }
    }

    fn get(&self, id: BlockId) -> &Block {
        // pa-lint: allow(expect): BlockIds are arena indices minted by alloc
        self.blocks[id].as_ref().expect("dangling BlockId")
    }

    fn get_mut(&mut self, id: BlockId) -> &mut Block {
        // pa-lint: allow(expect): BlockIds are arena indices minted by alloc
        self.blocks[id].as_mut().expect("dangling BlockId")
    }

    /// Add one owner.
    pub fn retain(&mut self, id: BlockId) {
        self.get_mut(id).refs += 1;
    }

    /// Drop one owner; frees the block (and recycles the id) at zero.
    pub fn release(&mut self, id: BlockId) {
        let b = self.get_mut(id);
        debug_assert!(b.refs > 0, "release on unreferenced block");
        b.refs -= 1;
        if b.refs == 0 {
            self.blocks[id] = None;
            self.free.push(id);
            self.live -= 1;
        }
    }

    pub fn refs(&self, id: BlockId) -> u32 {
        self.get(id).refs
    }

    /// Rows stored in the block.
    pub fn len(&self, id: BlockId) -> usize {
        self.get(id).len
    }

    /// Read rows `[start, start + n)`.
    pub fn rows(&self, id: BlockId, start: usize, n: usize) -> &[f32] {
        let b = self.get(id);
        assert!(start + n <= b.len, "row range {start}..{} past block len {}", start + n, b.len);
        &b.data[start * self.row_elems..(start + n) * self.row_elems]
    }

    /// Append rows to an exclusively-owned block. Returns rows appended
    /// (bounded by remaining block capacity); `rows.len()` must be a multiple
    /// of `row_elems`.
    pub fn push_rows(&mut self, id: BlockId, rows: &[f32]) -> usize {
        assert_eq!(rows.len() % self.row_elems, 0, "ragged row append");
        let row_elems = self.row_elems;
        let block_tokens = self.block_tokens;
        let b = self.get_mut(id);
        assert_eq!(b.refs, 1, "append to shared block (fork first)");
        let fit = (block_tokens - b.len).min(rows.len() / row_elems);
        b.data.extend_from_slice(&rows[..fit * row_elems]);
        b.len += fit;
        fit
    }

    /// Copy-on-write fork: return a block exclusively owning rows
    /// `[start, start + n)` packed from row 0, ready for appends.
    ///
    /// Fast path: if the segment already *is* the whole of an exclusively
    /// owned block, it is returned as-is. Otherwise a fresh block is
    /// allocated, the rows are copied, and the caller's reference on `id` is
    /// released. `None` when the pool is exhausted (caller's reference is
    /// kept untouched so it can unwind cleanly).
    pub fn cow(&mut self, id: BlockId, start: usize, n: usize) -> Option<BlockId> {
        {
            let b = self.get(id);
            assert!(start + n <= b.len, "cow range past block len");
            if b.refs == 1 && start == 0 && n == b.len {
                return Some(id);
            }
        }
        let fresh = self.alloc()?;
        let rows = self.rows(id, start, n).to_vec();
        let appended = self.push_rows(fresh, &rows);
        debug_assert_eq!(appended, n, "fresh block must fit the forked rows");
        self.release(id);
        Some(fresh)
    }

    /// Drop every block (cache flush).
    pub fn clear(&mut self) {
        self.blocks.clear();
        self.free.clear();
        self.live = 0;
    }

    /// Structural invariants, for the proptests: id-space conservation and
    /// no live block on the free list.
    pub fn check(&self) -> Result<(), String> {
        let live = self.blocks.iter().filter(|b| b.is_some()).count();
        if live != self.live {
            return Err(format!("live count {} != occupied slots {live}", self.live));
        }
        if live > self.capacity {
            return Err(format!("live {live} exceeds capacity {}", self.capacity));
        }
        if self.free.len() + live != self.blocks.len() {
            return Err(format!(
                "id conservation violated: {} free + {live} live != {} ids",
                self.free.len(),
                self.blocks.len()
            ));
        }
        for &id in &self.free {
            if self.blocks[id].is_some() {
                return Err(format!("block {id} is both free and live"));
            }
        }
        for (id, b) in self.blocks.iter().enumerate() {
            if let Some(b) = b {
                if b.refs == 0 {
                    return Err(format!("live block {id} has zero refs"));
                }
                if b.len > self.block_tokens || b.data.len() != b.len * self.row_elems {
                    return Err(format!("block {id} row bookkeeping corrupt"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    #[test]
    fn alloc_release_recycles_ids() {
        let mut p = BlockPool::new(2, 4, 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none(), "pool at capacity");
        p.release(a);
        assert_eq!(p.free_count(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed id is recycled");
        p.check().unwrap();
        p.release(b);
        p.release(c);
        assert_eq!(p.live_count(), 0);
        p.check().unwrap();
    }

    #[test]
    fn shared_block_survives_one_release() {
        let mut p = BlockPool::new(4, 4, 2);
        let a = p.alloc().unwrap();
        p.push_rows(a, &[1.0; 8]); // 4 rows
        p.retain(a);
        assert_eq!(p.refs(a), 2);
        p.release(a);
        assert_eq!(p.refs(a), 1);
        assert_eq!(p.rows(a, 0, 4).len(), 8, "rows intact after partial release");
        p.release(a);
        assert_eq!(p.live_count(), 0);
    }

    #[test]
    fn push_rows_respects_capacity() {
        let mut p = BlockPool::new(1, 3, 2);
        let a = p.alloc().unwrap();
        assert_eq!(p.push_rows(a, &[0.5; 4]), 2);
        assert_eq!(p.push_rows(a, &[1.5; 6]), 1, "only one row fits");
        assert_eq!(p.len(a), 3);
        assert_eq!(p.rows(a, 2, 1), &[1.5, 1.5]);
    }

    #[test]
    fn cow_forks_shared_and_partial_segments() {
        let mut p = BlockPool::new(4, 4, 1);
        let a = p.alloc().unwrap();
        p.push_rows(a, &[1.0, 2.0, 3.0, 4.0]);
        // Exclusive whole-block fast path: same id.
        assert_eq!(p.cow(a, 0, 4).unwrap(), a);
        // Shared: fork copies and drops one reference.
        p.retain(a);
        let f = p.cow(a, 1, 2).unwrap();
        assert_ne!(f, a);
        assert_eq!(p.rows(f, 0, 2), &[2.0, 3.0]);
        assert_eq!(p.refs(a), 1, "cow released the caller's reference");
        assert_eq!(p.refs(f), 1);
        // Forked block is append-ready.
        assert_eq!(p.push_rows(f, &[9.0]), 1);
        assert_eq!(p.rows(f, 2, 1), &[9.0]);
        p.check().unwrap();
    }

    #[test]
    fn cow_exhaustion_keeps_reference() {
        let mut p = BlockPool::new(1, 4, 1);
        let a = p.alloc().unwrap();
        p.push_rows(a, &[1.0, 2.0]);
        p.retain(a);
        assert!(p.cow(a, 0, 1).is_none(), "no room to fork");
        assert_eq!(p.refs(a), 2, "failed cow must not leak a release");
        p.check().unwrap();
    }

    #[test]
    fn prop_pool_conservation() {
        prop::quick(
            "block pool: id conservation under random alloc/retain/release",
            |rng: &mut Pcg64, size| {
                let cap = rng.range(1, 8);
                let ops: Vec<u64> = (0..size.scaled(80)).map(|_| rng.next_u64()).collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut p = BlockPool::new(*cap, 2, 1);
                let mut owned: Vec<BlockId> = Vec::new(); // one entry per reference we hold
                for &op in ops {
                    match op % 3 {
                        0 => {
                            if let Some(id) = p.alloc() {
                                owned.push(id);
                            } else if p.free_count() != 0 {
                                return Err("alloc failed with free capacity".into());
                            }
                        }
                        1 => {
                            if !owned.is_empty() {
                                let id = owned[(op as usize / 3) % owned.len()];
                                p.retain(id);
                                owned.push(id);
                            }
                        }
                        _ => {
                            if !owned.is_empty() {
                                let id = owned.swap_remove((op as usize / 3) % owned.len());
                                p.release(id);
                            }
                        }
                    }
                    p.check().map_err(|e| e.to_string())?;
                    // every reference we hold must still resolve
                    for &id in &owned {
                        if p.refs(id) == 0 {
                            return Err(format!("held block {id} was freed"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
