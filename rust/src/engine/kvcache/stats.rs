//! Prefix-cache counters.
//!
//! The accounting contract (pinned by proptests in the parent module): every
//! prompt token admitted through [`super::PrefixCache::match_prompt`] or
//! [`super::PrefixCache::match_prefix`] lands in exactly one of `hit_tokens`
//! (served from cached KV) or `miss_tokens` (left for the compiled prefill),
//! so `hit_tokens + miss_tokens` always equals the total prompt tokens the
//! engine admitted — with chunked admission the split is per-token, not
//! all-or-nothing. On a G-rollout group with a cold cache that yields a
//! `(G-1)/G` token hit rate — the inference-side dual of SPA's compute saving.

/// Cumulative prefix-cache counters (one instance per engine).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    /// Prompt lookups (one per admitted request while the cache is enabled).
    pub lookups: u64,
    /// Full-prompt hits (compiled prefill skipped entirely).
    pub hits: u64,
    /// Partial-prefix hits: some rows restored, the uncached suffix went
    /// through chunked prefill (`match_prefix` only; the full-hit-only
    /// `match_prompt` path never counts these).
    pub partial_hits: u64,
    /// Lookups that fell through to the compiled prefill.
    pub misses: u64,
    /// Prompt tokens whose KV was restored from the cache.
    pub hit_tokens: u64,
    /// Prompt tokens recomputed by the compiled prefill.
    pub miss_tokens: u64,
    /// Prompts inserted after a miss.
    pub inserts: u64,
    /// Inserts abandoned because eviction could not free enough blocks.
    pub insert_drops: u64,
    /// Radix nodes evicted (LRU/FIFO leaves with no active lease).
    pub evictions: u64,
    /// Blocks returned to the pool by eviction.
    pub blocks_evicted: u64,
    /// Copy-on-write block forks (shared tail block repacked before extend).
    pub cow_forks: u64,
    /// KV bytes *not* recomputed thanks to hits (hit tokens x row bytes).
    pub bytes_saved: u64,
    /// Whole-cache flushes (weight sync invalidates every entry).
    pub clears: u64,
}

impl CacheStats {
    /// Prompt-token hit rate in [0, 1]; 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hit_tokens + self.miss_tokens;
        if total == 0 {
            0.0
        } else {
            self.hit_tokens as f64 / total as f64
        }
    }

    /// Total prompt tokens accounted (hit + miss).
    pub fn prompt_tokens(&self) -> u64 {
        self.hit_tokens + self.miss_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_edges() {
        let mut s = CacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.hit_tokens = 30;
        s.miss_tokens = 10;
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.prompt_tokens(), 40);
    }

    #[test]
    fn group_hit_rate_shape() {
        // G rollouts of one prompt of length L: 1 miss + (G-1) hits.
        let (g, l) = (8u64, 64u64);
        let s = CacheStats {
            lookups: g,
            hits: g - 1,
            misses: 1,
            hit_tokens: (g - 1) * l,
            miss_tokens: l,
            ..Default::default()
        };
        assert!((s.hit_rate() - (g - 1) as f64 / g as f64).abs() < 1e-12);
    }
}
