//! Host-side token sampler.
//!
//! Samples the *first* response token from prefill logits (subsequent tokens
//! are sampled inside the compiled decode chunk — see
//! `python/compile/model.py::sample_token`, whose semantics this mirrors:
//! temperature scaling, optional top-k, then top-p nucleus truncation that
//! always keeps the highest-probability token, then categorical sampling;
//! temperature <= 1e-6 means greedy argmax).
//!
//! Top-k tie rule (identical in both samplers): every token whose scaled
//! logit is >= the k-th largest value is kept, so ties at the cutoff widen
//! the support past `top_k` — ties are never broken by token index. NaN
//! logits are masked out *in the top-k path*, matching the compiled
//! `scaled >= kth` predicate (false for NaN); with top-k disabled a NaN
//! row is a poisoned upstream matmul and the sampled token is garbage on
//! both sides — neither sampler panics on it.

use crate::util::rng::Pcg64;

/// Sampling hyper-parameters (paper Table 10: temperature 1.0 for training
/// rollouts; 0.6 / top-p 0.95 / top-k 20 for evaluation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerCfg {
    pub temperature: f32,
    pub top_p: f32,
    /// 0 disables top-k.
    pub top_k: usize,
}

impl SamplerCfg {
    pub fn greedy() -> SamplerCfg {
        SamplerCfg { temperature: 0.0, top_p: 1.0, top_k: 0 }
    }
}

/// Sample a token id from raw logits. Returns (token, logprob under the
/// truncated sampling distribution).
pub fn sample(logits: &[f32], cfg: &SamplerCfg, rng: &mut Pcg64) -> (u32, f32) {
    assert!(!logits.is_empty());
    if cfg.temperature <= 1e-6 {
        let (tok, _) = argmax(logits);
        return (tok as u32, 0.0);
    }
    let inv_t = 1.0 / cfg.temperature;
    let mut scaled: Vec<f32> = logits.iter().map(|&l| l * inv_t).collect();

    // top-k: keep exactly the tokens whose scaled logit is >= the k-th
    // largest value. Tie rule (shared with the compiled
    // `python/compile/model.py::sample_token`, which masks via
    // `scaled >= kth`): *all* tokens tied at the k-th value are kept, so the
    // support may exceed `top_k` when ties straddle the cutoff — both
    // samplers widen identically rather than breaking ties by index.
    if cfg.top_k > 0 && cfg.top_k < scaled.len() {
        let mut sorted = scaled.clone();
        // total_cmp: NaN logits (a poisoned upstream matmul) must not panic
        // the engine thread mid-batch; NaN orders above +inf here.
        sorted.sort_by(|a, b| b.total_cmp(a));
        let kth = sorted[cfg.top_k - 1];
        for s in scaled.iter_mut() {
            // NaN is masked explicitly: `< kth` is false for NaN, but the
            // compiled `jnp.where(scaled >= kth, ...)` masks NaN entries to
            // the fill value, so the host must drop them too.
            if *s < kth || s.is_nan() {
                *s = f32::NEG_INFINITY;
            }
        }
    }

    // top-p: sort descending, keep tokens whose cumulative mass *before* them
    // is < top_p (always keeps the top token).
    let mut idx: Vec<usize> = (0..scaled.len()).collect();
    idx.sort_by(|&a, &b| scaled[b].total_cmp(&scaled[a]));
    let max = scaled[idx[0]];
    let exps: Vec<f32> = idx.iter().map(|&i| (scaled[i] - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    let mut keep = vec![false; scaled.len()];
    let mut cum = 0.0f32;
    for (rank, &i) in idx.iter().enumerate() {
        if cum < cfg.top_p {
            keep[i] = true;
        } else {
            break;
        }
        cum += exps[rank] / z;
    }

    // categorical over kept tokens
    let kept_mass: f32 = scaled
        .iter()
        .zip(&keep)
        .filter(|(_, &k)| k)
        .map(|(&s, _)| (s - max).exp())
        .sum();
    let mut x = rng.f64() as f32 * kept_mass;
    let mut chosen = idx[0];
    for (i, (&s, &k)) in scaled.iter().zip(&keep).enumerate() {
        if !k {
            continue;
        }
        let w = (s - max).exp();
        x -= w;
        if x < 0.0 {
            chosen = i;
            break;
        }
    }
    let lp = (scaled[chosen] - max).exp() / kept_mass;
    (chosen as u32, lp.ln())
}

fn argmax(xs: &[f32]) -> (usize, f32) {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    (best, xs[best])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn greedy_takes_argmax() {
        let mut rng = Pcg64::seeded(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        for _ in 0..10 {
            let (tok, _) = sample(&logits, &SamplerCfg::greedy(), &mut rng);
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn top_p_truncates_to_dominant_token() {
        let mut rng = Pcg64::seeded(1);
        let logits = vec![5.0, 0.0, 0.0, 0.0];
        let cfg = SamplerCfg { temperature: 1.0, top_p: 0.5, top_k: 0 };
        for _ in 0..50 {
            let (tok, lp) = sample(&logits, &cfg, &mut rng);
            assert_eq!(tok, 0);
            assert!((lp - 0.0).abs() < 1e-5, "sole kept token has logprob 0, got {lp}");
        }
    }

    #[test]
    fn top_k_limits_support() {
        let mut rng = Pcg64::seeded(2);
        let logits = vec![1.0, 0.9, 0.8, -2.0];
        let cfg = SamplerCfg { temperature: 1.0, top_p: 1.0, top_k: 2 };
        for _ in 0..100 {
            let (tok, _) = sample(&logits, &cfg, &mut rng);
            assert!(tok < 2, "token {tok} outside top-2");
        }
    }

    #[test]
    fn top_k_keeps_all_tokens_tied_at_cutoff() {
        // Parity with python/compile/model.py::sample_token, which masks via
        // `scaled >= kth`: every token tied at the k-th value stays in the
        // support, so top_k=2 over {2.0, 1.0, 1.0, 1.0} keeps four tokens.
        let mut rng = Pcg64::seeded(9);
        let logits = vec![2.0, 1.0, 1.0, 1.0, -4.0];
        let cfg = SamplerCfg { temperature: 1.0, top_p: 1.0, top_k: 2 };
        let mut seen = [false; 5];
        for _ in 0..2000 {
            let (tok, _) = sample(&logits, &cfg, &mut rng);
            seen[tok as usize] = true;
        }
        assert!(
            seen[0] && seen[1] && seen[2] && seen[3],
            "all tokens tied at the cutoff must stay sampleable: {seen:?}"
        );
        assert!(!seen[4], "below-cutoff token was sampled");
    }

    #[test]
    fn top_k_masks_nan_like_compiled_spec() {
        // The compiled `jnp.where(scaled >= kth, ...)` drops NaN entries
        // (the comparison is false for NaN); the host sampler must never
        // emit one either.
        let mut rng = Pcg64::seeded(10);
        let logits = vec![1.0, f32::NAN, 0.5, 0.0];
        let cfg = SamplerCfg { temperature: 1.0, top_p: 1.0, top_k: 3 };
        for _ in 0..500 {
            let (tok, _) = sample(&logits, &cfg, &mut rng);
            assert_ne!(tok, 1, "NaN logit was sampled");
        }
    }

    #[test]
    fn temperature_one_matches_softmax_frequencies() {
        let mut rng = Pcg64::seeded(3);
        let probs = [0.7f32, 0.2, 0.1];
        let logits: Vec<f32> = probs.iter().map(|p| p.ln()).collect();
        let cfg = SamplerCfg { temperature: 1.0, top_p: 1.0, top_k: 0 };
        let mut counts = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            let (tok, _) = sample(&logits, &cfg, &mut rng);
            counts[tok as usize] += 1;
        }
        for (c, p) in counts.iter().zip(&probs) {
            let freq = *c as f32 / n as f32;
            assert!((freq - p).abs() < 0.02, "freq {freq} vs p {p}");
        }
    }

    #[test]
    fn prop_logprob_is_log_of_kept_distribution() {
        prop::quick(
            "sampled logprob normalises over kept set",
            |rng, size| {
                let v = rng.range(2, size.scaled(32).max(2) + 2);
                let logits: Vec<f32> = (0..v).map(|_| rng.f32() * 6.0 - 3.0).collect();
                let top_p = 0.3 + rng.f32() * 0.7;
                (logits, top_p, rng.next_u64())
            },
            |(logits, top_p, seed)| {
                let cfg = SamplerCfg { temperature: 1.0, top_p: *top_p, top_k: 0 };
                let mut rng = Pcg64::seeded(*seed);
                let (tok, lp) = sample(logits, &cfg, &mut rng);
                if !(lp <= 1e-6) {
                    return Err(format!("logprob {lp} > 0"));
                }
                if tok as usize >= logits.len() {
                    return Err("token out of range".into());
                }
                Ok(())
            },
        );
    }
}
