//! Sequence-slot bookkeeping for the continuous-batching engine.
//!
//! The KV cache has `n_slots` fixed sequence slots; this module tracks which
//! slot holds which in-flight request and enforces the allocator invariants
//! (no double allocation, no lost slots) that the proptests pin down.

/// An in-flight generation bound to one KV-cache slot.
#[derive(Debug, Clone)]
pub struct InFlight {
    /// Caller-assigned request id.
    pub request_id: u64,
    /// Prompt token length (cache rows [0, prompt_len) hold the prompt).
    pub prompt_len: usize,
    /// Response tokens generated so far (including EOS when emitted).
    pub tokens: Vec<u32>,
    /// Per-token logprobs (first token from the host sampler, rest from the
    /// compiled decode chunks).
    pub logprobs: Vec<f32>,
    /// Wall-clock start of this request's processing (prefill begin).
    pub started: std::time::Instant,
}

/// Slot table.
#[derive(Debug)]
pub struct SlotTable {
    slots: Vec<Option<InFlight>>,
}

impl SlotTable {
    pub fn new(n_slots: usize) -> SlotTable {
        SlotTable { slots: (0..n_slots).map(|_| None).collect() }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.len() - self.active_count()
    }

    /// Claim a free slot for a request. Returns the slot index.
    pub fn claim(&mut self, inflight: InFlight) -> Option<usize> {
        let idx = self.slots.iter().position(|s| s.is_none())?;
        self.slots[idx] = Some(inflight);
        Some(idx)
    }

    /// Release a slot, returning its in-flight state.
    pub fn release(&mut self, idx: usize) -> Option<InFlight> {
        self.slots[idx].take()
    }

    pub fn get(&self, idx: usize) -> Option<&InFlight> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut InFlight> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    pub fn iter_active(&self) -> impl Iterator<Item = (usize, &InFlight)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|f| (i, f)))
    }

    pub fn iter_active_mut(&mut self) -> impl Iterator<Item = (usize, &mut InFlight)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| s.as_mut().map(|f| (i, f)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;
    use std::time::Instant;

    fn mk(id: u64) -> InFlight {
        InFlight { request_id: id, prompt_len: 4, tokens: vec![], logprobs: vec![], started: Instant::now() }
    }

    #[test]
    fn claim_and_release() {
        let mut t = SlotTable::new(2);
        let a = t.claim(mk(1)).unwrap();
        let b = t.claim(mk(2)).unwrap();
        assert_ne!(a, b);
        assert!(t.claim(mk(3)).is_none(), "table full");
        let released = t.release(a).unwrap();
        assert_eq!(released.request_id, 1);
        assert_eq!(t.free_count(), 1);
        assert!(t.claim(mk(3)).is_some());
    }

    #[test]
    fn prop_allocator_invariants() {
        prop::quick(
            "slot allocator: no double alloc, conservation",
            |rng: &mut Pcg64, size| {
                let n_slots = rng.range(1, 6);
                let ops: Vec<(bool, usize)> = (0..size.scaled(60))
                    .map(|_| (rng.chance(0.6), rng.range(0, n_slots)))
                    .collect();
                (n_slots, ops)
            },
            |(n_slots, ops)| {
                let mut t = SlotTable::new(*n_slots);
                let mut next_id = 0u64;
                let mut live: std::collections::HashSet<u64> = Default::default();
                for &(is_claim, idx) in ops {
                    if is_claim {
                        if let Some(slot) = t.claim(mk(next_id)) {
                            if slot >= *n_slots {
                                return Err("slot index out of range".into());
                            }
                            live.insert(next_id);
                            next_id += 1;
                        } else if t.free_count() != 0 {
                            return Err("claim failed with free slots".into());
                        }
                    } else if let Some(f) = t.release(idx) {
                        if !live.remove(&f.request_id) {
                            return Err(format!("released unknown request {}", f.request_id));
                        }
                    }
                    if t.active_count() != live.len() {
                        return Err(format!(
                            "active {} != live {}",
                            t.active_count(),
                            live.len()
                        ));
                    }
                    if t.active_count() + t.free_count() != *n_slots {
                        return Err("slot conservation violated".into());
                    }
                }
                Ok(())
            },
        );
    }
}
