//! Sequence-slot bookkeeping for the continuous-batching engine.
//!
//! The KV cache has `n_slots` fixed sequence slots; this module tracks which
//! slot holds which in-flight request and enforces the allocator invariants
//! (no double allocation, no lost slots) that the proptests pin down.
//!
//! Allocation is O(log n) via a min-heap free list rather than a linear scan.
//! A *min*-heap (not a plain LIFO stack) is deliberate: it hands out the
//! lowest free index exactly like the original scan, so request→slot
//! assignment — and therefore cache-off engine output — is bit-identical to
//! the pre-free-list engine.

use super::kvcache::Lease;
use crate::util::rng::RequestRng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// An in-flight generation bound to one KV-cache slot.
#[derive(Debug)]
pub struct InFlight {
    /// Caller-assigned request id.
    pub request_id: u64,
    /// This request's own sampling stream, keyed by `(run_seed,
    /// request_id)`. Every random draw for the rollout — the host-side
    /// first-token sample and each compiled decode chunk's per-slot seed —
    /// comes from here at the request's own decode-step counter
    /// (`tokens.len()`), so sampled tokens are independent of which engine,
    /// slot, or admission order served the request.
    pub rng: RequestRng,
    /// Prompt token length (cache rows [0, prompt_len) hold the prompt).
    pub prompt_len: usize,
    /// Response tokens generated so far (including EOS when emitted).
    pub tokens: Vec<u32>,
    /// Per-token logprobs (first token from the host sampler, rest from the
    /// compiled decode chunks).
    pub logprobs: Vec<f32>,
    /// Wall-clock start of this request's processing (prefill begin).
    pub started: std::time::Instant,
    /// Prefix-cache pin held while this request occupies the slot (present
    /// when the engine's shared-prefix KV cache is enabled).
    pub lease: Option<Lease>,
    /// Cross-engine store pin held when this request imported a prefix from
    /// the shared segment store; released at retirement so hot templates
    /// stay resident store-wide while any importer is in flight.
    pub store_lease: Option<crate::store::StoreLease>,
    /// Lifecycle stamps carried from the [`crate::engine::GenRequest`]; the
    /// engine adds admit / first-token / finish when telemetry is on.
    pub timeline: crate::metrics::RequestTimeline,
}

/// Slot table.
#[derive(Debug)]
pub struct SlotTable {
    slots: Vec<Option<InFlight>>,
    /// Free slot indices, lowest first.
    free: BinaryHeap<Reverse<usize>>,
}

impl SlotTable {
    pub fn new(n_slots: usize) -> SlotTable {
        SlotTable {
            slots: (0..n_slots).map(|_| None).collect(),
            free: (0..n_slots).map(Reverse).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.len() == self.slots.len()
    }

    pub fn active_count(&self) -> usize {
        self.len() - self.free.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Claim the lowest free slot for a request. Returns the slot index.
    pub fn claim(&mut self, inflight: InFlight) -> Option<usize> {
        let Reverse(idx) = self.free.pop()?;
        debug_assert!(self.slots[idx].is_none(), "free list handed out a live slot");
        self.slots[idx] = Some(inflight);
        Some(idx)
    }

    /// Release a slot, returning its in-flight state.
    pub fn release(&mut self, idx: usize) -> Option<InFlight> {
        let fl = self.slots[idx].take()?;
        self.free.push(Reverse(idx));
        Some(fl)
    }

    pub fn get(&self, idx: usize) -> Option<&InFlight> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut InFlight> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    pub fn iter_active(&self) -> impl Iterator<Item = (usize, &InFlight)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|f| (i, f)))
    }

    pub fn iter_active_mut(&mut self) -> impl Iterator<Item = (usize, &mut InFlight)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| s.as_mut().map(|f| (i, f)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;
    use std::time::Instant;

    fn mk(id: u64) -> InFlight {
        InFlight {
            request_id: id,
            rng: RequestRng::new(0, id),
            prompt_len: 4,
            tokens: vec![],
            logprobs: vec![],
            started: Instant::now(),
            lease: None,
            store_lease: None,
            timeline: Default::default(),
        }
    }

    #[test]
    fn claim_and_release() {
        let mut t = SlotTable::new(2);
        let a = t.claim(mk(1)).unwrap();
        let b = t.claim(mk(2)).unwrap();
        assert_ne!(a, b);
        assert!(t.claim(mk(3)).is_none(), "table full");
        let released = t.release(a).unwrap();
        assert_eq!(released.request_id, 1);
        assert_eq!(t.free_count(), 1);
        assert!(t.claim(mk(3)).is_some());
    }

    #[test]
    fn claim_returns_lowest_free_index() {
        // The free list must preserve the original linear scan's order so
        // request→slot assignment stays bit-identical.
        let mut t = SlotTable::new(4);
        assert_eq!(t.claim(mk(0)), Some(0));
        assert_eq!(t.claim(mk(1)), Some(1));
        assert_eq!(t.claim(mk(2)), Some(2));
        t.release(2);
        t.release(0);
        assert_eq!(t.claim(mk(3)), Some(0), "lowest free index first");
        assert_eq!(t.claim(mk(4)), Some(2));
        assert_eq!(t.claim(mk(5)), Some(3));
    }

    #[test]
    fn double_release_is_inert() {
        let mut t = SlotTable::new(2);
        let a = t.claim(mk(1)).unwrap();
        assert!(t.release(a).is_some());
        assert!(t.release(a).is_none(), "second release finds nothing");
        assert_eq!(t.free_count(), 2, "double release must not duplicate a free slot");
        assert_eq!(t.claim(mk(2)), Some(a));
        assert!(t.claim(mk(3)).is_some());
        assert!(t.claim(mk(4)).is_none(), "conservation after double release");
    }

    #[test]
    fn prop_allocator_invariants() {
        prop::quick(
            "slot allocator: no double alloc, conservation",
            |rng: &mut Pcg64, size| {
                let n_slots = rng.range(1, 6);
                let ops: Vec<(bool, usize)> = (0..size.scaled(60))
                    .map(|_| (rng.chance(0.6), rng.range(0, n_slots)))
                    .collect();
                (n_slots, ops)
            },
            |(n_slots, ops)| {
                let mut t = SlotTable::new(*n_slots);
                let mut next_id = 0u64;
                let mut live: std::collections::HashSet<u64> = Default::default();
                for &(is_claim, idx) in ops {
                    if is_claim {
                        if let Some(slot) = t.claim(mk(next_id)) {
                            if slot >= *n_slots {
                                return Err("slot index out of range".into());
                            }
                            live.insert(next_id);
                            next_id += 1;
                        } else if t.free_count() != 0 {
                            return Err("claim failed with free slots".into());
                        }
                    } else if let Some(f) = t.release(idx) {
                        if !live.remove(&f.request_id) {
                            return Err(format!("released unknown request {}", f.request_id));
                        }
                    }
                    if t.active_count() != live.len() {
                        return Err(format!(
                            "active {} != live {}",
                            t.active_count(),
                            live.len()
                        ));
                    }
                    if t.active_count() + t.free_count() != *n_slots {
                        return Err("slot conservation violated".into());
                    }
                }
                Ok(())
            },
        );
    }
}
