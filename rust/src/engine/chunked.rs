//! Chunk planning for resumable (chunked) prefill admission.
//!
//! The engine's partial-prefix reuse replaces the monolithic one-shot
//! `prefill` with a multi-step state machine: restore the longest cached
//! prefix, then run the compiled `prefill_chunk` artifact over the uncached
//! suffix one cache-block-sized chunk at a time, publishing every completed
//! prefix back into the cache. This module holds the *pure* planning pieces
//! of that state machine so they are testable without a PJRT runtime; the
//! engine drives the same plan against the compiled artifact, and the tests
//! here drive it against an exact mock model to prove the admission algebra
//! (restore point, chunk boundaries, per-chunk publication, lease hand-over)
//! is bit-preserving.

/// One compiled `prefill_chunk` call: prompt positions `[start, start + len)`
/// (`len <= chunk_tokens`; only the final chunk may be short).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub start: usize,
    pub len: usize,
}

/// Where chunked admission resumes given `matched` cached tokens: every
/// matched row is reused, except that the prompt's last position must always
/// run through a compiled chunk when its logits are not cached (the engine
/// samples the first response token from them).
pub fn resume_point(matched: usize, prompt_len: usize) -> usize {
    matched.min(prompt_len.saturating_sub(1))
}

/// The chunk plan covering `[resume, prompt_len)` in `chunk_tokens`-sized
/// steps. Starts are *not* required to be chunk-aligned — a cached prefix can
/// end anywhere — only bounded by it.
pub fn plan_chunks(prompt_len: usize, resume: usize, chunk_tokens: usize) -> Vec<Chunk> {
    assert!(chunk_tokens > 0, "degenerate chunk size");
    assert!(resume <= prompt_len, "resume past prompt end");
    let mut out = Vec::with_capacity((prompt_len - resume).div_ceil(chunk_tokens));
    let mut start = resume;
    while start < prompt_len {
        let len = chunk_tokens.min(prompt_len - start);
        out.push(Chunk { start, len });
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::kvcache::{
        gather_prompt_rows, gather_rows_range, scatter_prompt_rows, EvictPolicy, KvGeometry,
        Lease, PrefixCache, PrefixCacheCfg,
    };
    use crate::engine::sampler::{sample, SamplerCfg};
    use crate::store::{SharedKvStore, StoreCfg, StoreLease};
    use crate::util::prop;
    use crate::util::rng::{Pcg64, RequestRng};

    #[test]
    fn plans_cover_the_suffix_exactly() {
        for (len, resume, cb) in [(7, 0, 3), (7, 6, 3), (8, 1, 4), (1, 0, 16), (5, 5, 2)] {
            let plan = plan_chunks(len, resume, cb);
            let mut pos = resume;
            for c in &plan {
                assert_eq!(c.start, pos, "contiguous");
                assert!(c.len >= 1 && c.len <= cb);
                pos += c.len;
            }
            assert_eq!(pos, len, "plan covers [{resume}, {len}) with cb={cb}");
        }
        assert!(plan_chunks(5, 5, 2).is_empty());
    }

    #[test]
    fn resume_always_leaves_the_last_position() {
        assert_eq!(resume_point(0, 8), 0);
        assert_eq!(resume_point(5, 8), 5);
        assert_eq!(resume_point(8, 8), 7, "full row match still recomputes logits");
        assert_eq!(resume_point(3, 1), 0);
    }

    // --- exact mock model ---------------------------------------------
    //
    // A stand-in for the compiled artifacts with *exact* (integer-valued
    // f32) arithmetic: KV row p is a hash chain over row p-1 and token p, and
    // the logits hash the final row. Chunk-invariant by construction, so any
    // bit difference between chunked-with-cache admission and a monolithic
    // run is a state-machine bug (wrong restore, wrong boundary, stale rows),
    // not float noise.

    fn seed_row(re: usize) -> Vec<f32> {
        vec![1.0; re]
    }

    fn mock_row(prev: &[f32], tok: u32, re: usize) -> Vec<f32> {
        let mut acc = 0x9E37_79B9_7F4A_7C15u64;
        for &x in prev {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(x as u64);
        }
        acc = acc.wrapping_add(tok as u64 + 1);
        (0..re)
            .map(|e| {
                acc = acc.wrapping_mul(2862933555777941757).wrapping_add(e as u64);
                ((acc >> 33) & 0xFFFF) as f32
            })
            .collect()
    }

    fn mock_logits(last: &[f32]) -> Vec<f32> {
        let mut acc = 7u64;
        for &x in last {
            acc = acc.wrapping_mul(0x0000_0100_0000_01B3).wrapping_add(x as u64);
        }
        (0..4).map(|i| ((acc >> (i * 8)) & 0xFF) as f32).collect()
    }

    /// The mock `prefill_chunk`: rows [0, start) must already be resident in
    /// the slot; computes rows [start, start+len) and the last-row logits.
    fn run_chunk_mock(
        kv: &mut [f32],
        g: &KvGeometry,
        slot: usize,
        prompt: &[u32],
        start: usize,
        len: usize,
    ) -> Vec<f32> {
        let re = g.row_elems();
        let mut rows = gather_prompt_rows(kv, g, slot, start);
        let mut prev = if start == 0 {
            seed_row(re)
        } else {
            rows[(start - 1) * re..start * re].to_vec()
        };
        for i in 0..len {
            let row = mock_row(&prev, prompt[start + i], re);
            rows.extend_from_slice(&row);
            prev = row;
        }
        scatter_prompt_rows(kv, g, slot, &rows);
        mock_logits(&prev)
    }

    /// One "engine"'s view of the shared store in the mock admission:
    /// the store handle, the params version it synced, and the store leases
    /// its in-flight imports hold (released on "retirement").
    struct StoreCtx<'a> {
        store: &'a SharedKvStore,
        version: u64,
        leases: Vec<StoreLease>,
    }

    /// Mirror of the engine's cache-enabled admission over the mock model,
    /// including the cross-engine import/publish steps when a store context
    /// is supplied. Returns (first-token logits, compiled tokens computed).
    fn admit_mock(
        cache: &mut PrefixCache,
        kv: &mut [f32],
        slot: usize,
        prompt: &[u32],
        leases: &mut Vec<Lease>,
        mut store: Option<&mut StoreCtx>,
    ) -> (Vec<f32>, usize) {
        let g = cache.geometry().clone();
        let re = g.row_elems();
        // Cross-engine import (mirrors Engine::import_from_store): warm the
        // local cache from the store before the admission match.
        if let Some(ctx) = store.as_mut() {
            let local = cache.resident_tokens(prompt);
            if local < prompt.len() {
                if let Some(f) = ctx.store.fetch_longest(prompt, local, ctx.version) {
                    match cache.insert_prefix(&prompt[..f.len], &f.rows, f.logits.clone()) {
                        Some(l) => {
                            cache.release(l);
                            ctx.leases.push(f.lease);
                        }
                        None => ctx.store.release(f.lease),
                    }
                }
            }
        }
        let m = cache.match_prefix(prompt);
        if m.matched == prompt.len() {
            if let Some(logits) = m.logits {
                scatter_prompt_rows(kv, &g, slot, &m.rows);
                leases.extend(m.lease);
                return (logits, 0);
            }
        }
        let resume = resume_point(m.matched, prompt.len());
        let mut lease = m.lease;
        if resume == 0 {
            // Cold prompt: monolithic mock call + full insert, like the
            // engine's seed-identical path.
            if let Some(l) = lease.take() {
                cache.release(l);
            }
            let logits = run_chunk_mock(kv, &g, slot, prompt, 0, prompt.len());
            let rows = gather_prompt_rows(kv, &g, slot, prompt.len());
            leases.extend(cache.insert(prompt, &rows, logits.clone()));
            if let Some(ctx) = store.as_mut() {
                ctx.store.publish_aligned(prompt, &rows, Some(&logits), ctx.version, true);
            }
            return (logits, prompt.len());
        }
        let mut rows_acc = m.rows[..resume * re].to_vec();
        scatter_prompt_rows(kv, &g, slot, &rows_acc);
        let mut computed = 0usize;
        let mut logits = Vec::new();
        for c in plan_chunks(prompt.len(), resume, cache.block_tokens()) {
            logits = run_chunk_mock(kv, &g, slot, prompt, c.start, c.len);
            computed += c.len;
            let end = c.start + c.len;
            rows_acc.extend_from_slice(&gather_rows_range(kv, &g, slot, c.start, end));
            let term = (end == prompt.len()).then(|| logits.clone());
            if let Some(nl) = cache.insert_prefix(&prompt[..end], &rows_acc, term) {
                if let Some(old) = lease.take() {
                    cache.release(old);
                }
                lease = Some(nl);
            }
        }
        leases.extend(lease);
        // One cross-engine publication per admission, like the engine.
        if let Some(ctx) = store.as_mut() {
            ctx.store.publish_aligned(prompt, &rows_acc, Some(&logits), ctx.version, true);
        }
        (logits, computed)
    }

    fn tiny_geom() -> KvGeometry {
        KvGeometry { n_layers: 2, n_slots: 2, cache_len: 24, kv_heads: 1, head_dim: 2 }
    }

    fn mk_cache(capacity_blocks: usize, block_tokens: usize) -> PrefixCache {
        PrefixCache::new(
            tiny_geom(),
            PrefixCacheCfg { block_tokens, capacity_blocks, policy: EvictPolicy::Lru },
        )
    }

    fn kv_slab(g: &KvGeometry) -> Vec<f32> {
        vec![0.0; g.n_layers * g.n_slots * 2 * g.cache_len * g.kv_heads * g.head_dim]
    }

    /// Oracle: monolithic mock prefill on a scratch slab.
    fn oracle(g: &KvGeometry, prompt: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let mut kv = kv_slab(g);
        let logits = run_chunk_mock(&mut kv, g, 0, prompt, 0, prompt.len());
        let rows = gather_prompt_rows(&kv, g, 0, prompt.len());
        (logits, rows)
    }

    /// A warm few-shot template is reused across differing suffixes: the
    /// second and later prompts compute only their uncached suffix tokens.
    #[test]
    fn warm_template_costs_only_the_suffix() {
        let g = tiny_geom();
        let mut cache = mk_cache(64, 4);
        let mut kv = kv_slab(&g);
        let mut leases = Vec::new();
        let template: Vec<u32> = (0..12).map(|i| 3 + (i % 5)).collect();

        let mk = |q: &[u32]| [&template[..], q].concat();
        let (_, computed) = admit_mock(&mut cache, &mut kv, 0, &mk(&[30, 31]), &mut leases, None);
        assert_eq!(computed, 14, "cold prompt computes everything");

        let suffixes: [&[u32]; 3] = [&[40, 41], &[50, 51, 52], &[60]];
        for (i, q) in suffixes.into_iter().enumerate() {
            let prompt = mk(q);
            let (logits, computed) = admit_mock(&mut cache, &mut kv, 1, &prompt, &mut leases, None);
            assert_eq!(
                computed,
                q.len(),
                "warm prompt {i} must compute only its uncached suffix"
            );
            let (want_logits, want_rows) = oracle(&g, &prompt);
            assert_eq!(logits, want_logits, "warm prompt {i} logits");
            assert_eq!(
                gather_prompt_rows(&kv, &g, 1, prompt.len()),
                want_rows,
                "warm prompt {i} rows"
            );
            cache.check().unwrap();
        }
        // Re-admitting an already-seen prompt is a full hit: zero compute.
        let (_, computed) = admit_mock(&mut cache, &mut kv, 1, &mk(&[50, 51, 52]), &mut leases, None);
        assert_eq!(computed, 0);
        assert!(cache.stats.hits >= 1);
        for l in leases {
            cache.release(l);
        }
        cache.check().unwrap();
    }

    /// Cross-engine acceptance (mock model): engine A admits a template
    /// prompt cold and publishes it; engine B — its own cache, its own KV
    /// slab, same store — admits a different prompt sharing the template and
    /// computes only the tokens past the store's block-aligned coverage,
    /// with logits and KV rows bit-identical to a monolithic prefill. An
    /// identical third engine *without* the store recomputes the template,
    /// proving the imported rows equal local compute bit-for-bit.
    #[test]
    fn cross_engine_import_is_bit_exact_and_saves_compute() {
        let g = tiny_geom();
        let bt = 4usize;
        let store = SharedKvStore::new(StoreCfg {
            block_tokens: bt,
            capacity_blocks: 64,
            policy: EvictPolicy::Lru,
            shards: 2,
        });
        store.set_version(1);
        let mut ctx_a = StoreCtx { store: &store, version: 1, leases: Vec::new() };
        let mut ctx_b = StoreCtx { store: &store, version: 1, leases: Vec::new() };
        let template: Vec<u32> = (0..12).map(|i| 3 + (i % 5)).collect(); // 3 full blocks
        let pa: Vec<u32> = [&template[..], &[30, 31]].concat();
        let pb: Vec<u32> = [&template[..], &[40, 41, 42]].concat();

        // Engine A: cold admission, publishes template+suffix to the store.
        let mut cache_a = mk_cache(64, bt);
        let mut kv_a = kv_slab(&g);
        let mut leases_a = Vec::new();
        let (_, computed_a) =
            admit_mock(&mut cache_a, &mut kv_a, 0, &pa, &mut leases_a, Some(&mut ctx_a));
        assert_eq!(computed_a, pa.len(), "cold leader computes everything");
        assert!(store.stats().publishes >= 1);

        // Engine B: local cache cold, but the store covers the template's
        // block-aligned prefix — B computes only the remainder.
        let mut cache_b = mk_cache(64, bt);
        let mut kv_b = kv_slab(&g);
        let mut leases_b = Vec::new();
        let (logits_b, computed_b) =
            admit_mock(&mut cache_b, &mut kv_b, 1, &pb, &mut leases_b, Some(&mut ctx_b));
        assert_eq!(
            computed_b,
            pb.len() - template.len(),
            "import must cover the whole (block-aligned) template"
        );
        assert_eq!(ctx_b.leases.len(), 1, "importer holds a store lease");
        assert!(store.leased_blocks() > 0, "imported segments pinned");
        let (want_logits, want_rows) = oracle(&g, &pb);
        assert_eq!(logits_b, want_logits, "imported admission logits diverge");
        assert_eq!(gather_prompt_rows(&kv_b, &g, 1, pb.len()), want_rows);

        // Store-less engine C on the same prompt: rows from local compute
        // must equal what B imported, bit for bit.
        let mut cache_c = mk_cache(64, bt);
        let mut kv_c = kv_slab(&g);
        let mut leases_c = Vec::new();
        let (logits_c, computed_c) =
            admit_mock(&mut cache_c, &mut kv_c, 0, &pb, &mut leases_c, None);
        assert_eq!(computed_c, pb.len());
        assert_eq!(logits_c, logits_b);
        assert_eq!(
            gather_prompt_rows(&kv_c, &g, 0, pb.len()),
            gather_prompt_rows(&kv_b, &g, 1, pb.len()),
            "import != local compute"
        );

        // Unaligned tails are deliberately not published (dead weight for
        // any non-identical prompt): re-admitting B's prompt on A resumes
        // from the shared template — local in A's case — and computes only
        // the 3-token suffix, not zero.
        let mut leases_a2 = Vec::new();
        let (_, computed) =
            admit_mock(&mut cache_a, &mut kv_a, 1, &pb, &mut leases_a2, Some(&mut ctx_a));
        assert_eq!(computed, pb.len() - template.len(), "tails must not be shared");

        // A *block-aligned* prompt publishes in full, terminal logits
        // included, so another engine's byte-identical admission is a
        // zero-compute store hit.
        let pc: Vec<u32> = [&template[..], &[70, 71, 72, 73]].concat(); // 16 = 4 blocks
        let (_, computed) =
            admit_mock(&mut cache_a, &mut kv_a, 0, &pc, &mut leases_a2, Some(&mut ctx_a));
        assert_eq!(computed, pc.len() - template.len());
        let (logits_c2, computed) =
            admit_mock(&mut cache_b, &mut kv_b, 0, &pc, &mut leases_b, Some(&mut ctx_b));
        assert_eq!(computed, 0, "aligned full-prompt store hit skips all compute");
        assert_eq!(logits_c2, oracle(&g, &pc).0);

        // Retirement releases every pin; a version bump drains the store.
        for l in leases_a.into_iter().chain(leases_a2) {
            cache_a.release(l);
        }
        for l in leases_b {
            cache_b.release(l);
        }
        for l in leases_c {
            cache_c.release(l);
        }
        for ctx in [ctx_a, ctx_b] {
            for l in ctx.leases {
                store.release(l);
            }
        }
        assert_eq!(store.leased_blocks(), 0);
        store.set_version(2);
        assert_eq!(store.live_blocks(), 0);
        cache_a.check().unwrap();
        cache_b.check().unwrap();
        store.check().unwrap();
    }

    /// Sampling placement-independence at the admission seam: the first
    /// response token is drawn from the request's own stream
    /// ([`RequestRng`]) over logits the admission algebra guarantees
    /// bit-equal — so for any assignment of requests to two mock engines
    /// and any admission order, every request samples exactly the token the
    /// single-engine in-order oracle samples. This is the property the old
    /// per-engine `Pcg64` (consumed in admission order) violated at
    /// temperature > 0.
    #[test]
    fn prop_first_token_sampling_is_placement_independent() {
        prop::quick(
            "first-token sample independent of placement and admission order",
            |rng: &mut Pcg64, size| {
                let run_seed = rng.next_u64();
                let n = rng.range(2, size.scaled(10).max(2) + 2);
                let n_templates = rng.range(1, 3);
                let templates: Vec<Vec<u32>> = (0..n_templates)
                    .map(|_| (0..rng.range(1, 10)).map(|_| rng.range(0, 6) as u32).collect())
                    .collect();
                let prompts: Vec<Vec<u32>> = (0..n)
                    .map(|_| {
                        let mut p = templates[rng.range(0, n_templates)].clone();
                        p.extend((0..rng.range(0, 5)).map(|_| rng.range(0, 6) as u32));
                        p.truncate(20); // keep within cache_len
                        p
                    })
                    .collect();
                // Fisher–Yates permutation of the admission order.
                let mut perm: Vec<usize> = (0..n).collect();
                for i in (1..n).rev() {
                    let j = rng.range(0, i + 1);
                    perm.swap(i, j);
                }
                // Which of the two mock engines serves each request.
                let assign: Vec<usize> = (0..n).map(|_| rng.range(0, 2)).collect();
                (run_seed, prompts, perm, assign)
            },
            |(run_seed, prompts, perm, assign)| {
                let cfg = SamplerCfg { temperature: 1.0, top_p: 0.9, top_k: 3 };
                // The request's own stream at step 0, over logits scaled
                // into a range where the categorical draw is non-degenerate.
                let first_token = |id: u64, logits: &[f32]| -> (u32, f32) {
                    let soft: Vec<f32> = logits.iter().map(|l| l / 32.0).collect();
                    let mut g = RequestRng::new(*run_seed, id).at_step(0);
                    sample(&soft, &cfg, &mut g)
                };
                let g = tiny_geom();
                // Oracle: one engine, dispatch order.
                let mut oracle_tokens = Vec::new();
                {
                    let mut cache = mk_cache(64, 4);
                    let mut kv = kv_slab(&g);
                    let mut leases: Vec<Lease> = Vec::new();
                    for (id, prompt) in prompts.iter().enumerate() {
                        let (logits, _) = admit_mock(
                            &mut cache,
                            &mut kv,
                            id % g.n_slots,
                            prompt,
                            &mut leases,
                            None,
                        );
                        oracle_tokens.push(first_token(id as u64, &logits));
                    }
                }
                // Two engines, permuted admission order: same tokens per id.
                let mut caches = [mk_cache(64, 4), mk_cache(64, 4)];
                let mut kvs = [kv_slab(&g), kv_slab(&g)];
                let mut leases: [Vec<Lease>; 2] = [Vec::new(), Vec::new()];
                for &i in perm {
                    let e = assign[i];
                    let (logits, _) = admit_mock(
                        &mut caches[e],
                        &mut kvs[e],
                        i % g.n_slots,
                        &prompts[i],
                        &mut leases[e],
                        None,
                    );
                    let got = first_token(i as u64, &logits);
                    if got != oracle_tokens[i] {
                        return Err(format!(
                            "request {i} sampled {got:?} on engine {e}, oracle says {:?}",
                            oracle_tokens[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// The acceptance proptest: for any chunk size, any prompt mix (shared
    /// templates force partial hits at arbitrary offsets), any interleaving
    /// of retirements and flushes, chunked admission produces logits and KV
    /// rows bit-identical to a monolithic prefill of the same prompt, never
    /// computes more than the uncached suffix, and keeps every cache
    /// invariant intact.
    #[test]
    fn prop_chunked_equals_monolithic_bit_exact() {
        prop::quick(
            "chunked prefill == monolithic (bit-exact, any chunking)",
            |rng: &mut Pcg64, size| {
                let block_tokens = rng.range(1, 7);
                let capacity = rng.range(6, 40);
                let n_templates = rng.range(1, 4);
                let templates: Vec<Vec<u32>> = (0..n_templates)
                    .map(|_| (0..rng.range(1, 12)).map(|_| rng.range(0, 6) as u32).collect())
                    .collect();
                let ops: Vec<(u64, Vec<u32>)> = (0..size.scaled(40))
                    .map(|_| {
                        let t = &templates[rng.range(0, n_templates)];
                        let suffix_len = rng.range(0, 6);
                        let mut p = t.clone();
                        p.extend((0..suffix_len).map(|_| rng.range(0, 6) as u32));
                        if p.len() > 20 {
                            p.truncate(20); // keep within cache_len
                        }
                        (rng.next_u64(), p)
                    })
                    .collect();
                (block_tokens, capacity, ops)
            },
            |(block_tokens, capacity, ops)| {
                let g = tiny_geom();
                let mut cache = mk_cache(*capacity, *block_tokens);
                let mut kv = kv_slab(&g);
                let mut leases: Vec<Lease> = Vec::new();
                for (op, prompt) in ops {
                    match op % 8 {
                        0..=5 => {
                            let slot = (*op as usize / 8) % g.n_slots;
                            let before = cache.stats.clone();
                            let (logits, computed) =
                                admit_mock(&mut cache, &mut kv, slot, prompt, &mut leases, None);
                            let (want_logits, want_rows) = oracle(&g, prompt);
                            if logits != want_logits {
                                return Err(format!("logits diverge for {prompt:?}"));
                            }
                            if gather_prompt_rows(&kv, &g, slot, prompt.len()) != want_rows {
                                return Err(format!("kv rows diverge for {prompt:?}"));
                            }
                            if computed > prompt.len() {
                                return Err("computed more than the prompt".into());
                            }
                            // Token accounting: exactly this prompt's tokens
                            // were split between hit and miss.
                            let d_hit = cache.stats.hit_tokens - before.hit_tokens;
                            let d_miss = cache.stats.miss_tokens - before.miss_tokens;
                            if d_hit + d_miss != prompt.len() as u64 {
                                return Err(format!(
                                    "accounting: {d_hit}+{d_miss} != {}",
                                    prompt.len()
                                ));
                            }
                            // Exactly the tokens accounted as misses were
                            // computed: the cache credits only rows the
                            // admission actually restores.
                            if computed != d_miss as usize {
                                return Err(format!(
                                    "computed {computed} != uncached {d_miss}"
                                ));
                            }
                        }
                        6 => {
                            if !leases.is_empty() {
                                let i = (*op as usize / 8) % leases.len();
                                cache.release(leases.swap_remove(i));
                            }
                        }
                        _ => {
                            cache.clear();
                            leases.clear();
                        }
                    }
                    cache.check().map_err(|e| format!("after {prompt:?}: {e}"))?;
                }
                Ok(())
            },
        );
    }
}
