//! Foundation substrates built from scratch for the offline environment
//! (no serde / rand / clap / criterion / proptest available): JSON, RNG,
//! CLI parsing, bench harness, and a mini property-testing framework.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
