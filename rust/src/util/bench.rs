//! Benchmark harness (no `criterion` in the offline environment).
//!
//! Each `benches/*.rs` target sets `harness = false` and drives this module:
//! warmup + timed repetitions with robust statistics, plus an aligned table
//! printer used to emit exactly the rows the paper's tables report
//! (paper value alongside measured value and the win-factor).

use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Statistics for a set of timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_durations(mut xs: Vec<Duration>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort();
        let n = xs.len();
        let total: Duration = xs.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = xs
            .iter()
            .map(|d| {
                let diff = d.as_secs_f64() - mean_s;
                diff * diff
            })
            .sum::<f64>()
            / n as f64;
        let pct = |p: f64| xs[((n as f64 - 1.0) * p).round() as usize];
        Stats {
            n,
            mean,
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: xs[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: xs[n - 1],
        }
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// True when `PA_RL_BENCH_QUICK=1` — CI smoke mode: [`bench`] shrinks its
/// warmup and repetition counts so a bench target finishes in seconds and
/// its emitted `BENCH_*.json` record can be schema-validated cheaply. The
/// numbers are noisier; the record's `iters` field says how many runs each
/// value came from.
pub fn quick_mode() -> bool {
    std::env::var("PA_RL_BENCH_QUICK").is_ok_and(|v| v == "1")
}

/// Time `f` with warmup. `label` is printed as progress on stderr.
/// Under [`quick_mode`] the counts are clamped to a handful of runs.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    let (warmup, iters) = if quick_mode() {
        (warmup.min(2), iters.clamp(2, 10))
    } else {
        (warmup, iters)
    };
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let stats = Stats::from_durations(times);
    eprintln!(
        "  [bench] {label}: mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms  (n={})",
        stats.mean.as_secs_f64() * 1e3,
        stats.p50.as_secs_f64() * 1e3,
        stats.p95.as_secs_f64() * 1e3,
        stats.n
    );
    stats
}

/// Quick-and-dirty throughput helper: items per second over one timed call.
pub fn throughput<F: FnOnce() -> usize>(f: F) -> (usize, f64, f64) {
    let t0 = Instant::now();
    let items = f();
    let secs = t0.elapsed().as_secs_f64();
    (items, secs, items as f64 / secs.max(1e-12))
}

/// Machine-readable benchmark record, written as `BENCH_<name>.json` at the
/// repository root — the in-tree perf trajectory: each optimisation PR
/// re-runs the bench and commits the refreshed record, and CI validates the
/// files against the schema with `scripts/check_bench_json.py`.
///
/// Schema: `{"bench": <name>, "source": <provenance>, "metrics":
/// [{"metric", "value", "unit", "iters"}, ...]}` with at least five metric
/// entries per record.
#[derive(Debug, Clone)]
pub struct BenchRecorder {
    bench: String,
    source: String,
    metrics: Vec<BenchMetric>,
}

#[derive(Debug, Clone)]
struct BenchMetric {
    metric: String,
    value: f64,
    unit: String,
    iters: usize,
}

impl BenchRecorder {
    /// `bench` names the output file (`BENCH_<bench>.json`); `source` records
    /// provenance (which target produced the numbers, and on what).
    pub fn new(bench: &str, source: &str) -> BenchRecorder {
        BenchRecorder { bench: bench.to_string(), source: source.to_string(), metrics: Vec::new() }
    }

    /// Append one metric. `iters` is how many timed runs the value came from
    /// (0 for derived/analytic values).
    pub fn push(&mut self, metric: &str, value: f64, unit: &str, iters: usize) {
        self.metrics.push(BenchMetric {
            metric: metric.to_string(),
            value,
            unit: unit.to_string(),
            iters,
        });
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str(&self.bench)),
            ("source", Json::str(&self.source)),
            (
                "metrics",
                Json::arr(self.metrics.iter().map(|m| {
                    Json::obj(vec![
                        ("metric", Json::str(&m.metric)),
                        ("value", Json::num(m.value)),
                        ("unit", Json::str(&m.unit)),
                        ("iters", Json::num(m.iters as f64)),
                    ])
                })),
            ),
        ])
    }

    /// The repository root: the parent of this crate's manifest directory.
    pub fn repo_root() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."))
    }

    /// Where [`BenchRecorder::write`] puts this record.
    pub fn path(&self) -> PathBuf {
        Self::repo_root().join(format!("BENCH_{}.json", self.bench))
    }

    /// Write the record into `dir`; returns the file path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, format!("{}\n", self.to_json().to_pretty()))?;
        Ok(path)
    }

    /// Write the record at the repository root (the committed convention).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(&Self::repo_root())
    }
}

/// An aligned text table used by the bench binaries to print paper-style rows.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn note(&mut self, note: &str) -> &mut Self {
        self.notes.push(note.to_string());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for note in &self.notes {
            out.push_str(&format!("  note: {note}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a speedup factor like the paper ("3.12x").
pub fn fx(factor: f64) -> String {
    format!("{factor:.2}x")
}

/// Format a float with 3 decimals (TPSPD style).
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_durations(xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert_eq!(s.p50, Duration::from_millis(20));
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Setting", "TPSPD", "Speedup"]);
        t.row_strs(&["Sync (ours)", "99.966", "1.00x"]);
        t.row_strs(&["Async (ours)", "192.259", "1.92x"]);
        t.note("example");
        let r = t.render();
        assert!(r.contains("Async (ours)"));
        assert!(r.contains("== Demo =="));
        assert!(r.contains("note: example"));
        // All data lines share the same width.
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fx(3.118), "3.12x");
        assert_eq!(f3(192.2591), "192.259");
    }

    #[test]
    fn quick_mode_defaults_off() {
        // Tests run without PA_RL_BENCH_QUICK, so bench() must honour the
        // requested counts (bench_runs_expected_iters relies on this).
        assert!(!quick_mode());
    }

    #[test]
    fn bench_recorder_schema_and_write() {
        let mut r = BenchRecorder::new("demo", "unit test");
        assert!(r.is_empty());
        for i in 0..5 {
            r.push(&format!("m{i}"), i as f64, "us", 10);
        }
        assert_eq!(r.len(), 5);
        let j = r.to_json();
        assert_eq!(j.req_str("bench").unwrap(), "demo");
        assert_eq!(j.req_str("source").unwrap(), "unit test");
        let ms = j.req("metrics").unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 5);
        assert_eq!(ms[0].req_str("metric").unwrap(), "m0");
        assert_eq!(ms[2].req_f64("value").unwrap(), 2.0);
        assert_eq!(ms[0].req_str("unit").unwrap(), "us");
        assert_eq!(ms[0].req_f64("iters").unwrap(), 10.0);
        // the committed convention: BENCH_<name>.json at the repo root
        assert_eq!(r.path().file_name().unwrap().to_str().unwrap(), "BENCH_demo.json");
        assert_eq!(r.path().parent().unwrap(), BenchRecorder::repo_root());
        // round-trips through the writer
        let dir = std::env::temp_dir().join("pa_rl_bench_recorder_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = r.write_to(&dir).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.req("metrics").unwrap().as_arr().unwrap().len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
