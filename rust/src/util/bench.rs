//! Benchmark harness (no `criterion` in the offline environment).
//!
//! Each `benches/*.rs` target sets `harness = false` and drives this module:
//! warmup + timed repetitions with robust statistics, plus an aligned table
//! printer used to emit exactly the rows the paper's tables report
//! (paper value alongside measured value and the win-factor).

use std::time::{Duration, Instant};

/// Statistics for a set of timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_durations(mut xs: Vec<Duration>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort();
        let n = xs.len();
        let total: Duration = xs.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = xs
            .iter()
            .map(|d| {
                let diff = d.as_secs_f64() - mean_s;
                diff * diff
            })
            .sum::<f64>()
            / n as f64;
        let pct = |p: f64| xs[((n as f64 - 1.0) * p).round() as usize];
        Stats {
            n,
            mean,
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: xs[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: xs[n - 1],
        }
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` with warmup. `label` is printed as progress on stderr.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let stats = Stats::from_durations(times);
    eprintln!(
        "  [bench] {label}: mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms  (n={})",
        stats.mean.as_secs_f64() * 1e3,
        stats.p50.as_secs_f64() * 1e3,
        stats.p95.as_secs_f64() * 1e3,
        stats.n
    );
    stats
}

/// Quick-and-dirty throughput helper: items per second over one timed call.
pub fn throughput<F: FnOnce() -> usize>(f: F) -> (usize, f64, f64) {
    let t0 = Instant::now();
    let items = f();
    let secs = t0.elapsed().as_secs_f64();
    (items, secs, items as f64 / secs.max(1e-12))
}

/// An aligned text table used by the bench binaries to print paper-style rows.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn note(&mut self, note: &str) -> &mut Self {
        self.notes.push(note.to_string());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for note in &self.notes {
            out.push_str(&format!("  note: {note}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a speedup factor like the paper ("3.12x").
pub fn fx(factor: f64) -> String {
    format!("{factor:.2}x")
}

/// Format a float with 3 decimals (TPSPD style).
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_durations(xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert_eq!(s.p50, Duration::from_millis(20));
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Setting", "TPSPD", "Speedup"]);
        t.row_strs(&["Sync (ours)", "99.966", "1.00x"]);
        t.row_strs(&["Async (ours)", "192.259", "1.92x"]);
        t.note("example");
        let r = t.render();
        assert!(r.contains("Async (ours)"));
        assert!(r.contains("== Demo =="));
        assert!(r.contains("note: example"));
        // All data lines share the same width.
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fx(3.118), "3.12x");
        assert_eq!(f3(192.2591), "192.259");
    }
}
