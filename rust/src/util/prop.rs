//! Miniature property-based testing harness.
//!
//! The offline environment has no `proptest`/`quickcheck`, so pa-rl provides a
//! small seeded property harness: a generator closure produces random cases
//! from a [`Pcg64`], a checker validates each case, and on
//! failure the harness retries a bounded number of "shrink" passes by asking
//! the generator for *smaller* cases (via a shrink hint), then reports the
//! failing seed so the case is exactly reproducible.
//!
//! Used throughout the coordinator/engine/sim tests for the paper's invariants
//! (gradient permutation invariance, on-policy version tags, queue
//! completeness, KV-slot safety, packing round-trips, ...).

use super::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Root seed; each case uses a derived stream so failures name one seed.
    pub seed: u64,
    /// Max shrink attempts after a failure (re-generating with reduced size).
    pub shrink_rounds: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Root seed can be pinned for reproduction via PA_RL_PROP_SEED.
        let seed = std::env::var("PA_RL_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        PropConfig { cases: 64, seed, shrink_rounds: 32 }
    }
}

/// Size hint passed to generators: starts small, grows across cases, and is
/// driven back toward zero during shrinking.
#[derive(Debug, Clone, Copy)]
pub struct Size(pub usize);

impl Size {
    /// Scale a nominal maximum by the current size (at least 1).
    pub fn scaled(&self, max: usize) -> usize {
        (max * self.0 / 100).max(1)
    }
}

/// Run a property: `gen` builds a case, `check` returns `Err(reason)` on
/// violation. Panics with a reproducible report on failure.
pub fn check<T, G, C>(name: &str, cfg: PropConfig, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64, Size) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case_idx in 0..cfg.cases {
        // size ramps 1..100 over the run
        let size = Size(1 + (case_idx * 99) / cfg.cases.max(1));
        let mut rng = Pcg64::new(cfg.seed, case_idx as u64 + 1);
        let case = gen(&mut rng, size);
        if let Err(reason) = check(&case) {
            // Attempt shrink passes: same stream, smaller sizes.
            let mut best: (Size, T, String) = (size, case, reason);
            for round in 0..cfg.shrink_rounds {
                let smaller = Size((best.0 .0 * 2 / 3).max(1));
                if smaller.0 >= best.0 .0 {
                    break;
                }
                let mut rng = Pcg64::new(cfg.seed, case_idx as u64 + 1 + (round as u64) << 32);
                let candidate = gen(&mut rng, smaller);
                if let Err(r) = check(&candidate) {
                    best = (smaller, candidate, r);
                }
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {seed}, size {sz}):\n  reason: {reason}\n  case: {case:?}\n  reproduce with PA_RL_PROP_SEED={seed}",
                seed = cfg.seed,
                sz = best.0 .0,
                reason = best.2,
                case = best.1,
            );
        }
    }
}

/// Convenience wrapper with default config.
pub fn quick<T, G, C>(name: &str, gen: G, check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64, Size) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    self::check(name, PropConfig::default(), gen, check)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        quick(
            "reverse-involution",
            |rng, size| {
                let n = rng.range(0, size.scaled(64) + 1);
                (0..n).map(|_| rng.next_u64()).collect::<Vec<_>>()
            },
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                if r == *xs {
                    Ok(())
                } else {
                    Err("reverse twice changed the vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_report() {
        quick(
            "always-fails",
            |rng, _| rng.range(0, 100),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_len = 0usize;
        check(
            "size-ramp",
            PropConfig { cases: 50, seed: 1, shrink_rounds: 0 },
            |rng, size| {
                let n = size.scaled(1000);
                max_len = max_len.max(n);
                rng.range(0, n + 1)
            },
            |_| Ok(()),
        );
        assert!(max_len > 500, "expected late cases to be large, got {max_len}");
    }
}
