//! Minimal JSON parser / writer.
//!
//! The offline environment has no `serde`, so pa-rl carries its own JSON
//! substrate. It is used for (1) `artifacts/<cfg>/manifest.json` written by the
//! python AOT pipeline and consumed by the rust runtime, (2) run configuration
//! files under `configs/`, and (3) machine-readable outputs (timeline traces,
//! bench reports, experiment CSVs' sidecars).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Object key order is preserved (insertion order) so
//! manifests round-trip stably.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (key, value) pairs.
    Obj(Vec<(String, Json)>),
}

/// Error produced by the parser, with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors ---------------------------------------------------------

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup by path, e.g. `j.path(&["model", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Array of usize (e.g. a shape).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    /// Required-field helpers that produce descriptive errors.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing key '{key}'"), offset: 0 })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError { msg: format!("key '{key}' is not a string"), offset: 0 })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| JsonError { msg: format!("key '{key}' is not a non-negative integer"), offset: 0 })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError { msg: format!("key '{key}' is not a number"), offset: 0 })
    }

    /// Optional-with-default lookups used by the config system.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Convert an object into a BTreeMap view (copies keys).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---- parsing -----------------------------------------------------------

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- writing -----------------------------------------------------------

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Handle UTF-16 surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            self.i -= 1; // compensate the +1 below
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::obj(vec![("k", Json::str("a\"b\\c\nd\te\u{1}"))]);
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_and_surrogates() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
        // Raw multibyte passthrough.
        let j = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn errors_report_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::parse(r#"{"model":{"d":256,"layers":[1,2,3]},"ok":true}"#).unwrap();
        let pretty = j.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn key_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integers_survive_exactly() {
        let j = Json::parse("1234567890123").unwrap();
        assert_eq!(j.as_i64().unwrap(), 1234567890123);
        assert_eq!(j.to_string(), "1234567890123");
    }

    #[test]
    fn accessors_defaults() {
        let j = Json::parse(r#"{"n": 5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(j.usize_or("n", 0), 5);
        assert_eq!(j.usize_or("missing", 7), 7);
        assert_eq!(j.str_or("s", "d"), "x");
        assert_eq!(j.str_or("missing", "d"), "d");
        assert!(j.bool_or("b", false));
        assert!(j.req("missing").is_err());
    }
}
