//! Deterministic pseudo-random number generation and sampling distributions.
//!
//! The offline build environment provides no `rand` crate, so pa-rl carries its
//! own RNG substrate: a PCG-XSH-RR 64/32-based 64-bit generator (`Pcg64`) with
//! explicit seeding, stream splitting, and the distributions the system needs
//! (uniform, normal, lognormal, categorical, permutation). Everything in the
//! repository that needs randomness threads one of these through explicitly so
//! runs are reproducible from a single root seed.

/// A 64-bit PCG-family generator (two xsh-rr 32-bit outputs per `next_u64`).
///
/// Deterministic, seedable, and cheap to fork into independent streams —
/// exactly what the coordinator needs to give each rollout worker / simulator
/// entity its own reproducible stream.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second normal variate from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 step: add the golden-gamma constant, then run the finalizer.
/// A bijection on `u64` — distinct inputs provably map to distinct outputs —
/// which is what makes the stream derivations below collision-free.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different `stream`
    /// values with the same seed yield statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Fork an independent child stream; advances `self`.
    ///
    /// The stream id is splitmixed and folded into *both* the child's seed
    /// and its increment. `new` can only honour the low 63 bits of a stream
    /// id (the increment is `(stream << 1) | 1`), so ids whose mixed values
    /// differ only in the top bit alias to the same increment — the seed
    /// perturbation keeps even those children on provably distinct streams.
    /// (The previous `stream * GOLDEN` derivation collapsed ids `a` and
    /// `a + 2^63` to the *same* generator outright.)
    pub fn fork(&mut self, stream: u64) -> Self {
        let h = splitmix64(stream);
        Self::new(self.next_u64() ^ h, h)
    }

    /// Counter-based constructor: the generator is a pure function of
    /// `(seed, stream)` with no parent state and no warm-up draws to share.
    /// For a fixed seed, distinct stream ids provably yield distinct
    /// generators: `splitmix64` is a bijection, so the derived states
    /// differ whenever the ids do (the independently derived odd increment
    /// additionally decorrelates the sequences). This is the substrate for
    /// per-request sampling streams ([`RequestRng`]), where draws must be
    /// keyed by identity, never by the order in which anything happened.
    pub fn from_stream(seed: u64, stream: u64) -> Self {
        let state = splitmix64(stream ^ splitmix64(seed));
        let inc = splitmix64(stream ^ splitmix64(seed ^ 0x5851_F42D_4C95_7F2D)) | 1;
        Pcg64 { state, inc, spare_normal: None }
    }

    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (Lemire-style rejection-free mapping is
    /// unnecessary here; modulo bias is negligible for our ranges but we use
    /// widening multiply anyway for correctness).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = hi - lo;
        // widening-multiply trick: floor(x * span / 2^64)
        let x = self.next_u64();
        lo + ((x as u128 * span as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterised by the *underlying* normal's mu/sigma.
    /// Used by the simulator for rollout-length distributions (long right
    /// tail, matching CoT response lengths).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (inter-arrival times in the simulator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Sample an index from unnormalised non-negative weights.
    /// Returns `None` if all weights are zero/empty.
    pub fn categorical(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

/// Per-request sampling stream: every draw for a rollout is keyed by
/// `(run_seed, request_id, decode_step)` and nothing else.
///
/// The stream is *counter-based* — [`RequestRng::at_step`] returns a fresh
/// generator for one decode step as a pure function of the key, so the draw
/// at step `k` does not depend on how many draws any other step (or any
/// other request) made. That is exactly the property that makes sampled
/// tokens identical across engine placements, admission orders, chunked vs.
/// monolithic prefill, and cache on/off: the only inputs are the request's
/// identity and its own position in its own response.
///
/// Distinctness is provable, not statistical: `key = splitmix64(request_id
/// ^ splitmix64(run_seed))` is injective in `request_id` for a fixed seed
/// (bijection composed with xor-by-constant), and [`Pcg64::from_stream`]
/// keeps the per-step states injective in `(key, step)` the same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestRng {
    /// Mixed `(run_seed, request_id)` key; distinct requests under one run
    /// seed are guaranteed distinct keys.
    key: u64,
}

impl RequestRng {
    pub fn new(run_seed: u64, request_id: u64) -> RequestRng {
        RequestRng { key: splitmix64(request_id ^ splitmix64(run_seed)) }
    }

    /// The generator for decode step `step` of this request (step 0 is the
    /// first response token, sampled host-side from the prefill logits).
    pub fn at_step(&self, step: u64) -> Pcg64 {
        Pcg64::from_stream(self.key, step)
    }

    /// The compiled decode chunk's per-slot seed for the chunk whose first
    /// sampled token is decode step `step`. The full 64-bit draw is
    /// xor-folded into 32 bits — no truncation bias, and the `u32 -> i32`
    /// bit-cast is lossless (the compiled sampler consumes it as raw PRNG
    /// key material, sign included).
    pub fn decode_seed(&self, step: u64) -> i32 {
        let mut g = self.at_step(step);
        let s = g.next_u64();
        (((s >> 32) ^ s) as u32) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be effectively independent");
    }

    /// Regression (PR 9): the old fork derivation `(stream * GOLDEN)` fed
    /// through `new`'s `(stream << 1) | 1` discarded the product's top bit,
    /// so ids `a` and `a + 2^63` produced the *same* child generator. The
    /// splitmix derivation must keep adjacent and high-bit-differing ids on
    /// distinct streams.
    #[test]
    fn fork_streams_distinct_for_adjacent_and_high_bit_ids() {
        let parent = Pcg64::new(7, 3);
        let seq = |stream: u64| {
            let mut p = parent.clone();
            let mut child = p.fork(stream);
            (0..16).map(|_| child.next_u64()).collect::<Vec<_>>()
        };
        let ids = [0u64, 1, 2, 3, 1 << 63, (1 << 63) | 1, 42, 42 + (1 << 63)];
        for (i, &a) in ids.iter().enumerate() {
            for &b in &ids[i + 1..] {
                assert_ne!(seq(a), seq(b), "fork streams {a:#x} and {b:#x} alias");
            }
        }
    }

    #[test]
    fn from_stream_is_deterministic_and_streams_differ() {
        let mut a = Pcg64::from_stream(9, 5);
        let mut b = Pcg64::from_stream(9, 5);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Adjacent and high-bit-differing stream ids must not alias.
        for (x, y) in [(0u64, 1u64), (5, 6), (0, 1 << 63), (7, 7 + (1 << 63))] {
            let mut gx = Pcg64::from_stream(9, x);
            let mut gy = Pcg64::from_stream(9, y);
            let same = (0..64).filter(|_| gx.next_u64() == gy.next_u64()).count();
            assert!(same < 2, "streams {x:#x}/{y:#x} correlate ({same} equal draws)");
        }
    }

    /// Regression (PR 9 satellite): per-request decode seeds must be
    /// distinct across requests and reproducible across calls — the old
    /// engine derivation (`engine_rng.next_u64() as i32`, one shared scalar
    /// per chunk) was neither per-request nor full-width.
    #[test]
    fn request_decode_seeds_distinct_and_reproducible() {
        let a = RequestRng::new(41, 0);
        let b = RequestRng::new(41, 1);
        for step in [0u64, 1, 17, 1 + (1 << 32)] {
            assert_eq!(a.decode_seed(step), a.decode_seed(step), "seed must be reproducible");
            assert_ne!(
                a.decode_seed(step),
                b.decode_seed(step),
                "two requests drew the same decode seed at step {step}"
            );
        }
        // Steps within one request are distinct draws too.
        assert_ne!(a.decode_seed(1), a.decode_seed(2));
        // And the stream is a pure function of the key: a different run
        // seed moves every draw.
        assert_ne!(RequestRng::new(42, 0).decode_seed(1), a.decode_seed(1));
    }

    /// Counter-based means stateless: reading step 7 first and step 0 later
    /// yields exactly what reading them in order yields.
    #[test]
    fn request_stream_is_order_independent() {
        let r = RequestRng::new(3, 99);
        let out_of_order: Vec<u64> = [7u64, 0, 3]
            .iter()
            .map(|&s| {
                let mut g = r.at_step(s);
                g.next_u64()
            })
            .collect();
        let in_order: Vec<u64> = [0u64, 3, 7]
            .iter()
            .map(|&s| {
                let mut g = r.at_step(s);
                g.next_u64()
            })
            .collect();
        assert_eq!(out_of_order[1], in_order[0]);
        assert_eq!(out_of_order[2], in_order[1]);
        assert_eq!(out_of_order[0], in_order[2]);
    }

    #[test]
    fn splitmix64_mixes_and_distinguishes() {
        // Bijection smoke: no collisions over a dense low range + high bits.
        let mut seen = std::collections::HashSet::new();
        for x in 0..1000u64 {
            assert!(seen.insert(splitmix64(x)));
            assert!(seen.insert(splitmix64(x | (1 << 63))));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Pcg64::seeded(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 6);
            assert!((3..6).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(4);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn categorical_zero_weights_is_none() {
        let mut r = Pcg64::seeded(5);
        assert!(r.categorical(&[0.0, 0.0]).is_none());
        assert!(r.categorical(&[]).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.0) > 0.0);
        }
    }
}
