//! Deterministic pseudo-random number generation and sampling distributions.
//!
//! The offline build environment provides no `rand` crate, so pa-rl carries its
//! own RNG substrate: a PCG-XSH-RR 64/32-based 64-bit generator (`Pcg64`) with
//! explicit seeding, stream splitting, and the distributions the system needs
//! (uniform, normal, lognormal, categorical, permutation). Everything in the
//! repository that needs randomness threads one of these through explicitly so
//! runs are reproducible from a single root seed.

/// A 64-bit PCG-family generator (two xsh-rr 32-bit outputs per `next_u64`).
///
/// Deterministic, seedable, and cheap to fork into independent streams —
/// exactly what the coordinator needs to give each rollout worker / simulator
/// entity its own reproducible stream.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second normal variate from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different `stream`
    /// values with the same seed yield statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Fork an independent child stream; advances `self`.
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }

    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (Lemire-style rejection-free mapping is
    /// unnecessary here; modulo bias is negligible for our ranges but we use
    /// widening multiply anyway for correctness).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = hi - lo;
        // widening-multiply trick: floor(x * span / 2^64)
        let x = self.next_u64();
        lo + ((x as u128 * span as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterised by the *underlying* normal's mu/sigma.
    /// Used by the simulator for rollout-length distributions (long right
    /// tail, matching CoT response lengths).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (inter-arrival times in the simulator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Sample an index from unnormalised non-negative weights.
    /// Returns `None` if all weights are zero/empty.
    pub fn categorical(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be effectively independent");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Pcg64::seeded(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 6);
            assert!((3..6).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(4);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn categorical_zero_weights_is_none() {
        let mut r = Pcg64::seeded(5);
        assert!(r.categorical(&[0.0, 0.0]).is_none());
        assert!(r.categorical(&[]).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.0) > 0.0);
        }
    }
}
