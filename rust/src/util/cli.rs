//! Command-line argument parsing (no `clap` in the offline environment).
//!
//! Supports the subcommand + `--key value` / `--flag` style used by the
//! `pa-rl` binary and the examples:
//!
//! ```text
//! pa-rl train --config configs/small.json --mode async --iters 20
//! pa-rl simulate --table 1
//! ```

use std::collections::BTreeMap;

/// Parsed command line: an optional subcommand, named options, bare flags and
/// positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut items: Vec<String> = iter.into_iter().collect();
        if !items.is_empty() && !items[0].starts_with('-') {
            args.subcommand = Some(items.remove(0));
        }
        let mut i = 0;
        while i < items.len() {
            let item = &items[i];
            if let Some(name) = item.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(item.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the real process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --config configs/small.json --iters 20 --spa");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("configs/small.json"));
        assert_eq!(a.usize_or("iters", 0), 20);
        assert!(a.has_flag("spa"));
    }

    #[test]
    fn equals_form() {
        let a = parse("simulate --table=2 --mode=async");
        assert_eq!(a.get("table"), Some("2"));
        assert_eq!(a.str_or("mode", "sync"), "async");
    }

    #[test]
    fn trailing_flag_and_defaults() {
        let a = parse("bench --verbose");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.usize_or("iters", 7), 7);
        assert_eq!(a.f64_or("lr", 1e-6), 1e-6);
    }

    #[test]
    fn positional_args() {
        let a = parse("inspect artifacts/small extra");
        assert_eq!(a.subcommand.as_deref(), Some("inspect"));
        assert_eq!(a.positional, vec!["artifacts/small", "extra"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }
}
