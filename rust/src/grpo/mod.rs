//! GRPO data plane: rollout/group types, rule-based rewards, group-normalised
//! advantages, and micro-batch lowering for the two train-step layouts
//! (standard causal and shared-prompt attention).

pub mod advantage;
pub mod batch;
pub mod reward;
pub mod types;

pub use advantage::group_advantages;
pub use batch::{build_spa, build_standard, spa_ratio, Sample, TrainBatch};
pub use types::{Group, Rollout};
