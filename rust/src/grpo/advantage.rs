//! Group-normalised advantages (GRPO).
//!
//! For a group of G rewards r_1..r_G generated from the same prompt,
//! A_i = (r_i - mean(r)) / (std(r) + eps). A zero-variance group (all
//! rollouts equally right/wrong) yields all-zero advantages — the group
//! contributes only its KL term to the loss, matching standard GRPO
//! implementations.

/// Epsilon guarding the std division.
pub const ADV_EPS: f32 = 1e-6;

/// Compute group-normalised advantages.
pub fn group_advantages(rewards: &[f32]) -> Vec<f32> {
    let g = rewards.len();
    if g == 0 {
        return Vec::new();
    }
    let mean = rewards.iter().sum::<f32>() / g as f32;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>() / g as f32;
    let std = var.sqrt();
    if std < ADV_EPS {
        return vec![0.0; g];
    }
    rewards.iter().map(|r| (r - mean) / (std + ADV_EPS)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_variance_gives_zero_advantage() {
        assert_eq!(group_advantages(&[1.0; 8]), vec![0.0; 8]);
        assert_eq!(group_advantages(&[0.0; 8]), vec![0.0; 8]);
    }

    #[test]
    fn mixed_rewards_sign_structure() {
        let a = group_advantages(&[1.0, 0.0, 0.0, 0.0]);
        assert!(a[0] > 0.0);
        assert!(a[1] < 0.0);
        assert_eq!(a[1], a[2]);
    }

    #[test]
    fn empty_group() {
        assert!(group_advantages(&[]).is_empty());
    }

    #[test]
    fn prop_zero_mean_unit_scale() {
        prop::quick(
            "advantages are zero-mean, bounded scale",
            |rng: &mut Pcg64, size| {
                let g = rng.range(2, size.scaled(32).max(2) + 2);
                (0..g).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect::<Vec<f32>>()
            },
            |rewards| {
                let adv = group_advantages(rewards);
                let mean: f32 = adv.iter().sum::<f32>() / adv.len() as f32;
                if mean.abs() > 1e-4 {
                    return Err(format!("advantage mean {mean} not ~0"));
                }
                // normalised by std -> values bounded by sqrt(G)
                let bound = (adv.len() as f32).sqrt() + 1e-3;
                if adv.iter().any(|a| a.abs() > bound) {
                    return Err(format!("advantage exceeds sqrt(G) bound {bound}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_shift_invariance() {
        prop::quick(
            "advantages invariant to reward shift",
            |rng: &mut Pcg64, size| {
                let g = rng.range(2, size.scaled(16).max(2) + 2);
                let rewards: Vec<f32> = (0..g).map(|_| rng.f32()).collect();
                let shift = rng.f32() * 10.0 - 5.0;
                (rewards, shift)
            },
            |(rewards, shift)| {
                let a = group_advantages(rewards);
                let shifted: Vec<f32> = rewards.iter().map(|r| r + shift).collect();
                let b = group_advantages(&shifted);
                for (x, y) in a.iter().zip(&b) {
                    if (x - y).abs() > 1e-3 {
                        return Err(format!("shift changed advantage: {x} vs {y}"));
                    }
                }
                Ok(())
            },
        );
    }
}
