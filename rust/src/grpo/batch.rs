//! Micro-batch construction for the two compiled train-step layouts.
//!
//! Both builders lower a set of (prompt, response, advantage) samples into the
//! flat i32/f32 arrays the AOT train-step artifacts consume. Per-token loss
//! weights encode the GRPO normalisation (1/n_samples * 1/|o_k| on response-
//! token label positions, 0 elsewhere), so the L2 loss is simply
//! `sum(weight * per_token_term)` and padded rows/positions contribute
//! nothing. The weights of a full micro-batch sum to exactly 1.
//!
//! **Standard layout** (`train_step`): one sample per row `[m, S]`, prompt and
//! response concatenated, causal attention; the prompt is recomputed for every
//! row of the same group — the redundancy SPA removes.
//!
//! **Shared-prompt layout** (`train_step_spa`, paper §4.3): one GRPO group per
//! micro-batch, packed as `[prompt, seg_1, ..., seg_K]` in a single row. Each
//! response segment *duplicates the final prompt token* as its first input
//! token (at the same rope position `Lp-1`): the duplicate's attention context
//! is {prompt[0..Lp-1], itself} — exactly the original last prompt token's
//! context — so its hidden state equals the standard computation's, and the
//! first response token's logprob is recovered exactly rather than dropped.
//! Segment tokens attend `{prompt[0..Lp-1]} ∪ {own segment ≤ self}`; the
//! original last prompt token's K/V is attended only by the prompt itself.
//! This makes SPA *exactly* equivalent to per-sample causal training
//! (∇L_shared = Σ∇L_k with no approximation), which the python tests assert.

use super::types::Group;
use crate::data::PAD;

/// A lowered micro-batch ready for a train-step artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainBatch {
    /// Rows (m for standard layout, 1 for SPA).
    pub rows: usize,
    /// Padded row length.
    pub seq: usize,
    /// `[rows*seq]` input token ids.
    pub tokens: Vec<i32>,
    /// `[rows*seq]` label ids (PAD where unused; weight is 0 there).
    pub labels: Vec<i32>,
    /// `[rows*seq]` rope position ids.
    pub pos: Vec<i32>,
    /// `[rows*seq]` segment ids (SPA: -1 pad, 0 prompt, 1..=K responses;
    /// standard layout: 0 for valid tokens, -1 for padding).
    pub seg: Vec<i32>,
    /// `[rows*seq]` per-token advantage (broadcast from the sample).
    pub adv: Vec<f32>,
    /// `[rows*seq]` per-token loss weight; sums to 1 over a full micro-batch.
    pub weight: Vec<f32>,
    /// Samples represented.
    pub n_samples: usize,
    /// Response tokens carrying loss (weight > 0).
    pub n_loss_tokens: usize,
    /// Total non-pad input tokens (compute volume; TPSPD counts these).
    pub n_input_tokens: usize,
}

/// One sample: prompt tokens, response tokens, advantage.
#[derive(Debug, Clone)]
pub struct Sample<'a> {
    pub prompt: &'a [u32],
    pub response: &'a [u32],
    pub advantage: f32,
}

impl<'a> Sample<'a> {
    pub fn from_group(group: &'a Group) -> Vec<Sample<'a>> {
        group
            .rollouts
            .iter()
            .zip(&group.advantages)
            .map(|(r, &a)| Sample {
                prompt: &group.prompt.tokens,
                response: &r.tokens,
                advantage: a,
            })
            .collect()
    }
}

/// Build a standard-layout micro-batch. `samples.len() <= rows`; spare rows
/// are fully padded with zero weight. Responses longer than the row allows
/// are truncated (callers size `seq >= prompt_max + max_new` so this only
/// triggers on misconfiguration).
pub fn build_standard(samples: &[Sample], rows: usize, seq: usize) -> TrainBatch {
    assert!(samples.len() <= rows, "{} samples > {rows} rows", samples.len());
    let n = rows * seq;
    let mut b = TrainBatch {
        rows,
        seq,
        tokens: vec![PAD as i32; n],
        labels: vec![PAD as i32; n],
        pos: vec![0; n],
        seg: vec![-1; n],
        adv: vec![0.0; n],
        weight: vec![0.0; n],
        n_samples: samples.len(),
        n_loss_tokens: 0,
        n_input_tokens: 0,
    };
    let m = samples.len().max(1);
    for (row, s) in samples.iter().enumerate() {
        let base = row * seq;
        let lp = s.prompt.len().min(seq);
        let lr = s.response.len().min(seq - lp);
        let total = lp + lr;
        for (i, &t) in s.prompt[..lp].iter().chain(s.response[..lr].iter()).enumerate() {
            b.tokens[base + i] = t as i32;
            b.pos[base + i] = i as i32;
            b.seg[base + i] = 0;
        }
        // labels: position t predicts tokens[t+1]
        for t in 0..total.saturating_sub(1) {
            b.labels[base + t] = b.tokens[base + t + 1];
        }
        // loss on label positions predicting response tokens:
        // t in [lp-1, lp+lr-2] predicts response[0..lr]
        if lr > 0 && lp > 0 {
            let w = 1.0 / (m as f32 * lr as f32);
            for t in (lp - 1)..(lp + lr - 1) {
                b.weight[base + t] = w;
                b.adv[base + t] = s.advantage;
            }
            b.n_loss_tokens += lr;
        }
        b.n_input_tokens += total;
    }
    b
}

/// Build a shared-prompt (SPA) micro-batch from one group's samples.
/// All samples must share the same prompt. Returns `None` if the packed
/// group does not fit in `pack_len` (caller falls back to standard layout).
pub fn build_spa(samples: &[Sample], pack_len: usize) -> Option<TrainBatch> {
    assert!(!samples.is_empty());
    let prompt = samples[0].prompt;
    debug_assert!(samples.iter().all(|s| s.prompt == prompt), "SPA pack requires one shared prompt");
    let lp = prompt.len();
    if lp == 0 {
        return None;
    }
    let needed: usize = lp + samples.iter().map(|s| s.response.len()).sum::<usize>();
    if needed > pack_len {
        return None;
    }
    let k = samples.len();
    let n = pack_len;
    let mut b = TrainBatch {
        rows: 1,
        seq: pack_len,
        tokens: vec![PAD as i32; n],
        labels: vec![PAD as i32; n],
        pos: vec![0; n],
        seg: vec![-1; n],
        adv: vec![0.0; n],
        weight: vec![0.0; n],
        n_samples: k,
        n_loss_tokens: 0,
        n_input_tokens: 0,
    };
    // Prompt segment.
    for (i, &t) in prompt.iter().enumerate() {
        b.tokens[i] = t as i32;
        b.pos[i] = i as i32;
        b.seg[i] = 0;
    }
    let mut cursor = lp;
    for (s_idx, s) in samples.iter().enumerate() {
        let lr = s.response.len();
        if lr == 0 {
            continue;
        }
        let seg_id = (s_idx + 1) as i32;
        let w = 1.0 / (k as f32 * lr as f32);
        // Segment inputs: [prompt_last, response[0..lr-1]] at rope positions
        // [lp-1, lp, ..., lp+lr-2]; labels: response[0..lr].
        for i in 0..lr {
            let idx = cursor + i;
            b.tokens[idx] =
                if i == 0 { prompt[lp - 1] as i32 } else { s.response[i - 1] as i32 };
            b.pos[idx] = (lp - 1 + i) as i32;
            b.seg[idx] = seg_id;
            b.labels[idx] = s.response[i] as i32;
            b.weight[idx] = w;
            b.adv[idx] = s.advantage;
        }
        cursor += lr;
        b.n_loss_tokens += lr;
    }
    b.n_input_tokens = cursor;
    Some(b)
}

/// The paper's Eq. 5 complexity-reduction ratio rho for a packed group:
/// shared cost / standard cost (attention token-pair counts).
pub fn spa_ratio(lp: usize, lr: usize, k: usize) -> f64 {
    let lp = lp as f64;
    let lr = lr as f64;
    let k = k as f64;
    (lp * lp + k * lr * (lp + lr)) / (k * (lp + lr) * (lp + lr))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn mk_samples<'a>(prompt: &'a [u32], responses: &'a [Vec<u32>]) -> Vec<Sample<'a>> {
        responses
            .iter()
            .enumerate()
            .map(|(i, r)| Sample { prompt, response: r, advantage: i as f32 - 1.0 })
            .collect()
    }

    #[test]
    fn standard_layout_shapes_and_shift() {
        let prompt = vec![1u32, 10, 11, 12];
        let responses = vec![vec![20u32, 21, 2], vec![30u32, 2]];
        let samples = mk_samples(&prompt, &responses);
        let b = build_standard(&samples, 4, 12);
        assert_eq!(b.rows, 4);
        assert_eq!(b.tokens.len(), 48);
        // row 0: tokens = prompt + response
        assert_eq!(&b.tokens[0..7], &[1, 10, 11, 12, 20, 21, 2]);
        // labels shifted by one
        assert_eq!(&b.labels[0..6], &[10, 11, 12, 20, 21, 2]);
        // loss weights on label positions 3..6 (predicting the 3 response tokens)
        assert_eq!(b.weight[2], 0.0);
        assert!(b.weight[3] > 0.0 && b.weight[4] > 0.0 && b.weight[5] > 0.0);
        assert_eq!(b.weight[6], 0.0);
        assert_eq!(b.n_loss_tokens, 5);
        assert_eq!(b.n_input_tokens, 7 + 6);
    }

    #[test]
    fn standard_weights_sum_to_one() {
        let prompt = vec![1u32, 10, 11];
        let responses = vec![vec![20u32, 2], vec![30u32, 31, 32, 2], vec![40u32, 2]];
        let samples = mk_samples(&prompt, &responses);
        let b = build_standard(&samples, 3, 10);
        let total: f32 = b.weight.iter().sum();
        assert!((total - 1.0).abs() < 1e-5, "weights sum {total}");
    }

    #[test]
    fn spa_pack_layout() {
        let prompt = vec![1u32, 10, 11, 12]; // lp = 4
        let responses = vec![vec![20u32, 21, 2], vec![30u32, 2]];
        let samples = mk_samples(&prompt, &responses);
        let b = build_spa(&samples, 16).unwrap();
        assert_eq!(b.rows, 1);
        // prompt occupies 0..4 with seg 0
        assert_eq!(&b.tokens[0..4], &[1, 10, 11, 12]);
        assert_eq!(&b.seg[0..4], &[0, 0, 0, 0]);
        // segment 1: inputs [prompt_last=12, 20, 21], pos [3,4,5], labels [20,21,2]
        assert_eq!(&b.tokens[4..7], &[12, 20, 21]);
        assert_eq!(&b.pos[4..7], &[3, 4, 5]);
        assert_eq!(&b.labels[4..7], &[20, 21, 2]);
        assert_eq!(&b.seg[4..7], &[1, 1, 1]);
        // segment 2: inputs [12, 30], labels [30, 2]
        assert_eq!(&b.tokens[7..9], &[12, 30]);
        assert_eq!(&b.labels[7..9], &[30, 2]);
        assert_eq!(&b.seg[7..9], &[2, 2]);
        // padding tail
        assert_eq!(b.seg[9], -1);
        let total: f32 = b.weight.iter().sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert_eq!(b.n_loss_tokens, 5);
        assert_eq!(b.n_input_tokens, 9);
    }

    #[test]
    fn spa_rejects_overflow() {
        let prompt = vec![1u32; 10];
        let responses = vec![vec![5u32; 8], vec![6u32; 8]];
        let samples = mk_samples(&prompt, &responses);
        assert!(build_spa(&samples, 20).is_none());
        assert!(build_spa(&samples, 26).is_some());
    }

    #[test]
    fn spa_ratio_matches_eq5_limits() {
        // Lp >> Lr: rho -> 1/K
        let rho = spa_ratio(1000, 10, 16);
        assert!((rho - 1.0 / 16.0).abs() < 0.05, "rho {rho}");
        // Lr >> Lp: rho -> 1 (no benefit)
        let rho = spa_ratio(10, 1000, 16);
        assert!(rho > 0.9, "rho {rho}");
    }

    #[test]
    fn prop_spa_input_token_saving() {
        // SPA packs lp + sum(lr) input tokens vs standard's k*lp + sum(lr).
        prop::quick(
            "spa packs fewer input tokens than standard",
            |rng: &mut Pcg64, size| {
                let lp = rng.range(2, size.scaled(40) + 3);
                let k = rng.range(2, 9);
                let responses: Vec<Vec<u32>> = (0..k)
                    .map(|_| (0..rng.range(1, 12)).map(|_| 5 + rng.next_u64() as u32 % 10).collect())
                    .collect();
                let prompt: Vec<u32> = (0..lp).map(|_| 3 + rng.next_u64() as u32 % 10).collect();
                (prompt, responses)
            },
            |(prompt, responses)| {
                let samples = mk_samples(prompt, responses);
                let sum_lr: usize = responses.iter().map(|r| r.len()).sum();
                let pack_len = prompt.len() + sum_lr + 4;
                let spa = build_spa(&samples, pack_len).ok_or("pack failed")?;
                let std =
                    build_standard(&samples, samples.len(), prompt.len() + 13);
                if spa.n_input_tokens > std.n_input_tokens {
                    return Err(format!(
                        "spa {} tokens > std {}",
                        spa.n_input_tokens, std.n_input_tokens
                    ));
                }
                // identical loss-token counts & weight normalisation
                if spa.n_loss_tokens != std.n_loss_tokens {
                    return Err("loss token mismatch".into());
                }
                let ws: f32 = spa.weight.iter().sum();
                let wt: f32 = std.weight.iter().sum();
                if (ws - 1.0).abs() > 1e-4 || (wt - 1.0).abs() > 1e-4 {
                    return Err(format!("weight sums {ws} {wt}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_spa_labels_cover_all_response_tokens() {
        prop::quick(
            "every response token appears exactly once as a weighted SPA label",
            |rng: &mut Pcg64, size| {
                let lp = rng.range(2, size.scaled(20) + 3);
                let k = rng.range(1, 6);
                let responses: Vec<Vec<u32>> = (0..k)
                    .map(|_| (0..rng.range(1, 10)).map(|_| 3 + rng.next_u64() as u32 % 20).collect())
                    .collect();
                let prompt: Vec<u32> = (0..lp).map(|_| 3 + rng.next_u64() as u32 % 20).collect();
                (prompt, responses)
            },
            |(prompt, responses)| {
                let samples = mk_samples(prompt, responses);
                let sum_lr: usize = responses.iter().map(|r| r.len()).sum();
                let b = build_spa(&samples, prompt.len() + sum_lr).ok_or("pack failed")?;
                let mut labelled: Vec<i32> = b
                    .weight
                    .iter()
                    .zip(&b.labels)
                    .filter(|(w, _)| **w > 0.0)
                    .map(|(_, l)| *l)
                    .collect();
                let mut expected: Vec<i32> =
                    responses.iter().flatten().map(|&t| t as i32).collect();
                labelled.sort_unstable();
                expected.sort_unstable();
                if labelled != expected {
                    return Err("labelled multiset != response tokens".into());
                }
                Ok(())
            },
        );
    }
}
