//! Rule-based reward (the paper §6: "the predicted answer is considered
//! correct if it can be accurately extracted and matches the ground-truth
//! answer; otherwise it is deemed incorrect").
//!
//! Binary reward over the synthetic arithmetic task: decode the response,
//! extract the integer answer, exact-match against ground truth.

use crate::data::{Tokenizer, EOS};

/// Extract the answer from decoded response text. The extraction rule is
/// strict, mirroring GSM8K-style verifiers: the response up to EOS must be a
/// bare (optionally sign-prefixed) integer, ignoring surrounding whitespace
/// and a trailing period. Anything else fails extraction.
pub fn extract_answer(text: &str) -> Option<i64> {
    let t = text.trim().trim_end_matches('.').trim();
    if t.is_empty() {
        return None;
    }
    let (sign, digits) = match t.strip_prefix('-') {
        Some(rest) => (-1i64, rest),
        None => (1i64, t),
    };
    if digits.is_empty() || !digits.chars().all(|c| c.is_ascii_digit()) {
        return None;
    }
    // Reject absurdly long numbers (overflow guard).
    if digits.len() > 18 {
        return None;
    }
    digits.parse::<i64>().ok().map(|v| sign * v)
}

/// Score a response token sequence against the ground truth.
pub fn score(tokenizer: &Tokenizer, response: &[u32], answer: i64) -> f32 {
    // Responses that never emitted EOS were truncated; still score the text
    // (matching the paper's handling of truncation at reduced context, §6.2:
    // truncation lowers accuracy, it is not special-cased).
    let text = tokenizer.decode(response);
    match extract_answer(&text) {
        Some(a) if a == answer => 1.0,
        _ => 0.0,
    }
}

/// Whether a response terminated with EOS.
pub fn terminated(response: &[u32]) -> bool {
    response.last() == Some(&EOS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Tokenizer, EOS};

    #[test]
    fn extracts_plain_integers() {
        assert_eq!(extract_answer("42"), Some(42));
        assert_eq!(extract_answer(" 42 "), Some(42));
        assert_eq!(extract_answer("42."), Some(42));
        assert_eq!(extract_answer("-7"), Some(-7));
        assert_eq!(extract_answer("0"), Some(0));
    }

    #[test]
    fn rejects_non_answers() {
        assert_eq!(extract_answer(""), None);
        assert_eq!(extract_answer("4 2"), None);
        assert_eq!(extract_answer("abc"), None);
        assert_eq!(extract_answer("4a"), None);
        assert_eq!(extract_answer("--4"), None);
        assert_eq!(extract_answer("99999999999999999999999"), None);
    }

    #[test]
    fn scores_token_sequences() {
        let t = Tokenizer::new();
        let ids42: Vec<u32> = t.encode("42").unwrap();
        let mut with_eos = ids42.clone();
        with_eos.push(EOS);
        assert_eq!(score(&t, &with_eos, 42), 1.0);
        assert_eq!(score(&t, &with_eos, 43), 0.0);
        // Truncated (no EOS) but correct text still scores.
        assert_eq!(score(&t, &ids42, 42), 1.0);
        // Garbage after the number fails strict extraction.
        let bad = t.encode("42+").unwrap();
        assert_eq!(score(&t, &bad, 42), 0.0);
    }

    #[test]
    fn terminated_checks_eos() {
        let t = Tokenizer::new();
        let mut ids = t.encode("7").unwrap();
        assert!(!terminated(&ids));
        ids.push(EOS);
        assert!(terminated(&ids));
    }
}
