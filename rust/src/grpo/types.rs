//! Core GRPO data types flowing through the producer–consumer pipeline.

use crate::data::Prompt;
use crate::metrics::RequestTimeline;

/// One generated response for a prompt, tagged with the policy version that
/// produced it. The version tag makes the paper's on-policy invariant
/// (Prop. 1: all rollouts in a batch come from θ_t) *structural*: the
/// consumer asserts `weight_version == iteration` on every dequeue.
#[derive(Debug, Clone)]
pub struct Rollout {
    /// Index within the group (0..G).
    pub sample_idx: usize,
    /// Policy version (iteration t) whose weights generated this rollout.
    pub weight_version: u64,
    /// Response token ids, including the terminating EOS if one was emitted.
    pub tokens: Vec<u32>,
    /// Engine-side per-token log-probabilities (diagnostics / integration
    /// tests against the tri-model's old-policy logprobs).
    pub logprobs: Vec<f32>,
    /// Rule-based reward.
    pub reward: f32,
    /// Lifecycle stamps gathered on the request's way here (all-unset in
    /// basic metrics mode); the consumer adds the final train-consume stamp.
    pub timeline: RequestTimeline,
}

/// A complete GRPO group: one prompt with its G scored rollouts and
/// group-normalised advantages. The unit that flows through the shared queue
/// (advantages need the full group, so groups are enqueued whole).
#[derive(Debug, Clone)]
pub struct Group {
    pub prompt: Prompt,
    pub weight_version: u64,
    pub rollouts: Vec<Rollout>,
    /// Group-normalised advantage per rollout.
    pub advantages: Vec<f32>,
    /// Wall-clock seconds spent generating this group (for the timeline).
    pub gen_seconds: f64,
}

impl Group {
    /// Total response tokens in the group (the "training tokens" that TPSPD
    /// counts for non-SPA training; SPA counts packed tokens).
    pub fn response_tokens(&self) -> usize {
        self.rollouts.iter().map(|r| r.tokens.len()).sum()
    }

    pub fn mean_reward(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rollouts.iter().map(|r| r.reward).sum::<f32>() / self.rollouts.len() as f32
    }
}
