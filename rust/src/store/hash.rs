//! Content hashing for the shared segment store and prompt-affinity routing.
//!
//! The store is content-addressed: a block entry is keyed by a 64-bit hash of
//! the *entire token prefix* ending at that block's boundary (Mooncake/vLLM
//! prefix-caching style), so two engines that computed the same prefix
//! independently land on the same key and dedupe. The dispatcher hashes the
//! same prefix form to pick a preferred engine, which is what makes affinity
//! routing and store keys agree about what "the template" is.
//!
//! [`PrefixHasher`] folds tokens incrementally so a caller probing every
//! block boundary of an n-token prompt pays O(n) total hashing, not O(n^2).
//! The hash is order-sensitive and avalanched (splitmix64-style finalizer on
//! every fold); collisions are further guarded by fragment-token comparison
//! at lookup time in [`super::segments`].

/// Incremental order-sensitive hash over a token prefix.
#[derive(Debug, Clone)]
pub struct PrefixHasher {
    h: u64,
}

impl Default for PrefixHasher {
    fn default() -> Self {
        // Must agree with `new()` — a zero-seeded hasher would silently
        // compute keys no store entry or router bucket ever matches.
        PrefixHasher::new()
    }
}

impl PrefixHasher {
    pub fn new() -> PrefixHasher {
        PrefixHasher { h: 0x9E37_79B9_7F4A_7C15 }
    }

    /// Fold one token; returns the hash of the prefix including it.
    pub fn push(&mut self, tok: u32) -> u64 {
        let mut x = self.h ^ (u64::from(tok).wrapping_add(0xA076_1D64_78BD_642F));
        x = x.wrapping_mul(0xE703_7ED1_A0B4_28DB);
        x ^= x >> 32;
        self.h = x.wrapping_mul(0x8EBC_6AF0_9C88_C6E3);
        self.h
    }

    /// Hash of everything pushed so far.
    pub fn value(&self) -> u64 {
        self.h
    }
}

/// One-shot hash of a whole token prefix.
pub fn hash_prefix(tokens: &[u32]) -> u64 {
    let mut h = PrefixHasher::new();
    for &t in tokens {
        h.push(t);
    }
    h.value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_agrees_with_new() {
        let mut a = PrefixHasher::new();
        let mut b = PrefixHasher::default();
        assert_eq!(a.push(7), b.push(7));
    }

    #[test]
    fn incremental_matches_one_shot() {
        let seq = [3u32, 0, 7, 7, 42, 1];
        let mut h = PrefixHasher::new();
        for (i, &t) in seq.iter().enumerate() {
            let v = h.push(t);
            assert_eq!(v, hash_prefix(&seq[..=i]));
        }
    }

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(hash_prefix(&[1, 2]), hash_prefix(&[2, 1]));
        assert_ne!(hash_prefix(&[1]), hash_prefix(&[1, 0]));
        assert_ne!(hash_prefix(&[0]), hash_prefix(&[0, 0]));
        assert_ne!(hash_prefix(&[]), hash_prefix(&[0]));
    }

    #[test]
    fn spreads_over_small_alphabets() {
        // Template prefixes differ in few tokens; the affinity router maps
        // hash % n_engines, so low bits must vary across near-identical
        // prefixes.
        let mut seen = std::collections::HashSet::new();
        for a in 0..8u32 {
            for b in 0..8u32 {
                seen.insert(hash_prefix(&[a, b, 5, 5]) % 8);
            }
        }
        assert!(seen.len() >= 7, "low bits collapse: {} of 8 buckets", seen.len());
    }
}
