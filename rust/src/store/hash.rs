//! Content hashing for the shared segment store and prompt-affinity routing.
//!
//! The store is content-addressed: a block entry is keyed by a 64-bit hash of
//! the *entire token prefix* ending at that block's boundary (Mooncake/vLLM
//! prefix-caching style), so two engines that computed the same prefix
//! independently land on the same key and dedupe. The dispatcher hashes the
//! same prefix form to pick a preferred engine, which is what makes affinity
//! routing and store keys agree about what "the template" is.
//!
//! [`PrefixHasher`] folds tokens incrementally so a caller probing every
//! block boundary of an n-token prompt pays O(n) total hashing, not O(n^2).
//! The hash is order-sensitive and avalanched (splitmix64-style finalizer on
//! every fold); collisions are further guarded by fragment-token comparison
//! at lookup time in [`super::segments`].

/// Incremental order-sensitive hash over a token prefix.
#[derive(Debug, Clone)]
pub struct PrefixHasher {
    h: u64,
}

impl Default for PrefixHasher {
    fn default() -> Self {
        // Must agree with `new()` — a zero-seeded hasher would silently
        // compute keys no store entry or router bucket ever matches.
        PrefixHasher::new()
    }
}

impl PrefixHasher {
    pub fn new() -> PrefixHasher {
        PrefixHasher { h: 0x9E37_79B9_7F4A_7C15 }
    }

    /// Fold one token; returns the hash of the prefix including it.
    pub fn push(&mut self, tok: u32) -> u64 {
        let mut x = self.h ^ (u64::from(tok).wrapping_add(0xA076_1D64_78BD_642F));
        x = x.wrapping_mul(0xE703_7ED1_A0B4_28DB);
        x ^= x >> 32;
        self.h = x.wrapping_mul(0x8EBC_6AF0_9C88_C6E3);
        self.h
    }

    /// Hash of everything pushed so far.
    pub fn value(&self) -> u64 {
        self.h
    }
}

/// One-shot hash of a whole token prefix.
pub fn hash_prefix(tokens: &[u32]) -> u64 {
    let mut h = PrefixHasher::new();
    for &t in tokens {
        h.push(t);
    }
    h.value()
}

/// One link of a prefix's block chain: the token fragment `[start, end)` and
/// the store key — the hash of the *whole prefix* through `end`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainLink {
    pub start: usize,
    pub end: usize,
    pub key: u64,
}

/// Iterator over the block-boundary chain of a token prefix: one
/// [`ChainLink`] per `block_tokens` boundary, plus the unaligned tail when
/// present, each carrying the rolling-hash key of the prefix through it.
///
/// This is the single source of truth for "where do a prefix's block
/// boundaries fall and what are their keys" — the shard's publish walk, the
/// fetch walk and the residency probe all iterate it, so they can never
/// disagree about boundaries or keys (they used to duplicate the walk).
/// O(n) total hashing for an n-token prefix.
#[derive(Debug, Clone)]
pub struct ChainKeys<'a> {
    tokens: &'a [u32],
    block_tokens: usize,
    hasher: PrefixHasher,
    pos: usize,
}

/// Walk the block chain of `tokens` (see [`ChainKeys`]).
pub fn chain_keys(tokens: &[u32], block_tokens: usize) -> ChainKeys<'_> {
    assert!(block_tokens > 0, "degenerate block size");
    ChainKeys { tokens, block_tokens, hasher: PrefixHasher::new(), pos: 0 }
}

impl Iterator for ChainKeys<'_> {
    type Item = ChainLink;

    fn next(&mut self) -> Option<ChainLink> {
        if self.pos >= self.tokens.len() {
            return None;
        }
        let start = self.pos;
        let end = (start + self.block_tokens).min(self.tokens.len());
        for &t in &self.tokens[start..end] {
            self.hasher.push(t);
        }
        self.pos = end;
        Some(ChainLink { start, end, key: self.hasher.value() })
    }
}

/// Blocks of the prompt head the affinity router hashes. Capping the routed
/// prefix at a fixed depth (rather than "everything but the last partial
/// block") is what keeps same-template prompts with *different question
/// lengths* on the same engine: an uncapped block-aligned prefix would
/// extend past the template into per-prompt question tokens whenever lengths
/// vary, and scatter the template across engines. Two blocks discriminate
/// distinct templates well while staying safely inside any realistic
/// template. (Lives here, next to the store keys it must agree with, so the
/// engine's warmth advertisements and the coordinator's router share one
/// definition without the engine depending on coordinator code.)
pub const AFFINITY_BLOCKS: usize = 2;

/// The routed prefix: the longest block-aligned proper prefix of the prompt,
/// capped at [`AFFINITY_BLOCKS`] blocks (the final partial block — the
/// per-prompt question tail — never participates). Whole-prompt fallback for
/// prompts shorter than one block.
pub fn affinity_prefix_len(prompt_len: usize, block_tokens: usize) -> usize {
    let bt = block_tokens.max(1);
    let aligned = prompt_len.saturating_sub(1) / bt * bt;
    if aligned == 0 {
        prompt_len
    } else {
        aligned.min(AFFINITY_BLOCKS * bt)
    }
}

/// `(key, prefix length)` of a prompt's affinity prefix — the identity the
/// router, the warmth map and the engines' warm-template advertisements all
/// agree on.
pub fn affinity_key(prompt: &[u32], block_tokens: usize) -> (u64, usize) {
    let len = affinity_prefix_len(prompt.len(), block_tokens);
    (hash_prefix(&prompt[..len]), len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_agrees_with_new() {
        let mut a = PrefixHasher::new();
        let mut b = PrefixHasher::default();
        assert_eq!(a.push(7), b.push(7));
    }

    #[test]
    fn incremental_matches_one_shot() {
        let seq = [3u32, 0, 7, 7, 42, 1];
        let mut h = PrefixHasher::new();
        for (i, &t) in seq.iter().enumerate() {
            let v = h.push(t);
            assert_eq!(v, hash_prefix(&seq[..=i]));
        }
    }

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(hash_prefix(&[1, 2]), hash_prefix(&[2, 1]));
        assert_ne!(hash_prefix(&[1]), hash_prefix(&[1, 0]));
        assert_ne!(hash_prefix(&[0]), hash_prefix(&[0, 0]));
        assert_ne!(hash_prefix(&[]), hash_prefix(&[0]));
    }

    #[test]
    fn chain_keys_match_boundary_hashes() {
        // The chain walk must produce exactly the boundaries the old
        // publish/fetch loops computed: every block_tokens multiple plus the
        // unaligned tail, each keyed by the whole prefix through it.
        let seq: Vec<u32> = (0..11).collect();
        let links: Vec<ChainLink> = chain_keys(&seq, 4).collect();
        assert_eq!(
            links.iter().map(|l| (l.start, l.end)).collect::<Vec<_>>(),
            vec![(0, 4), (4, 8), (8, 11)]
        );
        for l in &links {
            assert_eq!(l.key, hash_prefix(&seq[..l.end]));
        }
        // Aligned prefix: no tail link. Empty prefix: no links at all.
        assert_eq!(chain_keys(&seq[..8], 4).count(), 2);
        assert_eq!(chain_keys(&[], 4).count(), 0);
        // Sub-block prefix: a single tail link covering everything.
        let short: Vec<ChainLink> = chain_keys(&seq[..3], 4).collect();
        assert_eq!(short.len(), 1);
        assert_eq!((short[0].start, short[0].end), (0, 3));
    }

    #[test]
    fn affinity_prefix_drops_the_partial_tail_block() {
        assert_eq!(affinity_prefix_len(10, 4), 8);
        assert_eq!(affinity_prefix_len(8, 4), 4, "aligned length is itself a tail");
        assert_eq!(affinity_prefix_len(3, 4), 3, "short prompt: whole-prompt fallback");
        assert_eq!(affinity_prefix_len(1, 4), 1);
        // Capped: long prompts hash a fixed head, so a 48-token template
        // with question tails of varying length routes identically.
        assert_eq!(affinity_prefix_len(56, 4), AFFINITY_BLOCKS * 4);
        assert_eq!(affinity_prefix_len(62, 4), AFFINITY_BLOCKS * 4);
    }

    #[test]
    fn affinity_key_is_stable_across_question_lengths() {
        let template: Vec<u32> = (0..48).map(|i| 3 + (i % 7)).collect();
        let keys: std::collections::HashSet<u64> = (5..13)
            .map(|q| {
                let mut p = template.clone();
                p.extend((0..q).map(|i| 60 + i));
                affinity_key(&p, 4).0
            })
            .collect();
        assert_eq!(keys.len(), 1, "template identity scattered: {keys:?}");
    }

    #[test]
    fn spreads_over_small_alphabets() {
        // Template prefixes differ in few tokens; the affinity router maps
        // hash % n_engines, so low bits must vary across near-identical
        // prefixes.
        let mut seen = std::collections::HashSet::new();
        for a in 0..8u32 {
            for b in 0..8u32 {
                seen.insert(hash_prefix(&[a, b, 5, 5]) % 8);
            }
        }
        assert!(seen.len() >= 7, "low bits collapse: {} of 8 buckets", seen.len());
    }
}
