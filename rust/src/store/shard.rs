//! One shard of the cross-engine segment store.
//!
//! The store is partitioned into `S` independent shards, each behind its own
//! lock in the [`super::SharedKvStore`] facade. A whole *chain* (every block
//! of one published prefix) lives in exactly one shard — the facade routes
//! by the hash of the chain's first block ([`super::hash`]), so publish,
//! fetch and the residency probe each lock a single shard and the chain
//! invariants (contiguity, publish-never-evicts-own-chain, lease pinning)
//! stay shard-local. Capacity is a per-shard slice of the configured block
//! budget; version/epoch bookkeeping is replicated per shard and advanced in
//! lockstep by the facade, so `shards = 1` is bit-identical to the old
//! single-`StoreCore` store.
//!
//! Eviction is O(log n) amortised via a lazily-invalidated min-heap of
//! candidate entries, replacing the old O(n) scan under the (then-global)
//! mutex: every transition *into* evictability (entry stored, last lease
//! released, LRU key refreshed) pushes a `(policy key, entry key)` heap
//! entry; pops discard entries whose segment has since been evicted,
//! re-leased or re-keyed. The heap orders by `(policy key, entry key)` —
//! exactly the old scan's `min_by_key` tie-break — so the victim *sequence*
//! is identical to the linear scan's (the differential proptest below drives
//! both against the same workloads and requires identical post-states). The
//! `check()` covering invariant — every currently evictable entry has a live
//! heap entry carrying its current key — is what keeps laziness sound.

use super::hash::chain_keys;
use super::segments::{Entry, FetchedCore, Publish};
use super::stats::StoreStats;
use crate::engine::kvcache::EvictPolicy;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// A lazily-invalidated eviction candidate: `(policy key, entry key)`,
/// min-ordered. Ticks are never reused, so a re-published entry always
/// carries fresh policy keys and stale heap entries can never match it.
type HeapEntry = Reverse<(u64, u64)>;

/// The state behind one facade lock: a content-addressed map of
/// block-granular KV segments covering one hash range of chains.
#[derive(Debug)]
pub(crate) struct Shard {
    block_tokens: usize,
    capacity: usize,
    policy: EvictPolicy,
    /// f32 elements per token row; learned from the first publish and
    /// enforced afterwards (all engines share one KV geometry).
    row_elems: Option<usize>,
    entries: HashMap<u64, Entry>,
    /// Params version the resident segments were computed under.
    version: Option<u64>,
    /// Lease epoch; bumped on every flush so stale releases are ignored.
    pub(crate) epoch: u64,
    tick: u64,
    /// Min-heap of eviction candidates (see module docs).
    evictable: BinaryHeap<HeapEntry>,
    pub(crate) stats: StoreStats,
    /// Differential testing only: route evictions through the old O(n)
    /// linear scan instead of the heap.
    #[cfg(test)]
    pub(crate) use_scan_evict: bool,
}

impl Shard {
    pub fn new(block_tokens: usize, capacity: usize, policy: EvictPolicy) -> Shard {
        assert!(block_tokens > 0 && capacity > 0, "degenerate shard geometry");
        Shard {
            block_tokens,
            capacity,
            policy,
            row_elems: None,
            entries: HashMap::new(),
            version: None,
            epoch: 0,
            tick: 0,
            evictable: BinaryHeap::new(),
            stats: StoreStats::default(),
            #[cfg(test)]
            use_scan_evict: false,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn live_blocks(&self) -> usize {
        self.entries.len()
    }

    pub fn leased_blocks(&self) -> usize {
        self.entries.values().filter(|e| e.refs > 0).count()
    }

    fn tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// The eviction-policy ordering key for an entry.
    fn evict_key(&self, e: &Entry) -> u64 {
        match self.policy {
            EvictPolicy::Lru => e.last_use,
            EvictPolicy::Fifo => e.created,
        }
    }

    /// Push a heap entry if `key` currently names an unleased entry. Cheap
    /// and idempotent: duplicates and soon-stale entries are discarded at
    /// pop time, and [`Shard::compact_heap`] bounds pile-up on touch-heavy
    /// workloads that never evict.
    fn heap_push(&mut self, key: u64) {
        let entry = match self.entries.get(&key) {
            Some(e) if e.refs == 0 => Reverse((self.evict_key(e), key)),
            _ => return,
        };
        self.evictable.push(entry);
        // Amortised O(1): a rebuild costs O(live entries) and is triggered
        // only after at least that many pushes since the last one.
        if self.evictable.len() > self.entries.len() * 2 + 64 {
            self.compact_heap();
        }
    }

    /// Rebuild the candidate heap from scratch: one current-key entry per
    /// unleased segment, every stale entry dropped.
    fn compact_heap(&mut self) {
        let mut fresh = BinaryHeap::with_capacity(self.entries.len());
        for (k, e) in &self.entries {
            if e.refs == 0 {
                fresh.push(Reverse((self.evict_key(e), *k)));
            }
        }
        self.evictable = fresh;
    }

    /// Bind the shard to a params version. A real bump flushes every segment
    /// (cached KV is a function of the weights) and invalidates outstanding
    /// leases; re-announcing the current version keeps the shard warm.
    /// Returns true when a flush happened.
    pub fn set_version(&mut self, v: u64) -> bool {
        if self.version == Some(v) {
            return false;
        }
        if self.version.is_some() {
            self.stats.clears += 1;
        }
        self.entries.clear();
        self.evictable.clear();
        self.epoch += 1;
        self.version = Some(v);
        true
    }

    /// Publish a completed prefix: one entry per block boundary (existing
    /// blocks are deduped and LRU-refreshed; `logits` attach to the final
    /// boundary and never get erased by a later `None`). With `allow_evict`,
    /// unleased entries are evicted to make room (never this prefix's own
    /// chain — that would orphan the blocks just stored); without it, a full
    /// shard drops the remainder instead, so dedup refreshes and free-space
    /// growth stay available to budget-exhausted engines. Stops at the
    /// first un-storable block, since deeper blocks would be unreachable
    /// through the hole anyway.
    pub fn publish(
        &mut self,
        tokens: &[u32],
        rows: &[f32],
        logits: Option<&[f32]>,
        version: u64,
        allow_evict: bool,
    ) -> Publish {
        assert!(!tokens.is_empty(), "cannot publish an empty prefix");
        assert_eq!(rows.len() % tokens.len(), 0, "ragged rows");
        if self.version != Some(version) {
            self.stats.version_rejects += 1;
            return Publish::StaleVersion;
        }
        let re = rows.len() / tokens.len();
        match self.row_elems {
            None => self.row_elems = Some(re),
            Some(r) => assert_eq!(r, re, "row geometry changed across engines"),
        }
        let mut stored = 0usize;
        let mut evicted = 0usize;
        let mut dropped = false;
        // Keys of this prefix's chain verified or stored so far: the
        // eviction pass must never pick them, or storing a later block
        // would orphan the earlier ones (a fetch stops at the hole).
        let mut chain: Vec<u64> = Vec::new();
        for link in chain_keys(tokens, self.block_tokens) {
            let (start, end, key) = (link.start, link.end, link.key);
            let is_last = end == tokens.len();
            let t = self.tick();
            if let Some(e) = self.entries.get_mut(&key) {
                if e.end == end && e.tokens == tokens[start..end] {
                    // Dedup hit: refresh recency, upgrade terminal logits.
                    e.last_use = t;
                    if is_last && e.logits.is_none() {
                        if let Some(l) = logits {
                            e.logits = Some(l.to_vec());
                        }
                    }
                    chain.push(key);
                    // An LRU key change goes through the heap like any
                    // other transition (the old entry goes stale in place).
                    if self.policy == EvictPolicy::Lru {
                        self.heap_push(key);
                    }
                    continue;
                }
                // 64-bit key collision with a different prefix: leave the
                // resident entry alone; deeper blocks of ours would be
                // unreachable past the mismatch, so stop here.
                dropped = true;
                break;
            }
            while self.entries.len() >= self.capacity {
                if !allow_evict || !self.evict_one(&chain) {
                    break;
                }
                evicted += 1;
            }
            if self.entries.len() >= self.capacity {
                self.stats.publish_drops += 1;
                dropped = true;
                break;
            }
            self.entries.insert(
                key,
                Entry {
                    end,
                    tokens: tokens[start..end].to_vec(),
                    rows: rows[start * re..end * re].to_vec(),
                    logits: if is_last { logits.map(<[f32]>::to_vec) } else { None },
                    refs: 0,
                    last_use: t,
                    created: t,
                },
            );
            chain.push(key);
            stored += 1;
            self.heap_push(key);
        }
        if stored > 0 {
            self.stats.publishes += 1;
            self.stats.publish_blocks += stored as u64;
            Publish::Stored { blocks: stored, evicted }
        } else if dropped {
            Publish::Dropped
        } else {
            self.stats.publish_dups += 1;
            Publish::Duplicate
        }
    }

    /// Longest published prefix of `tokens` reconstructable from consecutive
    /// block entries. Returns `None` unless it covers strictly more than
    /// `min_len` tokens (the caller's local radix match — shorter coverage
    /// would import nothing new). On a hit, every matched entry gains a
    /// lease reference; the caller must release them via the facade.
    pub fn fetch_longest(
        &mut self,
        tokens: &[u32],
        min_len: usize,
        version: u64,
    ) -> Option<FetchedCore> {
        self.stats.fetches += 1;
        if self.version != Some(version) {
            self.stats.version_rejects += 1;
            self.stats.fetch_misses += 1;
            return None;
        }
        let Some(re) = self.row_elems else {
            // Nothing has ever been published into this shard.
            self.stats.fetch_misses += 1;
            return None;
        };
        let mut covered = 0usize;
        let mut keys: Vec<u64> = Vec::new();
        let mut rows: Vec<f32> = Vec::new();
        let mut logits: Option<Vec<f32>> = None;
        for link in chain_keys(tokens, self.block_tokens) {
            let Some(e) = self.entries.get(&link.key) else { break };
            // `link.start` is exactly `covered` while the chain is
            // contiguous; verify tokens to reject hash collisions.
            if e.end != link.end || e.tokens != tokens[link.start..link.end] {
                break;
            }
            rows.extend_from_slice(&e.rows);
            keys.push(link.key);
            covered = link.end;
            if covered == tokens.len() {
                logits = e.logits.clone();
            }
        }
        if covered <= min_len {
            self.stats.fetch_misses += 1;
            return None;
        }
        let t = self.tick();
        for k in &keys {
            let e = self.entries.get_mut(k).expect("matched above");
            e.refs += 1;
            e.last_use = t;
            // Acquiring the lease removed it from evictability; any live
            // heap entry goes stale and is discarded at pop time.
        }
        self.stats.fetch_hits += 1;
        self.stats.fetch_tokens += (covered - min_len) as u64;
        debug_assert_eq!(rows.len(), covered * re);
        Some(FetchedCore { len: covered, rows, logits, keys })
    }

    /// Tokens of `tokens` covered by resident segments, block-granular —
    /// the router's warmth probe. Non-mutating: refreshes no LRU stamps,
    /// acquires no lease, counts no fetch stats (mirrors the radix cache's
    /// `resident_prefix`). Whatever is resident is by construction valid for
    /// the shard's current version — a version bump flushes everything.
    pub fn residency(&self, tokens: &[u32]) -> usize {
        let mut covered = 0usize;
        for link in chain_keys(tokens, self.block_tokens) {
            match self.entries.get(&link.key) {
                Some(e) if e.end == link.end && e.tokens == tokens[link.start..link.end] => {
                    covered = link.end;
                }
                _ => break,
            }
        }
        covered
    }

    /// Drop one lease reference per key (facade guarantees epoch validity).
    pub fn release(&mut self, keys: &[u64]) {
        for k in keys {
            if let Some(e) = self.entries.get_mut(k) {
                debug_assert!(e.refs > 0, "store lease release without acquire");
                e.refs = e.refs.saturating_sub(1);
            }
            // Back at zero refs the entry is evictable again: re-cover it.
            self.heap_push(*k);
        }
    }

    /// Evict the best unleased entry per the policy, never touching
    /// `protect` (the publish-in-progress chain). False when every entry is
    /// leased or protected (or the shard is empty).
    ///
    /// O(log n) amortised: pops the lazily-invalidated candidate heap,
    /// discarding entries whose segment was evicted, re-leased or re-keyed
    /// since the push. Valid-but-protected candidates are set aside and
    /// re-pushed after the selection so later evictions still see them. The
    /// pop order over *current* keys is `(policy key, entry key)` — the old
    /// linear scan's exact ordering, so victims are identical.
    fn evict_one(&mut self, protect: &[u64]) -> bool {
        #[cfg(test)]
        if self.use_scan_evict {
            return self.evict_one_scan(protect);
        }
        let mut deferred: Vec<HeapEntry> = Vec::new();
        let victim = loop {
            let Some(Reverse((pkey, key))) = self.evictable.pop() else { break None };
            self.stats.evict_probes += 1;
            let live = self
                .entries
                .get(&key)
                .is_some_and(|e| e.refs == 0 && self.evict_key(e) == pkey);
            if !live {
                continue; // stale: evicted, re-leased or re-keyed since push
            }
            if protect.contains(&key) {
                deferred.push(Reverse((pkey, key)));
                continue;
            }
            break Some(key);
        };
        // Protected candidates stay evictable for later passes.
        for d in deferred {
            self.evictable.push(d);
        }
        match victim {
            Some(k) => {
                self.entries.remove(&k);
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// The old O(n) eviction scan, kept verbatim as the differential-test
    /// oracle: the heap path must pick byte-identical victim sequences.
    #[cfg(test)]
    pub(crate) fn evict_one_scan(&mut self, protect: &[u64]) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(k, e)| e.refs == 0 && !protect.contains(*k))
            .min_by_key(|(k, e)| (self.evict_key(e), **k))
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                self.entries.remove(&k);
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Resident entry keys, sorted (differential-test state comparison).
    #[cfg(test)]
    pub(crate) fn resident_keys(&self) -> Vec<u64> {
        let mut ks: Vec<u64> = self.entries.keys().copied().collect();
        ks.sort_unstable();
        ks
    }

    /// Structural invariants for the proptests.
    pub fn check(&self) -> Result<(), String> {
        if self.entries.len() > self.capacity {
            return Err(format!(
                "{} entries exceed shard capacity {}",
                self.entries.len(),
                self.capacity
            ));
        }
        for (k, e) in &self.entries {
            if e.tokens.is_empty() || e.tokens.len() > self.block_tokens {
                return Err(format!("entry {k:#x}: fragment of {} tokens", e.tokens.len()));
            }
            let start = if e.end % self.block_tokens == 0 {
                e.end - self.block_tokens
            } else {
                e.end / self.block_tokens * self.block_tokens
            };
            if e.end - start != e.tokens.len() {
                return Err(format!(
                    "entry {k:#x}: fragment {} tokens for range [{start}, {})",
                    e.tokens.len(),
                    e.end
                ));
            }
            if let Some(re) = self.row_elems {
                if e.rows.len() != e.tokens.len() * re {
                    return Err(format!("entry {k:#x}: row bookkeeping corrupt"));
                }
            }
        }
        // Heap covering invariant: every currently evictable entry must have
        // a live heap entry carrying its current policy key, or eviction
        // could miss it (or pick a worse victim than the linear scan).
        #[cfg(test)]
        if self.use_scan_evict {
            return Ok(());
        }
        for (k, e) in &self.entries {
            if e.refs > 0 {
                continue;
            }
            let key = self.evict_key(e);
            let covered = self.evictable.iter().any(|Reverse((pk, ek))| *ek == *k && *pk == key);
            if !covered {
                return Err(format!(
                    "evictable entry {k:#x} (key {key}) has no live heap entry"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    const RE: usize = 3;

    fn rows_for(seq: &[u32]) -> Vec<f32> {
        let mut acc = 11u64;
        let mut out = Vec::with_capacity(seq.len() * RE);
        for &t in seq {
            acc = acc.wrapping_mul(2862933555777941757).wrapping_add(u64::from(t) + 1);
            for e in 0..RE {
                out.push(((acc >> (e * 7 % 50)) & 0xFF) as f32);
            }
        }
        out
    }

    fn logits_for(seq: &[u32]) -> Vec<f32> {
        vec![seq.iter().sum::<u32>() as f32, seq.len() as f32]
    }

    #[test]
    fn residency_probe_is_non_mutating_and_block_granular() {
        let mut s = Shard::new(4, 16, EvictPolicy::Lru);
        s.set_version(1);
        let a: Vec<u32> = (0..10).collect(); // 2 full blocks + 2-token tail
        s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 1, true);
        assert_eq!(s.residency(&a), 10, "fully published prefix is fully resident");
        // A diverging suffix shares the aligned template head only.
        let b: Vec<u32> = [&a[..8], &[90, 91][..]].concat();
        assert_eq!(s.residency(&b), 8);
        assert_eq!(s.residency(&[70, 71]), 0, "cold prefix has no residency");
        // The probe refreshed nothing: stats and heap state untouched, so
        // the LRU victim order is exactly what the publishes established.
        assert_eq!(s.stats.fetches, 0);
        assert_eq!(s.stats.fetch_hits, 0);
        s.check().unwrap();
    }

    #[test]
    fn heap_stays_bounded_under_dedup_churn() {
        // Republishing the same prefix refreshes its LRU keys each time;
        // without compaction the heap would grow one entry per block per
        // publish, forever.
        let mut s = Shard::new(2, 8, EvictPolicy::Lru);
        s.set_version(1);
        let a: Vec<u32> = (0..8).collect();
        for _ in 0..10_000 {
            s.publish(&a, &rows_for(&a), None, 1, true);
        }
        assert!(
            s.evictable.len() <= s.entries.len() * 2 + 64,
            "candidate heap grew unbounded: {} entries for {} segments",
            s.evictable.len(),
            s.entries.len()
        );
        s.check().unwrap();
    }

    #[test]
    fn eviction_probes_stay_sublinear_in_entry_count() {
        // Steady-state churn over a large shard: with the old scan every
        // eviction cost O(entries); the heap path must examine a bounded
        // number of candidates per eviction.
        let cap = 512usize;
        let mut s = Shard::new(1, cap, EvictPolicy::Lru);
        s.set_version(1);
        for i in 0..(cap as u32 * 4) {
            s.publish(&[i, i + 1], &rows_for(&[i, i + 1]), None, 1, true);
        }
        let evictions = s.stats.evictions;
        assert!(evictions as usize > cap, "workload must actually churn");
        assert!(
            s.stats.evict_probes < evictions * 4 + 64,
            "{} probes for {} evictions looks linear in {} entries",
            s.stats.evict_probes,
            evictions,
            cap
        );
        s.check().unwrap();
    }

    /// The differential satellite: identical proptest-generated workloads
    /// drive the heap path and the old O(n) scan; victim sets and post-state
    /// must be identical after every operation.
    #[test]
    fn prop_heap_eviction_matches_linear_scan() {
        prop::quick(
            "shard eviction: heap == linear scan",
            |rng: &mut Pcg64, size| {
                let bt = rng.range(1, 5);
                let capacity = rng.range(2, 16);
                let fifo = rng.range(0, 2) == 0;
                let n_templates = rng.range(1, 4);
                let templates: Vec<Vec<u32>> = (0..n_templates)
                    .map(|_| (0..rng.range(1, 10)).map(|_| rng.range(0, 5) as u32).collect())
                    .collect();
                let ops: Vec<(u64, Vec<u32>)> = (0..size.scaled(60))
                    .map(|_| {
                        let t = &templates[rng.range(0, n_templates)];
                        let mut p = t.clone();
                        p.extend((0..rng.range(0, 5)).map(|_| rng.range(0, 5) as u32));
                        (rng.next_u64(), p)
                    })
                    .collect();
                (bt, capacity, fifo, ops)
            },
            |(bt, capacity, fifo, ops)| {
                let policy = if *fifo { EvictPolicy::Fifo } else { EvictPolicy::Lru };
                let mut heap = Shard::new(*bt, *capacity, policy);
                let mut scan = Shard::new(*bt, *capacity, policy);
                scan.use_scan_evict = true;
                heap.set_version(1);
                scan.set_version(1);
                // Parallel lease books: the same ops acquire/release the
                // same keys on both shards.
                let mut leases_h: Vec<Vec<u64>> = Vec::new();
                let mut leases_s: Vec<Vec<u64>> = Vec::new();
                for (op, prompt) in ops {
                    match op % 8 {
                        0..=3 => {
                            let logits = logits_for(prompt);
                            let a = heap.publish(prompt, &rows_for(prompt), Some(&logits), 1, op % 2 == 0);
                            let b = scan.publish(prompt, &rows_for(prompt), Some(&logits), 1, op % 2 == 0);
                            if a != b {
                                return Err(format!("publish diverged: {a:?} vs {b:?}"));
                            }
                        }
                        4..=5 => {
                            let min_len = (*op as usize / 8) % (prompt.len() + 1);
                            let a = heap.fetch_longest(prompt, min_len, 1);
                            let b = scan.fetch_longest(prompt, min_len, 1);
                            match (a, b) {
                                (None, None) => {}
                                (Some(a), Some(b)) => {
                                    if a.len != b.len || a.rows != b.rows || a.keys != b.keys {
                                        return Err("fetch results diverged".into());
                                    }
                                    leases_h.push(a.keys);
                                    leases_s.push(b.keys);
                                }
                                _ => return Err("fetch hit/miss diverged".into()),
                            }
                        }
                        _ => {
                            if !leases_h.is_empty() {
                                let i = (*op as usize / 8) % leases_h.len();
                                heap.release(&leases_h.swap_remove(i));
                                scan.release(&leases_s.swap_remove(i));
                            }
                        }
                    }
                    if heap.resident_keys() != scan.resident_keys() {
                        return Err(format!(
                            "resident sets diverged after {prompt:?}: heap {} vs scan {} entries",
                            heap.live_blocks(),
                            scan.live_blocks()
                        ));
                    }
                    if heap.stats.evictions != scan.stats.evictions {
                        return Err(format!(
                            "victim counts diverged: heap {} vs scan {}",
                            heap.stats.evictions, scan.stats.evictions
                        ));
                    }
                    if heap.leased_blocks() != scan.leased_blocks() {
                        return Err("lease pinning diverged".into());
                    }
                    heap.check()?;
                }
                Ok(())
            },
        );
    }
}
