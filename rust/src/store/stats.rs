//! Shared-store counters.
//!
//! One [`StoreStats`] instance lives inside *each shard* (global across
//! engines, unlike the per-engine [`crate::engine::CacheStats`]); the facade
//! folds the shards together with [`StoreStats::absorb`] on every snapshot.
//! The coordinator snapshots the aggregate per iteration and reports deltas
//! next to the per-engine cache metrics, so the cross-engine contribution is
//! separable from local radix hits in the CSV / trace outputs.

/// Cumulative counters of the cross-engine segment store.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreStats {
    /// Publish calls that stored at least one new block entry.
    pub publishes: u64,
    /// Block entries inserted by publishes.
    pub publish_blocks: u64,
    /// Publish calls whose every block was already resident (pure dedup —
    /// the common case once a template is warm store-wide).
    pub publish_dups: u64,
    /// Block entries not stored because eviction could not free capacity
    /// (every resident entry leased).
    pub publish_drops: u64,
    /// Fetch probes (one per admission that consulted the store).
    pub fetches: u64,
    /// Fetches that returned a prefix longer than the caller's local match.
    pub fetch_hits: u64,
    /// Fetches that could not beat the caller's local match.
    pub fetch_misses: u64,
    /// Prompt tokens handed to importers *beyond* their local radix match —
    /// the store's own contribution to `prefill_tokens_saved`.
    pub fetch_tokens: u64,
    /// Publishes/fetches rejected because the caller's params version did
    /// not match the store's (engines mid-sync; stale KV must never cross).
    pub version_rejects: u64,
    /// Unleased block entries evicted to make room.
    pub evictions: u64,
    /// Candidate-heap entries examined (popped) across evictions — the
    /// heap-path cost counter. With the lazily-invalidated min-heap this
    /// stays O(1) amortised per eviction; the old linear scan would have
    /// cost O(live entries) per eviction instead, so benches assert
    /// `evict_probes` stays far below `evictions * live_entries`.
    pub evict_probes: u64,
    /// Whole-store flushes (a real params-version bump).
    pub clears: u64,
}

impl StoreStats {
    /// Fraction of fetches that imported something, in [0, 1].
    pub fn fetch_hit_rate(&self) -> f64 {
        if self.fetches == 0 {
            0.0
        } else {
            self.fetch_hits as f64 / self.fetches as f64
        }
    }

    /// Fold another shard's counters into this one (facade aggregation).
    pub fn absorb(&mut self, o: &StoreStats) {
        self.publishes += o.publishes;
        self.publish_blocks += o.publish_blocks;
        self.publish_dups += o.publish_dups;
        self.publish_drops += o.publish_drops;
        self.fetches += o.fetches;
        self.fetch_hits += o.fetch_hits;
        self.fetch_misses += o.fetch_misses;
        self.fetch_tokens += o.fetch_tokens;
        self.version_rejects += o.version_rejects;
        self.evictions += o.evictions;
        self.evict_probes += o.evict_probes;
        // Shards flush in lockstep (the facade drives every set_version), so
        // the whole-store flush count is any one shard's — not the sum.
        self.clears = self.clears.max(o.clears);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fetch_hit_rate_edges() {
        let mut s = StoreStats::default();
        assert_eq!(s.fetch_hit_rate(), 0.0);
        s.fetches = 4;
        s.fetch_hits = 3;
        assert!((s.fetch_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn absorb_sums_counters_but_not_lockstep_clears() {
        let mut total = StoreStats::default();
        for _ in 0..3 {
            let shard = StoreStats {
                publishes: 2,
                fetches: 5,
                fetch_hits: 1,
                evictions: 4,
                evict_probes: 6,
                clears: 7,
                ..StoreStats::default()
            };
            total.absorb(&shard);
        }
        assert_eq!(total.publishes, 6);
        assert_eq!(total.fetches, 15);
        assert_eq!(total.evictions, 12);
        assert_eq!(total.evict_probes, 18);
        assert_eq!(total.clears, 7, "lockstep flushes must not triple-count");
    }
}
